"""End-to-end driver (the paper's kind: INFERENCE): event-driven CNN serving.

Serves image requests through the production serving tier (DESIGN.md §10):
a FIFO queue continuously batched into padded buckets, one AOT-warmed
executable per bucket, weights replicated over the (data, model) mesh.
Every completed request is checked against the dense oracle, per-layer
event stats feed the cost model, and throughput/energy are reported in
the paper's units (frames/s, frames/J).

    PYTHONPATH=src python examples/serve_cnn_events.py --rate 4 --ticks 4 \
        --size 64 --cache-dir /tmp/mnf_cache
"""
import argparse
import time

import jax
import numpy as np

from repro.costmodel import network_cycles, table4_row
from repro.data import cnn_batch
from repro.models.cnn import ALEXNET, VGG16, init_cnn_params, \
    make_cnn_pipeline, run_with_stats
from repro.serving import ServeEngine, ServeEngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", choices=("alexnet", "vgg16"), default="alexnet")
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--rate", type=int, default=4,
                    help="request arrivals per serving tick")
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--buckets", default="1,4,8",
                    help="compiled batch bucket sizes, ascending")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent compilation cache (restarted replicas "
                         "re-warm from disk)")
    ap.add_argument("--weight-sparsity", type=float, default=0.5)
    ap.add_argument("--act-sparsity", type=float, default=0.6)
    args = ap.parse_args()

    spec = (ALEXNET if args.net == "alexnet" else VGG16).scaled(args.size)
    params = init_cnn_params(jax.random.PRNGKey(0), spec,
                             weight_sparsity=args.weight_sparsity)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    t0 = time.time()
    eng = ServeEngine(spec, params,
                      ServeEngineConfig(buckets=buckets,
                                        cache_dir=args.cache_dir))
    print(f"replica warmed in {time.time() - t0:.1f}s "
          f"(buckets {list(buckets)}, "
          f"compile {eng.warmup_s})")

    # Synthetic traffic, generated ahead of the serving loop; the dense
    # oracle (one compiled pipeline, DESIGN.md §5.1) checks every request.
    n_requests = args.rate * args.ticks
    frames = np.concatenate([
        np.asarray(cnn_batch(1, args.size, spec.in_ch, i,
                             activation_sparsity=args.act_sparsity))
        for i in range(n_requests)])
    ref_fn = make_cnn_pipeline(spec, mnf=False, donate=False)

    it = iter(frames)
    for _ in range(args.ticks):
        for _ in range(args.rate):
            eng.submit(next(it))
        eng.run_tick()
    stats = eng.stats()
    assert len(eng.completed) == n_requests, "queue did not drain"

    ref = np.asarray(ref_fn(params, frames))
    for i, req in enumerate(eng.completed):
        assert np.allclose(req.result, ref[req.rid], atol=5e-3, rtol=5e-3), \
            f"request {req.rid} diverged from the dense oracle"
        if i < args.rate:
            print(f"req {req.rid}: bucket {req.bucket} "
                  f"latency {req.latency_s * 1e3:.1f}ms "
                  f"pred={int(np.argmax(req.result))}")

    # price one frame's measured event stream on the paper's accelerator
    _, layer_stats = run_with_stats(params, frames[:1], spec)
    row = table4_row(layer_stats, w_density=1 - args.weight_sparsity)
    cyc = network_cycles(layer_stats, "mnf", d_w=1 - args.weight_sparsity)
    dense_macs = sum(s["dense_macs"] for s in layer_stats)
    event_macs = sum(s["event_macs"] for s in layer_stats)
    print(f"\nserved {stats['requests']} frames at "
          f"{stats['requests_s']:.1f} req/s "
          f"(p50 {stats['p50_ms']:.1f}ms, p99 {stats['p99_ms']:.1f}ms, "
          f"{stats['recompiles']} compiles, all at warmup)")
    print(f"event/dense MAC ratio: {event_macs / dense_macs:.3f}")
    print(f"modeled on MNF ASIC (Table 3 hw): {row['frames_s']:.1f} frames/s,"
          f" {row['power_mw']:.1f} mW, {row['frames_j']:.1f} frames/J "
          f"({cyc:,.0f} cycles/frame)")


if __name__ == "__main__":
    main()
