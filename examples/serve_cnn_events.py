"""End-to-end driver (the paper's kind: INFERENCE): event-driven CNN serving.

Serves batched image requests through AlexNet with the MNF pipeline:
dense-equivalence checked per batch, per-layer event stats streamed to the
cost model, throughput/energy reported in the paper's units (frames/s,
frames/J).

    PYTHONPATH=src python examples/serve_cnn_events.py --batches 4 --size 64
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.costmodel import network_cycles, table4_row
from repro.data import cnn_batch
from repro.models.cnn import ALEXNET, VGG16, init_cnn_params, \
    make_cnn_pipeline, run_with_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", choices=("alexnet", "vgg16"), default="alexnet")
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--weight-sparsity", type=float, default=0.5)
    ap.add_argument("--act-sparsity", type=float, default=0.6)
    args = ap.parse_args()

    spec = (ALEXNET if args.net == "alexnet" else VGG16).scaled(args.size)
    params = init_cnn_params(jax.random.PRNGKey(0), spec,
                             weight_sparsity=args.weight_sparsity)
    # One compiled oracle per network (DESIGN.md §5.1); the MNF path is the
    # single-jit instrumented pipeline inside run_with_stats.
    ref_fn = make_cnn_pipeline(spec, mnf=False, donate=False)

    total_events = total_dense = total_event_macs = 0.0
    t0 = time.time()
    for step in range(args.batches):
        x = cnn_batch(args.batch, args.size, spec.in_ch, step,
                      activation_sparsity=args.act_sparsity)
        logits, stats = run_with_stats(params, x, spec)
        ref = ref_fn(params, x)
        assert np.allclose(np.asarray(logits), np.asarray(ref), atol=5e-3,
                           rtol=5e-3), "event path diverged from dense!"
        preds = np.argmax(np.asarray(logits), -1)
        total_events += sum(s["in_events"] for s in stats)
        total_dense += sum(s["dense_macs"] for s in stats)
        total_event_macs += sum(s["event_macs"] for s in stats)
        print(f"batch {step}: preds={preds.tolist()}  "
              f"mac_reduction={sum(s['dense_macs'] for s in stats) / max(sum(s['event_macs'] for s in stats), 1):.2f}x")
    wall = time.time() - t0

    # price the measured event stream on the paper's accelerator
    _, stats = run_with_stats(
        params, cnn_batch(1, args.size, spec.in_ch, 0,
                          activation_sparsity=args.act_sparsity), spec)
    row = table4_row(stats, w_density=1 - args.weight_sparsity)
    cyc = network_cycles(stats, "mnf", d_w=1 - args.weight_sparsity)
    print(f"\nserved {args.batches * args.batch} frames in {wall:.1f}s "
          f"(CPU reference path)")
    print(f"event/dense MAC ratio: {total_event_macs / total_dense:.3f}")
    print(f"modeled on MNF ASIC (Table 3 hw): {row['frames_s']:.1f} frames/s,"
          f" {row['power_mw']:.1f} mW, {row['frames_j']:.1f} frames/J "
          f"({cyc:,.0f} cycles/frame)")


if __name__ == "__main__":
    main()
