"""Batched LM serving: prefill + KV-cache decode loop.

    python examples/serve_lm_decode.py --arch hymba-1.5b

Runs ``repro.launch.serve`` *in-process* (import + call) instead of
re-exec'ing a child interpreter: a ``subprocess`` re-exec silently depended
on PYTHONPATH=src reaching the child's environment — from a clean
environment (cron, CI, a bare shell) the child could not import ``repro``
at all.  The launcher now makes itself runnable from anywhere by putting
the repo's src directory on ``sys.path`` before importing.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")


def main(argv=None):
    if _SRC not in sys.path:
        sys.path.insert(0, _SRC)
    from repro.launch import serve

    args = list(sys.argv[1:] if argv is None else argv)
    if "--arch" not in args:
        args = ["--arch", "qwen2-0.5b"] + args
    argv_full = ["serve_lm_decode", "--reduced", "--batch", "4",
                 "--prompt-len", "32", "--gen", "16"] + args
    old_argv = sys.argv
    sys.argv = argv_full
    try:
        serve.main()
    finally:
        sys.argv = old_argv


if __name__ == "__main__":
    main()
