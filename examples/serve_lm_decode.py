"""Batched LM serving: prefill + KV-cache decode loop.

    PYTHONPATH=src python examples/serve_lm_decode.py --arch hymba-1.5b
"""
import subprocess
import sys


def main():
    args = sys.argv[1:]
    if "--arch" not in args:
        args = ["--arch", "qwen2-0.5b"] + args
    cmd = [sys.executable, "-m", "repro.launch.serve", "--reduced",
           "--batch", "4", "--prompt-len", "32", "--gen", "16"] + args
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
