"""Train a reduced LM on the synthetic Markov corpus with the resilient loop.

Demonstrates the full training substrate: config -> sharded step ->
fault-tolerant loop (async checkpoints, straggler detection, auto-resume) ->
loss decreasing on a learnable synthetic language.  Interrupt it (Ctrl-C)
and rerun: it resumes from the last checkpoint.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import subprocess
import sys


def main():
    args = sys.argv[1:] or ["--steps", "200"]
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen2-0.5b", "--reduced",
           "--batch", "8", "--seq", "128",
           "--ckpt-dir", "/tmp/repro_train_example"] + args
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
