"""Quickstart: the Multiply-and-Fire pipeline in five minutes (CPU).

1. Build a sparse activation map, encode it as block events (the paper's
   compressed storage scheme, TPU-tiled).
2. Run the multiply phase through the unified engine API (`repro.engine`) —
   one `EngineConfig` picks the backend — and verify it equals the dense
   oracle.
3. Run the fire phase: `engine.fire` returns an `EventStream` that feeds the
   second layer directly (no decode→re-encode between layers).
4. Price the whole thing with the paper-calibrated cost model.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import encode_block_events
from repro.costmodel import compare_dataflows, ConvShape, mnf_layer_cycles

rng = np.random.default_rng(0)

# --- a sparse activation matrix (post-ReLU, like a deep CNN layer).
# Block events live at VMEM-tile granularity, so *channel-structured*
# sparsity (whole channel groups silent — what ReLU on correlated features
# produces) is what the TPU adaptation rides; fully unstructured sparsity
# needs the scalar-event CNN path or higher rates.
m, k, n = 64, 1024, 512
acts = rng.normal(size=(m, k)).astype(np.float32)
acts *= rng.random((1, k // 128, 1)).repeat(128, 1).reshape(1, k) > 0.6
acts *= rng.random((m, k)) > 0.3
acts = np.abs(acts)
w1 = (rng.normal(size=(k, n)) / np.sqrt(k)).astype(np.float32)
w2 = (rng.normal(size=(n, n)) / np.sqrt(n)).astype(np.float32)

# --- event encoding: how many weight tiles does MNF even touch? ---
ev = encode_block_events(jnp.asarray(acts), blk_m=8, blk_k=128)
live = float(ev.counts.sum()) / (ev.block_idx.shape[0] * ev.num_k_blocks)
print(f"activation density {np.mean(acts != 0):.2f} -> "
      f"{live:.2f} of weight tiles are event-addressed "
      f"({1 - live:.0%} of DMAs + MXU work skipped)")

# --- the engine: one config, every backend ---
# backend="auto" resolves to the Pallas kernels on TPU and the pure-jnp
# block-event path on CPU; force backend="pallas" to exercise the kernel in
# interpret mode anywhere.
cfg = engine.EngineConfig(backend="pallas", blk_m=8, blk_k=128, blk_n=128)
print("engine:", engine.describe(cfg))

# --- multiply phase via the engine ---
y = engine.linear(jnp.asarray(acts), jnp.asarray(w1), cfg=cfg)
dense = acts @ w1
print("multiply phase == dense:", np.allclose(y, dense, atol=1e-3))

# --- fire phase: threshold + events for the next layer, *chained* ---
stream = engine.fire(y, cfg)
print(f"fired {float((np.asarray(stream.dense()) > 0).mean()):.2f} of outputs "
      f"to layer 2 ({int(stream.num_events)} block events, "
      f"occupancy {float(stream.occupancy()):.2f})")
# the EventStream feeds layer 2's multiply phase directly — activations stay
# compressed between layers (the paper's end-to-end event dataflow)
y2 = engine.linear(stream.without_dense(), jnp.asarray(w2), cfg=cfg)
print("layer-2 output:", y2.shape)

# --- what does this cost on the paper's accelerator? ---
shape = ConvShape(in_ch=256, out_ch=384, in_size=56, out_size=56, k=3)
for d in (1.0, 0.3, 0.1):
    e = compare_dataflows(shape, d_act=d, d_w=0.6)
    print(f"density {d:.1f}: energy/layer  "
          + "  ".join(f"{kk}={vv/1e6:.1f}uJ" for kk, vv in e.items()))
cyc = mnf_layer_cycles(n_events=float((acts != 0).sum()), avg_touched=9,
                       c_out=n)
print(f"MNF multiply-phase cycles for this layer: {cyc:,.0f} "
      f"(@200 MHz = {cyc/200e3:.2f} ms)")
