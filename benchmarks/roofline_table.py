"""§Roofline summary from dry-run artifacts (results/dryrun/*.json)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def rows():
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if rec.get("tag"):
            name += f"_{rec['tag']}"
        if rec["status"] == "skipped":
            out.append((name, 0.0, f"skipped:{rec['reason'][:60]}"))
            continue
        if rec["status"] != "ok":
            out.append((name, 0.0, f"ERROR:{rec.get('error','')[:80]}"))
            continue
        r = rec["roofline"]
        us = (rec.get("lower_s", 0) + rec.get("compile_s", 0)) * 1e6
        out.append((name, us,
                    f"bottleneck={r['bottleneck']};"
                    f"t_comp={r['t_compute']*1e3:.1f}ms;"
                    f"t_mem={r['t_memory']*1e3:.1f}ms;"
                    f"t_coll={r['t_collective']*1e3:.1f}ms;"
                    f"roofline_frac={r['roofline_frac']:.3f};"
                    f"useful={r['useful_ratio']:.2f};"
                    f"dev_gib={r['bytes_per_device']/2**30:.2f}"))
    if not out:
        out.append(("roofline_table", 0.0,
                    "no dry-run artifacts; run python -m repro.launch.dryrun --all"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
