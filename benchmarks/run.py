"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Run:
    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (fig1_dataflow_energy, fig2_utilization, fig8_cycles,
                        kernel_bench, roofline_table, table4_comparison,
                        table5_memory_energy)

MODULES = (
    ("fig1", fig1_dataflow_energy),
    ("fig2", fig2_utilization),
    ("fig8", fig8_cycles),
    ("table4", table4_comparison),
    ("table5", table5_memory_energy),
    ("kernels", kernel_bench),
    ("roofline", roofline_table),
)


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for tag, mod in MODULES:
        try:
            for name, us, derived in mod.rows():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:   # keep the harness running; count failures
            failures += 1
            print(f"{tag}_FAILED,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
