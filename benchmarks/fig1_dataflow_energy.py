"""Fig. 1 — energy of WS/IS/OS vs MNF event-driven on Table 1 layers."""
from __future__ import annotations

import time

from repro.costmodel import TABLE1, compare_dataflows


def rows():
    out = []
    for lname, shape in TABLE1.items():
        for d_act in (1.0, 0.6, 0.3, 0.1):
            t0 = time.perf_counter()
            e = compare_dataflows(shape, d_act, d_w=0.6)
            us = (time.perf_counter() - t0) * 1e6
            best = min(e, key=e.get)
            derived = (f"d_act={d_act};uJ_ws={e['ws']/1e6:.1f};"
                       f"uJ_is={e['inp']/1e6:.1f};uJ_os={e['os']/1e6:.1f};"
                       f"uJ_mnf={e['mnf']/1e6:.1f};best={best};"
                       f"mnf_vs_best_other={min(e['ws'], e['inp'], e['os'])/e['mnf']:.2f}x")
            out.append((f"fig1_{lname}_d{d_act}", us, derived))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
