"""Fig. 8 — cycle counts: MNF vs SCNN-Dense / SCNN / SparTen / GoSPA."""
from __future__ import annotations

import time

from repro.costmodel import network_cycles
from repro.costmodel.table4 import (ALEXNET_DENSITY_PROFILE,
                                    VGG16_DENSITY_PROFILE)
from repro.costmodel.workloads import analytic_network_stats
from repro.models.cnn import ALEXNET, VGG16

PAPER_RATIOS = {
    "vgg16": dict(scnn_dense=19.0, scnn=8.31, sparten=3.15, gospa=2.57),
    "alexnet": dict(scnn_dense=11.82, scnn=7.32, sparten=3.51, gospa=2.68),
}
W_DENSITY = {"vgg16": 0.596, "alexnet": 0.499}   # paper §6.1 pruned nets


def rows():
    out = []
    for name, spec, prof in (("vgg16", VGG16, VGG16_DENSITY_PROFILE),
                             ("alexnet", ALEXNET, ALEXNET_DENSITY_PROFILE)):
        t0 = time.perf_counter()
        stats = analytic_network_stats(spec, prof)
        mnf = network_cycles(stats, "mnf", d_w=W_DENSITY[name])
        us = (time.perf_counter() - t0) * 1e6
        for design in ("scnn_dense", "scnn", "sparten", "gospa"):
            cyc = network_cycles(stats, design, d_w=W_DENSITY[name])
            ratio = cyc / mnf
            paper = PAPER_RATIOS[name][design]
            out.append((f"fig8_{name}_{design}", us,
                        f"mnf_cycles={mnf:.3g};{design}_cycles={cyc:.3g};"
                        f"speedup={ratio:.2f}x;paper={paper}x;"
                        f"rel_err={abs(ratio-paper)/paper:.2f}"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
