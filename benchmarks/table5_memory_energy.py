"""Table 5 — per-access energy inputs (identity check of the model inputs)
and the resulting per-MAC energy of each dataflow at the paper's operating
point."""
from __future__ import annotations

import time

from repro.costmodel import TABLE1, TABLE5_MNF, TABLE5_OTHERS, compare_dataflows


def rows():
    out = []
    e, em = TABLE5_OTHERS, TABLE5_MNF
    out.append(("table5_dram_pj", 0.0,
                f"others={e.dram_pj}@{e.dram_bits}b;mnf={em.dram_pj}@{em.dram_bits}b"))
    out.append(("table5_sram_pj", 0.0,
                f"others={e.sram_pj}@{e.sram_bits}b;mnf={em.sram_pj}@{em.sram_bits}b"))
    out.append(("table5_buf_pj", 0.0,
                f"others={e.buf_pj}@{e.buf_bits}b;mnf={em.buf_pj}@{em.buf_bits}b"))
    out.append(("table5_reg_pj", 0.0,
                f"others={e.reg_pj}x3;mnf={em.reg_pj}x3"))
    t0 = time.perf_counter()
    eng = compare_dataflows(TABLE1["layer1"], 0.3, 0.6)
    us = (time.perf_counter() - t0) * 1e6
    macs = TABLE1["layer1"].macs
    for k, v in eng.items():
        out.append((f"table5_pj_per_dense_mac_{k}", us, f"{v/macs:.3f}pJ"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
