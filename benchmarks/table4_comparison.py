"""Table 4 — frames/s, power, frames/J for MNF on VGG16/AlexNet."""
from __future__ import annotations

import time

from repro.costmodel import PAPER_TABLE4, table4_row
from repro.costmodel.table4 import (ALEXNET_DENSITY_PROFILE,
                                    ALEXNET_W_DENSITY,
                                    VGG16_DENSITY_PROFILE, VGG16_W_DENSITY)
from repro.costmodel.workloads import analytic_network_stats
from repro.models.cnn import ALEXNET, VGG16


def rows():
    out = []
    for name, spec, prof, wd in (
            ("vgg16", VGG16, VGG16_DENSITY_PROFILE, VGG16_W_DENSITY),
            ("alexnet", ALEXNET, ALEXNET_DENSITY_PROFILE, ALEXNET_W_DENSITY)):
        t0 = time.perf_counter()
        r = table4_row(analytic_network_stats(spec, prof), w_density=wd)
        us = (time.perf_counter() - t0) * 1e6
        p = PAPER_TABLE4[name]
        out.append((f"table4_{name}", us,
                    f"frames_s={r['frames_s']:.1f}(paper {p['frames_s']});"
                    f"power_mw={r['power_mw']:.1f}(paper {p['power_mw']});"
                    f"frames_j={r['frames_j']:.1f}(paper {p['frames_j']})"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
