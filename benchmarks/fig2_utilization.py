"""Fig. 2 — multiplier utilization vs activation density: MNF vs SNAP."""
from __future__ import annotations

import time

from repro.costmodel import utilization_sweep


def rows():
    t0 = time.perf_counter()
    sweep = utilization_sweep()
    us = (time.perf_counter() - t0) * 1e6 / len(sweep)
    out = []
    for r in sweep:
        out.append((f"fig2_util_d{r['density']}", us,
                    f"mnf={r['mnf']:.3f};snap={r['snap']:.3f}"))
    mnf_min = min(r["mnf"] for r in sweep)
    out.append(("fig2_mnf_flatness", us,
                f"min_mnf_util={mnf_min:.3f};paper_claim=~1.0_at_all_densities"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
