"""Kernel microbenchmarks: event_matmul / fire_compact / wkv6 — plus engine
backend-comparison and CNN chained-pipeline modes.

Wall-times are interpret-mode on CPU (correctness harness, not TPU perf);
the derived columns carry the *structural* quantities that transfer to TPU:
fraction of weight-tile DMAs skipped (== event sparsity the kernel rides),
per-boundary decode counts, and the ref/kernel agreement.

Every jitted path is warmed before timing: the first call's wall-time is
recorded separately as ``compile_us`` (trace+compile dominated) and the
steady-state ``us`` is averaged over post-warm reps — compile time never
pollutes the trajectory numbers.

``--engine`` sweeps every registered ``EngineConfig.backend`` of
``engine.linear`` over a sparsity grid and compares the chained
(fire → EventStream → linear) path against the decode→re-encode round-trip.
``--cnn-chain`` times the event-resident CNN pipeline (one jit per network,
conv streams chained end-to-end) against the per-layer round-trip twin and
records where each path densifies.  Both write/merge BENCH_engine.json.
``--smoke`` runs a fast subset of everything (CI anti-rot).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.kernels import (event_matmul, event_matmul_ref, fire_compact,
                           fire_compact_ref, wkv6, wkv6_ref)


def _time_thunk(fn, reps=3):
    """(steady_us, compile_us, out): first call timed apart as compile."""
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    compile_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, compile_us, out


def _timeit(fn, *args, reps=3, **kw):
    return _time_thunk(lambda: fn(*args, **kw), reps=reps)


def rows(reps=3):
    rng = np.random.default_rng(0)
    out = []
    for sparsity in (0.0, 0.7, 0.95):
        m, k, n = 64, 1024, 512
        a = rng.normal(size=(m, k)).astype(np.float32)
        a *= rng.random((m, k)) > sparsity
        w = rng.normal(size=(k, n)).astype(np.float32)
        us, cus, y = _timeit(event_matmul, jnp.asarray(a), jnp.asarray(w),
                             blk_m=8, blk_k=128, interpret=True, reps=reps)
        yr = event_matmul_ref(jnp.asarray(a), jnp.asarray(w), blk_m=8,
                              blk_k=128)
        live = np.abs(a.reshape(8, 8, 8, 128)).max(axis=(1, 3)) > 0
        out.append((f"event_matmul_s{sparsity}", us, cus,
                    f"tiles_skipped={1-live.mean():.2f};"
                    f"allclose={np.allclose(y, yr, atol=1e-4)}"))
    acc = jnp.asarray(rng.normal(size=(128, 1024)), jnp.float32)
    us, cus, (f, occ) = _timeit(fire_compact, acc, blk_m=8, blk_k=128,
                                interpret=True, reps=reps)
    fr, occr = fire_compact_ref(acc, blk_m=8, blk_k=128)
    out.append(("fire_compact", us, cus,
                f"allclose={np.allclose(f, fr)};"
                f"occ_match={np.array_equal(np.asarray(occ), np.asarray(occr))}"))
    b, h, t, d = 2, 2, 64, 32
    r, k2, v = (jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
                for _ in range(3))
    w6 = jnp.asarray(rng.uniform(0.3, 0.99, (b, h, t, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    us, cus, (o, s) = _timeit(wkv6, r, k2, v, w6, u, chunk=16,
                              interpret=True, reps=reps)
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k2, v, w6, u)
    out.append(("wkv6_chunked", us, cus,
                f"allclose={np.allclose(o, orf, atol=1e-4)};"
                f"state_ok={np.allclose(s, srf, atol=1e-4)}"))
    return out


def _merge_bench(out_path: str, entries, drop_kinds: set):
    """Read-modify-write BENCH_engine.json: each mode owns its entry kinds."""
    payload = dict(device=jax.default_backend(),
                   note="CPU interpret-mode wall-times; structural columns "
                        "(allclose, events, bit_exact, boundaries) are what "
                        "transfers; compile_us is trace+compile, us is "
                        "steady-state",
                   entries=[])
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                prev = json.load(f)
            payload["entries"] = [e for e in prev.get("entries", [])
                                  if e.get("kind") not in drop_kinds]
        except (json.JSONDecodeError, OSError):
            pass
    payload["entries"].extend(entries)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)


def engine_rows(out_path: str = "BENCH_engine.json", reps=3):
    """Backend comparison through the unified engine API.

    Every backend must agree with the dense oracle at threshold 0 — the
    sweep records that check alongside wall-time, then times the chained
    EventStream path vs the dense round-trip between two layers.
    """
    rng = np.random.default_rng(0)
    m, k, n = 32, 256, 128
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    entries = []
    for sparsity in (0.0, 0.7, 0.95):
        a = rng.normal(size=(m, k)).astype(np.float32)
        a *= rng.random((m, k)) > sparsity
        aj = jnp.asarray(a)
        ref = a @ np.asarray(w)
        for name in engine.list_backends("linear"):
            cfg = engine.EngineConfig(backend=name, blk_m=8, blk_k=32,
                                      blk_n=32)
            us, cus, y = _time_thunk(
                lambda: engine.linear(aj, w, cfg=cfg), reps=reps)
            entries.append(dict(
                kind="linear", backend=name, sparsity=sparsity,
                m=m, k=k, n=n, us=round(us, 1), compile_us=round(cus, 1),
                allclose=bool(np.allclose(np.asarray(y), ref, atol=2e-3))))

    # chained vs round-trip: layer1 -> fire -> layer2
    w2 = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    a = rng.normal(size=(m, k)).astype(np.float32)
    a *= rng.random((m, k)) > 0.7
    aj = jnp.asarray(a)
    for name in engine.list_backends("linear_events"):
        cfg = engine.EngineConfig(backend=name, blk_m=8, blk_k=32, blk_n=32)
        acc = engine.linear(aj, w, cfg=cfg)
        stream = engine.fire(acc, cfg)

        def chained():
            return engine.linear(stream.without_dense(), w2, cfg=cfg)

        def roundtrip():
            return engine.linear(stream.dense(), w2, cfg=cfg)

        us_c, cus_c, yc = _time_thunk(chained, reps=reps)
        us_r, cus_r, yr = _time_thunk(roundtrip, reps=reps)
        entries.append(dict(
            kind="chained_vs_roundtrip", backend=name,
            events=int(stream.num_events), occupancy=float(stream.occupancy()),
            chained_us=round(us_c, 1), roundtrip_us=round(us_r, 1),
            chained_compile_us=round(cus_c, 1),
            roundtrip_compile_us=round(cus_r, 1),
            speedup=round(us_r / max(us_c, 1e-9), 3),
            bit_exact=bool(jnp.all(yc == yr))))
    _merge_bench(out_path, entries, {"linear", "chained_vs_roundtrip"})
    return entries


def _smoke_spec():
    """Tiny 2-conv + pool + FC net: exercises every chain seam in seconds."""
    from repro.models.cnn import CNNSpec, ConvSpec, FCSpec, PoolSpec
    return CNNSpec("mini", 8, 3,
                   (ConvSpec(8, 3, 1, 1), ConvSpec(8, 3, 1, 1), PoolSpec(),
                    FCSpec(10)))


def cnn_chain_rows(out_path: str = "BENCH_engine.json", *, smoke=False,
                   batch=2, reps=3):
    """Event-resident CNN pipeline vs per-layer round-trip (one jit each).

    Chained and round-trip paths use identical compute geometry
    (pixel-granular conv tiles) so logits are bit-exact; the difference is
    purely the inter-layer format: events stay resident across conv
    boundaries vs a dense materialize + re-encode at every boundary.
    ``boundaries`` records where each compiled graph densifies.
    """
    from repro.models.cnn import (ALEXNET, ConvSpec, FCSpec, PoolSpec,
                                  cnn_forward, init_cnn_params,
                                  make_cnn_pipeline)

    nets = [(_smoke_spec(), 8)] if smoke else [(ALEXNET, 64)]
    entries = []
    for spec, size in nets:
        spec = spec.scaled(size)
        n_conv = sum(isinstance(l, ConvSpec) for l in spec.layers)
        n_fc = sum(isinstance(l, FCSpec) for l in spec.layers)
        n_pool = sum(isinstance(l, PoolSpec) for l in spec.layers)
        params = init_cnn_params(jax.random.PRNGKey(0), spec,
                                 weight_sparsity=0.5)
        x = jax.nn.relu(jax.random.normal(
            jax.random.PRNGKey(1), (batch, size, size, spec.in_ch)))

        # Structural accounting: abstract-trace one forward per mode
        # (records fire at trace time — eval_shape runs no numeric work).
        counts = {}
        for mode, chain in (("chained", True), ("roundtrip", False)):
            with engine.trace_dispatch() as recs:
                jax.eval_shape(
                    lambda p, xx, chain=chain: cnn_forward(
                        p, xx, spec, mnf=True, chain=chain), params, x)
            counts[mode] = dict(
                events_only_boundaries=sum(
                    1 for r in recs if r.get("chained")),
                decodes=sum(1 for r in recs if r.get("decode")),
                fallback_decodes=sum(
                    1 for r in recs if r.get("fallback_decode")))

        fns = {mode: make_cnn_pipeline(spec, mnf=True, chain=chain,
                                       donate=False)
               for mode, chain in (("chained", True), ("roundtrip", False))}
        # Compile each once (compile_us), then time the two pipelines in
        # interleaved rounds and keep the per-mode minimum: back-to-back
        # rep loops right after compilation catch allocator/scheduler
        # transients on share-capped CPUs and can swing 2-3x.
        compile_us, best, out = {}, {}, {}
        for mode, fn in fns.items():
            t0 = time.perf_counter()
            out[mode] = fn(params, x)
            jax.block_until_ready(out[mode])
            compile_us[mode] = (time.perf_counter() - t0) * 1e6
            best[mode] = float("inf")
        for _ in range(max(reps, 3)):
            for mode, fn in fns.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn(params, x))
                best[mode] = min(best[mode],
                                 (time.perf_counter() - t0) * 1e6)
        us_c, cus_c, yc = best["chained"], compile_us["chained"], \
            out["chained"]
        us_r, cus_r, yr = best["roundtrip"], compile_us["roundtrip"], \
            out["roundtrip"]
        entries.append(dict(
            kind="cnn_chain", net=spec.name, input_size=size, batch=batch,
            chained_us=round(us_c, 1), roundtrip_us=round(us_r, 1),
            chained_compile_us=round(cus_c, 1),
            roundtrip_compile_us=round(cus_r, 1),
            speedup=round(us_r / max(us_c, 1e-9), 3),
            bit_exact=bool(jnp.all(yc == yr)),
            boundaries=dict(
                conv=n_conv, fc=n_fc, pool=n_pool,
                # chained: only pool boundaries densify (cached twin + the
                # permitted re-encode); roundtrip: every boundary is dense.
                chained=dict(densify=n_pool, **counts["chained"]),
                roundtrip=dict(densify=n_conv + n_fc + n_pool - 1,
                               **counts["roundtrip"]))))
    _merge_bench(out_path, entries, {"cnn_chain"})
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="sweep EngineConfig.backend and write "
                         "BENCH_engine.json")
    ap.add_argument("--cnn-chain", action="store_true",
                    help="time the event-resident CNN pipeline vs the "
                         "per-layer round-trip (cnn_chain entries)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: 1-rep kernel microbench + engine "
                         "sweep + mini-net cnn chain — keeps every "
                         "benchmark path from rotting")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args()
    if args.smoke:
        for name, us, compile_us, derived in rows(reps=1):
            print(f"{name},{us:.1f},compile={compile_us:.1f},{derived}")
        for e in engine_rows(args.out, reps=1):
            print(json.dumps(e))
        for e in cnn_chain_rows(args.out, smoke=True, reps=1):
            print(json.dumps(e))
        return
    if args.engine:
        for e in engine_rows(args.out):
            print(json.dumps(e))
    if args.cnn_chain:
        for e in cnn_chain_rows(args.out):
            print(json.dumps(e))
    if args.engine or args.cnn_chain:
        return
    for name, us, compile_us, derived in rows():
        print(f"{name},{us:.1f},compile={compile_us:.1f},{derived}")


if __name__ == "__main__":
    main()
