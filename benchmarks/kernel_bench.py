"""Kernel microbenchmarks: event_matmul / fire_compact / wkv6 — plus engine
backend-comparison and CNN chained-pipeline modes.

Wall-times are interpret-mode on CPU (correctness harness, not TPU perf);
the derived columns carry the *structural* quantities that transfer to TPU:
fraction of weight-tile DMAs skipped (== event sparsity the kernel rides),
per-boundary decode counts, and the ref/kernel agreement.

Every jitted path is warmed before timing: the first call's wall-time is
recorded separately as ``compile_us`` (trace+compile dominated) and the
steady-state ``us`` is averaged over post-warm reps — compile time never
pollutes the trajectory numbers.

``--engine`` sweeps every registered ``EngineConfig.backend`` of
``engine.linear`` over a sparsity grid and compares the chained
(fire → EventStream → linear) path against the decode→re-encode round-trip.
``--cnn-chain`` times the event-resident CNN pipeline (one jit per network,
conv streams chained end-to-end) against the per-layer round-trip twin and
records where each path densifies plus per-conv-layer launch counts (taps
fused vs per-tap).  ``--conv-fused`` times the fused strip-tiled conv
kernel (one launch per layer, 8x smaller event grid) against the per-tap
chained path at matched shapes — both stride-1 and stride-2 downsampling
geometries (the interleaved half-strip plan).  ``--pool`` times the
event-native max-pool (segment max over stream events, one launch) against
the dense pool + re-encode round-trip.  ``--serve`` benchmarks the bucketed
AOT-warmed serving replica (``repro.serving``): requests/s and p50/p99 per
batch bucket, cold vs persistent-cache-warmed compile, and replica
time-to-first-response.  ``--sweep`` runs the occupancy sweep 0→1 over
conv/pool/linear boundaries: every route timed per point (``crossover``
entries — the calibrated table ``route="adaptive"`` dispatch consults,
DESIGN.md §11) and the adaptive router re-timed end-to-end against the
best static route (``adaptive`` entries).  All write/merge
BENCH_engine.json.
``--smoke`` runs a fast subset of everything (CI anti-rot) — including a
downsampling mini-net whose stride-2 layer must ride the fused strip
path — and **fails** if an eligible strip layer (either stride) or pool
boundary falls back to a decode (fallback_decode) — the silent-degrade
bug class — or if any adaptive routing decision contradicts the
committed crossover table beyond the hysteresis band (``route_gate``).
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.kernels import (event_matmul, event_matmul_ref, fire_compact,
                           fire_compact_ref, wkv6, wkv6_ref)


def _time_thunk(fn, reps=3):
    """(steady_us, compile_us, out): first call timed apart as compile."""
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    compile_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, compile_us, out


def _timeit(fn, *args, reps=3, **kw):
    return _time_thunk(lambda: fn(*args, **kw), reps=reps)


def rows(reps=3):
    rng = np.random.default_rng(0)
    out = []
    for sparsity in (0.0, 0.7, 0.95):
        m, k, n = 64, 1024, 512
        a = rng.normal(size=(m, k)).astype(np.float32)
        a *= rng.random((m, k)) > sparsity
        w = rng.normal(size=(k, n)).astype(np.float32)
        us, cus, y = _timeit(event_matmul, jnp.asarray(a), jnp.asarray(w),
                             blk_m=8, blk_k=128, interpret=True, reps=reps)
        yr = event_matmul_ref(jnp.asarray(a), jnp.asarray(w), blk_m=8,
                              blk_k=128)
        live = np.abs(a.reshape(8, 8, 8, 128)).max(axis=(1, 3)) > 0
        out.append((f"event_matmul_s{sparsity}", us, cus,
                    f"tiles_skipped={1-live.mean():.2f};"
                    f"allclose={np.allclose(y, yr, atol=1e-4)}"))
    acc = jnp.asarray(rng.normal(size=(128, 1024)), jnp.float32)
    us, cus, (f, occ) = _timeit(fire_compact, acc, blk_m=8, blk_k=128,
                                interpret=True, reps=reps)
    fr, occr = fire_compact_ref(acc, blk_m=8, blk_k=128)
    out.append(("fire_compact", us, cus,
                f"allclose={np.allclose(f, fr)};"
                f"occ_match={np.array_equal(np.asarray(occ), np.asarray(occr))}"))
    b, h, t, d = 2, 2, 64, 32
    r, k2, v = (jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
                for _ in range(3))
    w6 = jnp.asarray(rng.uniform(0.3, 0.99, (b, h, t, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    us, cus, (o, s) = _timeit(wkv6, r, k2, v, w6, u, chunk=16,
                              interpret=True, reps=reps)
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k2, v, w6, u)
    out.append(("wkv6_chunked", us, cus,
                f"allclose={np.allclose(o, orf, atol=1e-4)};"
                f"state_ok={np.allclose(s, srf, atol=1e-4)}"))
    return out


def _merge_bench(out_path: str, entries, drop_kinds: set):
    """Read-modify-write BENCH_engine.json: each mode owns its entry kinds."""
    payload = dict(device=jax.default_backend(),
                   note="CPU interpret-mode wall-times; structural columns "
                        "(allclose, events, bit_exact, boundaries) are what "
                        "transfers; compile_us is trace+compile, us is "
                        "steady-state",
                   entries=[])
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                prev = json.load(f)
            payload["entries"] = [e for e in prev.get("entries", [])
                                  if e.get("kind") not in drop_kinds]
        except (json.JSONDecodeError, OSError):
            pass
    payload["entries"].extend(entries)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)


def engine_rows(out_path: str = "BENCH_engine.json", reps=3):
    """Backend comparison through the unified engine API.

    Every backend must agree with the dense oracle at threshold 0 — the
    sweep records that check alongside wall-time, then times the chained
    EventStream path vs the dense round-trip between two layers.
    """
    rng = np.random.default_rng(0)
    m, k, n = 32, 256, 128
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    entries = []
    for sparsity in (0.0, 0.7, 0.95):
        a = rng.normal(size=(m, k)).astype(np.float32)
        a *= rng.random((m, k)) > sparsity
        aj = jnp.asarray(a)
        ref = a @ np.asarray(w)
        for name in engine.list_backends("linear"):
            cfg = engine.EngineConfig(backend=name, blk_m=8, blk_k=32,
                                      blk_n=32)
            us, cus, y = _time_thunk(
                lambda: engine.linear(aj, w, cfg=cfg), reps=reps)
            entries.append(dict(
                kind="linear", backend=name, sparsity=sparsity,
                m=m, k=k, n=n, us=round(us, 1), compile_us=round(cus, 1),
                allclose=bool(np.allclose(np.asarray(y), ref, atol=2e-3))))

    # chained vs round-trip: layer1 -> fire -> layer2
    w2 = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    a = rng.normal(size=(m, k)).astype(np.float32)
    a *= rng.random((m, k)) > 0.7
    aj = jnp.asarray(a)
    for name in engine.list_backends("linear_events"):
        cfg = engine.EngineConfig(backend=name, blk_m=8, blk_k=32, blk_n=32)
        acc = engine.linear(aj, w, cfg=cfg)
        stream = engine.fire(acc, cfg)

        def chained():
            return engine.linear(stream.without_dense(), w2, cfg=cfg)

        def roundtrip():
            return engine.linear(stream.dense(), w2, cfg=cfg)

        us_c, cus_c, yc = _time_thunk(chained, reps=reps)
        us_r, cus_r, yr = _time_thunk(roundtrip, reps=reps)
        entries.append(dict(
            kind="chained_vs_roundtrip", backend=name,
            events=int(stream.num_events), occupancy=float(stream.occupancy()),
            chained_us=round(us_c, 1), roundtrip_us=round(us_r, 1),
            chained_compile_us=round(cus_c, 1),
            roundtrip_compile_us=round(cus_r, 1),
            speedup=round(us_r / max(us_c, 1e-9), 3),
            bit_exact=bool(jnp.all(yc == yr))))
    _merge_bench(out_path, entries, {"linear", "chained_vs_roundtrip"})
    return entries


def _smoke_spec():
    """Tiny conv→conv→pool→conv→FC net: exercises every chain seam —
    conv→conv, the event-native conv→pool→conv boundary, pool→FC — in
    seconds."""
    from repro.models.cnn import CNNSpec, ConvSpec, FCSpec, PoolSpec
    return CNNSpec("mini", 8, 3,
                   (ConvSpec(8, 3, 1, 1), ConvSpec(8, 3, 1, 1), PoolSpec(),
                    ConvSpec(8, 3, 1, 1), FCSpec(10)))


def _smoke_ds_spec():
    """Tiny downsampling net: a stride-2 strip-eligible conv between two
    stride-1 convs.  Its middle layer must ride the fused stride-2 strip
    path — if it reports fallback_decode the smoke run fails CI (the
    silent-degrade bug class, extended to downsampling convs)."""
    from repro.models.cnn import CNNSpec, ConvSpec, FCSpec
    return CNNSpec("mini_ds", 16, 3,
                   (ConvSpec(8, 3, 1, 1), ConvSpec(8, 3, 2, 1),
                    ConvSpec(8, 3, 1, 1), FCSpec(10)))


def pool_rows(out_path: str = "BENCH_engine.json", *, smoke=False, reps=3):
    """Event-native max-pool (one launch, events in → events out) vs the
    dense pool + re-encode round-trip at matched shapes (pool entries).

    Same stream in, same pooled stream out (bit-exact vs the dense
    ``reduce_window`` oracle): the difference is purely the inter-layer
    format — the event path never materializes the input feature map.
    CI-fatal if an eligible stream falls back to a decode instead of
    riding the segment-max kernel (fallback_decode — the silent-degrade
    bug class, now covering pool boundaries too).
    """
    from repro.kernels.event_pool import pool_plan

    rng = np.random.default_rng(0)
    shapes = [(2, 8, 16, 8, 2, 2, 1)]
    if not smoke:
        shapes += [(2, 16, 16, 16, 2, 2, 8), (1, 15, 15, 8, 3, 2, 1)]
    entries = []
    for (b, h, w0, c, k, s, bm_in) in shapes:
        x = rng.normal(size=(b, h, w0, c)).astype(np.float32)
        x *= rng.random(x.shape) > 0.5
        xd = jnp.maximum(jnp.asarray(x), 0.0)
        for backend in ("pallas",):
            cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=8)
            stream = engine.fire_conv(xd, cfg, blk_m=bm_in, keep_dense=False)
            with engine.trace_dispatch() as recs:
                jax.eval_shape(lambda st: engine.maxpool2d(st, k, s, cfg=cfg),
                               stream)
            if not any(r.get("pool_events") for r in recs) or \
                    any(r.get("fallback_decode") for r in recs):
                raise RuntimeError(
                    f"pool[{backend}]: eligible stream fell back instead of "
                    f"riding the event-native pool: {recs}")

            ev_fn = jax.jit(lambda st: engine.maxpool2d(st, k, s, cfg=cfg))
            dense_fn = jax.jit(lambda xx: engine.EventStream.encode_nhwc(
                engine.maxpool2d(xx, k, s, cfg=cfg), blk_k=cfg.blk_k,
                keep_dense=False))
            us_e, cus_e, ye = _time_thunk(lambda: ev_fn(stream), reps=reps)
            us_d, cus_d, yd = _time_thunk(lambda: dense_fn(xd), reps=reps)
            plan = pool_plan((b, h, w0, c), k, s,
                             nkb=stream.events.num_k_blocks)
            entries.append(dict(
                kind="pool", backend=backend, b=b, h=h, w=w0, c=c, k=k,
                stride=s, blk_m_in=bm_in,
                event_us=round(us_e, 1), dense_us=round(us_d, 1),
                event_compile_us=round(cus_e, 1),
                dense_compile_us=round(cus_d, 1),
                speedup=round(us_d / max(us_e, 1e-9), 3),
                bit_exact=bool(jnp.all(ye.dense_nhwc() == yd.dense_nhwc())),
                launches=plan["launches"], window_taps=plan["window_taps"],
                event_grid=plan["event_grid"],
                dense_reads=plan["dense_reads"]))
    _merge_bench(out_path, entries, {"pool"})
    return entries


def conv_fused_rows(out_path: str = "BENCH_engine.json", *, smoke=False,
                    reps=3):
    """Fused strip-tiled conv (one launch per layer) vs the per-tap chained
    path, matched shapes, per backend (conv_fused entries) — stride-1,
    stride-2 and stride-4 rows (the N-part interleaved straddle plan,
    k11s4 being the AlexNet conv1 class: 121 launches fused into 1).

    Same events in, same outputs (bit-exact): the difference is purely one
    fused launch over an 8x-smaller strip event grid vs k*k re-dispatches
    over per-tap gathered pixel grids.  Structural columns (event-grid
    reduction, launches, bit_exact) transfer to TPU; wall times are the
    CPU harness.  Only the pallas backend (the kernel under test) is
    timed — the block strip path is a correctness twin, pinned bitwise in
    tests/test_conv_strips.py, not a deployment path.  CI-fatal if an
    eligible strip layer (either stride) falls back (fallback_decode)
    instead of riding the fused path.
    """
    from repro.kernels.event_conv import fused_conv_plan

    rng = np.random.default_rng(0)
    # (B, H, W, CI, CO, k, padding, stride) — stride-2/4 rows are the
    # downsampling-conv classes the interleaved straddle plan covers; the
    # k11s4 row is AlexNet conv1's shape class (5 straddle parts,
    # 561/605 live subtaps after dead-part compaction).
    shapes = [(1, 8, 8, 8, 8, 3, 1, 1), (1, 8, 16, 8, 8, 3, 1, 2),
              (1, 8, 32, 8, 8, 3, 1, 4)]
    if not smoke:
        shapes += [(2, 16, 16, 8, 16, 3, 1, 1), (2, 9, 16, 8, 16, 5, 2, 2),
                   (1, 9, 16, 8, 8, 1, 0, 2), (1, 11, 32, 8, 8, 11, 4, 4)]
    entries = []
    for (b, h, w0, ci, co, k, p, st) in shapes:
        x = rng.normal(size=(b, h, w0, ci)).astype(np.float32)
        x *= rng.random(x.shape) > 0.5
        x = jnp.maximum(jnp.asarray(x), 0.0)
        wgt = jnp.asarray(rng.normal(size=(k, k, ci, co)).astype(np.float32))
        for backend in ("pallas",):
            cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=8,
                                      blk_n=8)
            strip = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W,
                                     keep_dense=False)
            pixel = engine.fire_conv(x, cfg, blk_m=1, keep_dense=False)

            fused_fn = jax.jit(lambda s: engine.conv2d(s, wgt, cfg=cfg,
                                                       stride=st, padding=p))
            pertap_fn = jax.jit(lambda s: engine.conv2d(s, wgt, cfg=cfg,
                                                        stride=st, padding=p))
            for stream, want_strip in ((strip, True), (pixel, False)):
                with engine.trace_dispatch() as recs:
                    jax.eval_shape(lambda s: engine.conv2d(
                        s, wgt, cfg=cfg, stride=st, padding=p), stream)
                ok = (not any(r.get("fallback_decode") for r in recs)
                      and any(r.get("chained")
                              and bool(r.get("strip")) == want_strip
                              for r in recs))
                if not ok:
                    raise RuntimeError(
                        f"conv_fused[{backend}]: "
                        f"{'strip' if want_strip else 'per-tap'} path "
                        f"(stride {st}) fell back instead of consuming "
                        f"events: {recs}")
            us_f, cus_f, yf = _time_thunk(lambda: fused_fn(strip), reps=reps)
            us_p, cus_p, yp = _time_thunk(lambda: pertap_fn(pixel), reps=reps)
            plan = fused_conv_plan((b, h, w0, ci), k, p,
                                   nkb=strip.events.num_k_blocks, stride=st)
            entries.append(dict(
                kind="conv_fused", backend=backend, b=b, h=h, w=w0, ci=ci,
                co=co, k=k, padding=p, stride=st,
                fused_us=round(us_f, 1), per_tap_us=round(us_p, 1),
                fused_compile_us=round(cus_f, 1),
                per_tap_compile_us=round(cus_p, 1),
                speedup=round(us_p / max(us_f, 1e-9), 3),
                bit_exact=bool(jnp.all(yf == yp)),
                launches_fused=plan["launches_fused"],
                launches_per_tap=plan["launches_per_tap"],
                subtaps=plan["subtaps"],
                subtaps_worst=plan["subtaps_worst"],
                compaction=round(plan["compaction"], 3),
                event_grid_strip=plan["event_grid_strip"],
                event_grid_pixel=plan["event_grid_pixel"],
                grid_reduction=plan["grid_reduction"],
                gathered_groups_per_tap=plan["gathered_groups_per_tap"],
                gathered_groups_fused=plan["gathered_groups_fused"]))
    _merge_bench(out_path, entries, {"conv_fused"})
    return entries


def cnn_chain_rows(out_path: str = "BENCH_engine.json", *, smoke=False,
                   batch=2, reps=3):
    """Event-resident CNN pipeline vs per-layer round-trip (one jit each).

    Chained and round-trip paths use identical compute geometry
    (pixel-granular conv tiles) so logits are bit-exact; the difference is
    purely the inter-layer format: events stay resident across conv
    boundaries vs a dense materialize + re-encode at every boundary.
    ``boundaries`` records where each compiled graph densifies.
    """
    from repro.core import events as ev
    from repro.models.cnn import (ALEXNET, ALEXNET_DS, ALEXNET_FF, MINI_S4,
                                  VGG16, VGG16_DS,
                                  ConvSpec, FCSpec, FireConfig, PoolSpec,
                                  _input_stream_blk_m, _layer_cfg,
                                  _trace_shapes, chain_boundary_summary,
                                  cnn_forward, init_cnn_params,
                                  make_cnn_pipeline)

    # AlexNet@64 keeps no strip-eligible interior layer (W=7/3 tails);
    # VGG16@32 runs six of its twelve chained convs on the fused strip path.
    # The _ds variants replace pools with stride-2 conv blocks (VGG16_DS@32
    # fuses 8/17 chained convs, ALEXNET_DS@68 both of its eligible layers).
    # ALEXNET_FF@256 is the fully-fused demonstration: every conv —
    # including the stride-4 k=11 head, strip-encoded straight off the
    # dense image — runs 1 launch (conv1: 1 vs 121); batch 1 keeps the
    # 121-launch round-trip twin affordable on the CPU harness.  MINI_S4@32
    # is its smoke twin: a stride-4 mid-layer that must ride the fused
    # path (fallback_decode there fails CI).
    nets = ([(_smoke_spec(), 8, batch), (_smoke_ds_spec(), 16, batch),
             (MINI_S4, 32, batch)] if smoke
            else [(ALEXNET, 64, batch), (VGG16, 32, batch),
                  (ALEXNET_DS, 68, batch), (VGG16_DS, 32, batch),
                  (ALEXNET_FF, 256, 1)])
    entries = []
    for spec, size, batch in nets:
        spec = spec.scaled(size)
        n_conv = sum(isinstance(l, ConvSpec) for l in spec.layers)
        n_fc = sum(isinstance(l, FCSpec) for l in spec.layers)
        n_pool = sum(isinstance(l, PoolSpec) for l in spec.layers)
        params = init_cnn_params(jax.random.PRNGKey(0), spec,
                                 weight_sparsity=0.5)
        x = jax.nn.relu(jax.random.normal(
            jax.random.PRNGKey(1), (batch, size, size, spec.in_ch)))

        # Structural accounting: abstract-trace one forward per mode
        # (records fire at trace time — eval_shape runs no numeric work).
        counts = {}
        for mode, chain in (("chained", True), ("roundtrip", False)):
            with engine.trace_dispatch() as recs:
                jax.eval_shape(
                    lambda p, xx, chain=chain: cnn_forward(
                        p, xx, spec, mnf=True, chain=chain), params, x)
            counts[mode] = dict(
                events_only_boundaries=sum(
                    1 for r in recs if r.get("chained")),
                decodes=sum(1 for r in recs if r.get("decode")),
                fallback_decodes=sum(
                    1 for r in recs if r.get("fallback_decode")),
                pool_events=sum(1 for r in recs if r.get("pool_events")),
                chained_conv_launches=sum(
                    r.get("launches", 0) for r in recs
                    if r.get("chained") and r.get("op") == "conv2d"))
        if counts["chained"]["fallback_decodes"]:
            raise RuntimeError(
                f"cnn_chain[{spec.name}]: chained pipeline hit "
                f"fallback_decode — an eligible strip layer (or a chained "
                f"boundary) silently densified")
        summary = chain_boundary_summary(spec, batch=batch)
        if counts["chained"]["pool_events"] != summary["pool_events"]:
            raise RuntimeError(
                f"cnn_chain[{spec.name}]: {summary['pool_events']} pool "
                f"boundaries are event-eligible but only "
                f"{counts['chained']['pool_events']} rode the event-native "
                f"pool — a conv→pool→conv boundary silently densified")

        # Per-layer launch accounting (taps fused vs per-tap): the strip
        # layers of the chained graph run 1 launch each — including a
        # dense-input conv whose input the chain strip-encodes
        # (_input_stream_blk_m, the AlexNet-head case) — everything else
        # (incl. the whole round-trip twin) pays k*k per conv layer.
        # Strip layers carry their compacted-vs-worst-case subtap counts
        # (dead straddle parts dropped at plan time).
        shapes = _trace_shapes(spec)
        conv_base = _layer_cfg(None, mnf=True, fire_cfg=FireConfig())
        conv_base = conv_base.replace(blk_m=1,
                                      blk_k=min(8, conv_base.blk_k))
        per_layer, stream_in, dense_head_launches = [], False, 0
        for i, layer in enumerate(spec.layers):
            h_in, w_in, c_in = shapes[i]
            if isinstance(layer, FCSpec):
                stream_in = False          # FC ends the conv chain
                continue
            if isinstance(layer, PoolSpec):
                # an ineligible pool densifies the chain (dense fallback)
                stream_in = stream_in and engine.pool_ineligible_reason(
                    (batch, h_in, w_in, c_in), layer.k, layer.stride,
                    conv_base) is None
                continue
            if stream_in:
                strip = bool(engine.strip_eligible(
                    w_in, layer.k, layer.stride, layer.padding,
                    co=layer.out_ch))
            else:
                # dense input (chain head / densified seam): strip only
                # when the chain strip-encodes it for the fused kernel
                strip = bool(_input_stream_blk_m(
                    layer, (batch, h_in, w_in, c_in), conv_base))
            if not (stream_in or strip):
                dense_head_launches += layer.k ** 2
            entry = dict(
                layer=i, k=layer.k, w_in=w_in, strip=strip,
                launches_chained=1 if strip else layer.k ** 2,
                launches_roundtrip=layer.k ** 2)
            if strip:
                subtaps, worst = ev.strip_subtap_counts(
                    layer.k, layer.padding, layer.stride)
                entry.update(subtaps=subtaps, subtaps_worst=worst,
                             compaction=round(subtaps / worst, 3))
            per_layer.append(entry)
            stream_in = True
        launches = dict(
            per_layer=per_layer,
            chained_total=sum(l["launches_chained"] for l in per_layer),
            roundtrip_total=sum(l["launches_roundtrip"] for l in per_layer))
        # convs consuming a dense input dispatch on the dense per-tap path
        # (no chained record) unless the chain strip-encoded that input —
        # strip-encoded heads do produce a chained record, so only
        # dense-input non-strip convs are excluded from the traced total
        want = launches["chained_total"] - dense_head_launches
        got = counts["chained"]["chained_conv_launches"]
        if got != want:
            raise RuntimeError(
                f"cnn_chain[{spec.name}]: launch accounting drifted from "
                f"the traced graph (static {want} != traced {got})")

        fns = {mode: make_cnn_pipeline(spec, mnf=True, chain=chain,
                                       donate=False)
               for mode, chain in (("chained", True), ("roundtrip", False))}
        # Compile each once (compile_us), then time the two pipelines in
        # interleaved rounds and keep the per-mode minimum: back-to-back
        # rep loops right after compilation catch allocator/scheduler
        # transients on share-capped CPUs and can swing 2-3x.
        compile_us, best, out = {}, {}, {}
        for mode, fn in fns.items():
            t0 = time.perf_counter()
            out[mode] = fn(params, x)
            jax.block_until_ready(out[mode])
            compile_us[mode] = (time.perf_counter() - t0) * 1e6
            best[mode] = float("inf")
        for _ in range(max(reps, 3)):
            for mode, fn in fns.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn(params, x))
                best[mode] = min(best[mode],
                                 (time.perf_counter() - t0) * 1e6)
        us_c, cus_c, yc = best["chained"], compile_us["chained"], \
            out["chained"]
        us_r, cus_r, yr = best["roundtrip"], compile_us["roundtrip"], \
            out["roundtrip"]
        entries.append(dict(
            kind="cnn_chain", net=spec.name, input_size=size, batch=batch,
            chained_us=round(us_c, 1), roundtrip_us=round(us_r, 1),
            chained_compile_us=round(cus_c, 1),
            roundtrip_compile_us=round(cus_r, 1),
            speedup=round(us_r / max(us_c, 1e-9), 3),
            bit_exact=bool(jnp.all(yc == yr)),
            launches=launches,
            boundaries=dict(
                conv=n_conv, fc=n_fc, pool=n_pool,
                # chained: pools ride the event-native segment max, so the
                # only densify points left are dense-pool fallbacks
                # (ineligible geometry — 0 on both paper workloads);
                # roundtrip: every boundary is dense.
                chained=dict(densify=summary["densify"],
                             input_encode=summary["input_encode"],
                             **counts["chained"]),
                roundtrip=dict(densify=n_conv + n_fc + n_pool - 1,
                               **counts["roundtrip"]))))
    _merge_bench(out_path, entries, {"cnn_chain"})
    return entries


def _fc_sweep_input(rng, shape, sparsity, blk=8, kblk=16):
    """``_sweep_input`` for flat (batch, features) activations.

    Same block-structured masking idea, but with a 16-wide feature block so
    MNIST-class widths (784 = 49·16) tile exactly — the 32-wide K-block of
    the conv variant does not divide them."""
    m, kd = shape
    x = np.abs(rng.normal(size=shape)).astype(np.float32) + 1e-3
    if sparsity >= 1.0:
        return jnp.zeros(shape, jnp.float32)
    mask = rng.random((max(m // blk, 1), max(kd // kblk, 1))) > sparsity
    mask = np.repeat(np.repeat(mask, blk, axis=0), kblk, axis=1)[:m, :kd]
    return jnp.asarray(x * mask)


def mlp_rows(out_path: str = "BENCH_engine.json", *, smoke=False, batch=8,
             reps=3):
    """Event-native MLP pipeline (mlp_chain entries): the FC family end to
    end — chained fire→EventStream→linear at every boundary, zero densify
    points by construction (DESIGN.md §12).

    Per (net, input sparsity) sweep point: events/token entering the chain
    (the paper's MNIST-class headline quantity), event vs dense MACs
    (Algorithm 2), f32 vs int8 steady-state wall time of the chained
    pipeline, and the exactness-contract flags — f32 chained bitwise ==
    the per-layer round-trip twin, int8 chained bitwise == the fake-quant
    twin (both CI-fatal when they break, like every structural gate here).
    Also CI-fatal: any FC boundary of the chained graph reporting
    fallback_decode — every FC→FC boundary is structurally eligible, so a
    fallback there is the silent-degrade bug class on the new seam.
    """
    from repro.core.fire import FireConfig
    from repro.models.mlp import (LENET_300_100, MLP_MINI, init_mlp_params,
                                  make_mlp_forward, make_mlp_pipeline,
                                  mlp_boundary_summary, run_mlp_with_stats)

    nets = [MLP_MINI] if smoke else [MLP_MINI, LENET_300_100]
    sparsities = (0.0, 0.9) if smoke else (0.0, 0.5, 0.75, 0.9, 0.98)
    rng = np.random.default_rng(0)
    entries = []
    for spec in nets:
        params = init_mlp_params(jax.random.PRNGKey(0), spec,
                                 weight_sparsity=0.5)
        # Structural gate first: abstract-trace the chained graph — every
        # FC boundary must consume events, none may fall back.
        x_sds = jax.ShapeDtypeStruct((batch, spec.in_features), jnp.float32)
        with engine.trace_dispatch() as recs:
            jax.eval_shape(make_mlp_forward(spec, mnf=True), params, x_sds)
        if any(r.get("fallback_decode") for r in recs):
            raise RuntimeError(
                f"mlp_chain[{spec.name}]: an eligible FC boundary reported "
                f"fallback_decode — every FC→FC boundary is structurally "
                f"event-eligible: {recs}")
        summary = mlp_boundary_summary(spec, batch=batch)
        if summary["densify"]:
            raise RuntimeError(
                f"mlp_chain[{spec.name}]: boundary summary reports densify "
                f"points on an all-FC chain: {summary}")

        fq = FireConfig(quantize_to_int8=True)
        fns = dict(
            f32_chained=make_mlp_pipeline(spec, chain=True, donate=False),
            f32_roundtrip=make_mlp_pipeline(spec, chain=False, donate=False),
            int8_chained=make_mlp_pipeline(spec, fire_cfg=fq, chain=True,
                                           donate=False),
            int8_roundtrip=make_mlp_pipeline(spec, fire_cfg=fq, chain=False,
                                             donate=False))
        for sp in sparsities:
            x = _fc_sweep_input(rng, (batch, spec.in_features), sp)
            out = {}
            for name, fn in fns.items():
                out[name] = fn(params, x)
                jax.block_until_ready(out[name])
            best = _interleaved_best(
                {name: (lambda fn=fn: fn(params, x))
                 for name, fn in fns.items()}, reps=reps)
            bit_f32 = bool(jnp.all(out["f32_chained"]
                                   == out["f32_roundtrip"]))
            bit_int8 = bool(jnp.all(out["int8_chained"]
                                    == out["int8_roundtrip"]))
            if not (bit_f32 and bit_int8):
                raise RuntimeError(
                    f"mlp_chain[{spec.name}@sparsity={sp}]: exactness "
                    f"contract broken — f32 bitwise={bit_f32}, int8 "
                    f"fake-quant bitwise={bit_int8} (DESIGN.md §12)")
            _, stats = run_mlp_with_stats(params, x, spec)
            entries.append(dict(
                kind="mlp_chain", net=spec.name, batch=batch,
                in_features=spec.in_features, widths=list(spec.widths),
                sparsity=sp,
                events_per_token=round(
                    sum(s["in_events"] for s in stats) / batch, 1),
                event_macs=round(sum(s["event_macs"] for s in stats), 1),
                dense_macs=round(sum(s["dense_macs"] for s in stats), 1),
                f32_chained_us=round(best["f32_chained"], 1),
                f32_roundtrip_us=round(best["f32_roundtrip"], 1),
                int8_chained_us=round(best["int8_chained"], 1),
                int8_roundtrip_us=round(best["int8_roundtrip"], 1),
                speedup=round(best["f32_roundtrip"]
                              / max(best["f32_chained"], 1e-9), 3),
                int8_vs_f32=round(best["f32_chained"]
                                  / max(best["int8_chained"], 1e-9), 3),
                bit_exact_f32=bit_f32, bit_exact_int8=bit_int8,
                densify=summary["densify"],
                routes=[r["route"] for r in summary["routes"]]))
    _merge_bench(out_path, entries, {"mlp_chain"})
    return entries


def serve_rows(out_path: str = "BENCH_engine.json", *, smoke=False, reps=3):
    """Serving-tier benchmark: the bucketed AOT-warmed replica
    (serve_bench entries, one per batch bucket, plus a replica summary).

    Two replicas are built against the same warm-start cache dir: the
    first with an empty cache (cold — every bucket pays a real trace +
    lower + XLA compile) and the second re-warming from disk (warm — the
    restarted-replica path: per-bucket executable snapshots restore
    finished executables with no trace/lower/compile at all, the
    persistent compilation cache covering any snapshot miss).  Per bucket: steady-state requests/s and p50/p99
    latency through the full submit → route → pad → execute → unpad path,
    cold vs warmed compile time, and the bitwise padding check (one real
    row padded up to the bucket == the unpadded bucket-1 forward).  The
    summary row carries replica time-to-first-response cold vs warmed
    under progressive warmup (smallest bucket first, serve, warm the rest
    behind the first response) — the warmed TTFR is the number ROADMAP
    item 1 asks to be an order of magnitude under the cold ``cnn_chain``
    compile, and the ratio is recorded against the cnn_chain entry
    already in the file.  CI-fatal
    (like every mode here) if any steady-state tick recompiles or the
    padding drifts bitwise.
    """
    import tempfile

    from repro.models.cnn import ALEXNET, init_cnn_params
    from repro.serving import ServeEngine, ServeEngineConfig, pad_bucket

    if smoke:
        spec, buckets = _smoke_spec(), (1, 2, 4)
    else:
        spec, buckets = ALEXNET.scaled(64), (1, 8, 32, 128)
    params = init_cnn_params(jax.random.PRNGKey(0), spec,
                             weight_sparsity=0.5)
    rng = np.random.default_rng(0)
    images = np.maximum(rng.standard_normal(
        (max(buckets), spec.input_size, spec.input_size, spec.in_ch),
        dtype=np.float32), 0.0)
    img = images[0]
    cache_dir = tempfile.mkdtemp(prefix="mnf_serve_bench_")

    def replica():
        """Fresh replica against the shared cache, warming progressively:
        the smallest bucket comes up first and answers the first request
        (TTFR), the remaining buckets warm behind it (full_warm)."""
        t0 = time.perf_counter()
        eng = ServeEngine(spec, params,
                          ServeEngineConfig(buckets=buckets,
                                            cache_dir=cache_dir,
                                            aot_warmup=False))
        eng.submit(img)
        eng.run_tick()                     # compiles/restores bucket 1 only
        ttfr_us = (time.perf_counter() - t0) * 1e6
        eng.warm()                         # the rest of the buckets
        return eng, ttfr_us, (time.perf_counter() - t0) * 1e6

    # empty cache: real trace+lower+XLA compiles
    eng_cold, ttfr_cold_us, full_warm_cold_us = replica()
    cold_warmup_s, cold_recompiles = eng_cold.warmup_s, eng_cold.recompiles
    # A restarted replica is a fresh process: drop the cold engine (and its
    # live per-bucket executables) before timing the restart, or the warm
    # snapshot loads pay the cold replica's memory pressure.
    del eng_cold
    gc.collect()
    # restarted replica: executable snapshots off disk
    eng, ttfr_warm_us, full_warm_warm_us = replica()
    warm_recompiles = eng.recompiles

    # Steady-state traffic: each bucket driven at exactly its batch size so
    # routing lands every tick on that bucket (smallest admissible).
    window_us = {}
    for b in buckets:
        t0 = time.perf_counter()
        for _ in range(reps):
            for i in range(b):
                eng.submit(images[i])
            eng.run_tick()
        window_us[b] = (time.perf_counter() - t0) * 1e6
    if eng.recompiles != warm_recompiles:
        raise RuntimeError(
            f"serve_bench[{spec.name}]: {eng.recompiles - warm_recompiles} "
            f"steady-state recompiles — the jit cache-miss counter must "
            f"stay flat after warmup")

    # Bitwise padding: within each bucket executable, a real row's logits
    # must not depend on what the other rows hold (zeros vs other real
    # images) — zero rows ride as event-free streams and per-sample row
    # groups are independent, so padding is bitwise-inert.  Cross-bucket
    # agreement (the same image through different bucket shapes) is
    # reported separately: XLA picks different GEMM kernels for the dense
    # FC head at different batch shapes, so it is allclose, and bitwise
    # only where the kernel choice coincides (asserted strictly on the
    # mini gate net in `serve --smoke`).
    ref = np.asarray(eng._compiled(1)(
        eng.params, eng._place(1, img[None])))[0]
    entries = []
    for b in buckets:
        got = np.asarray(eng._compiled(b)(
            eng.params, eng._place(b, pad_bucket([img], b))))[0]
        full = np.asarray(eng._compiled(b)(
            eng.params, eng._place(b, pad_bucket(list(images[:b]), b))))[0]
        bit_exact = bool(np.array_equal(got, full))
        if not bit_exact:
            raise RuntimeError(
                f"serve_bench[{spec.name}]: bucket {b} real-row logits "
                f"changed with the padding rows — padding is not "
                f"bitwise-inert")
        if not np.allclose(ref, got, atol=1e-4, rtol=1e-4):
            raise RuntimeError(
                f"serve_bench[{spec.name}]: bucket {b} logits diverged "
                f"from the bucket-1 forward beyond kernel-selection noise")
        stats_b = eng.stats()["per_bucket"][b]
        # cold warmup = lower+compile seconds; warm warmup = either an
        # executable-snapshot load_s or a cache-assisted recompile.
        compile_cold_us = sum(cold_warmup_s[b].values()) * 1e6
        warm_us = sum(eng.warmup_s[b].values()) * 1e6
        entries.append(dict(
            kind="serve_bench", net=spec.name, input_size=spec.input_size,
            bucket=b, requests=stats_b["requests"],
            requests_s=round(b * reps / max(window_us[b] * 1e-6, 1e-9), 2),
            p50_ms=stats_b["p50_ms"], p99_ms=stats_b["p99_ms"],
            compile_cold_us=round(compile_cold_us, 1),
            warm_us=round(warm_us, 1),
            warm_mode=("snapshot" if "load_s" in eng.warmup_s[b]
                       else "compile"),
            warm_speedup=round(compile_cold_us / max(warm_us, 1e-9), 2),
            bit_exact_padding=bit_exact,
            data_shards=eng.plans[b].data_shards))

    # Replica summary: warmed TTFR vs the cold cnn_chain compile already
    # on file (the order-of-magnitude claim, stated as a ratio).
    chain_compile_us = None
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                for e in json.load(f).get("entries", []):
                    if (e.get("kind") == "cnn_chain"
                            and e.get("net") == spec.name):
                        chain_compile_us = e["chained_compile_us"]
        except (json.JSONDecodeError, OSError):
            pass
    stats = eng.stats()
    entries.append(dict(
        kind="serve_bench_summary", net=spec.name,
        input_size=spec.input_size, buckets=list(buckets),
        devices=stats["devices"], mnf=True,
        ttfr_cold_us=round(ttfr_cold_us, 1),
        ttfr_warm_us=round(ttfr_warm_us, 1),
        full_warm_cold_us=round(full_warm_cold_us, 1),
        full_warm_warm_us=round(full_warm_warm_us, 1),
        restart_speedup=round(full_warm_cold_us
                              / max(full_warm_warm_us, 1e-9), 2),
        cold_cnn_chain_compile_us=chain_compile_us,
        warm_ttfr_vs_cold_compile=(
            round(ttfr_warm_us / chain_compile_us, 4)
            if chain_compile_us else None),
        recompiles_warmup=cold_recompiles,
        snapshot_hits_warm=eng.snapshot_hits,
        recompiles_steady=eng.recompiles - warm_recompiles))
    _merge_bench(out_path, entries, {"serve_bench", "serve_bench_summary"})
    return entries


def _lm_decode_gate():
    """Mini recurrent net (CI-fatal): the reduced rwkv6 + hymba decode
    steps run with MNF on — every eligible recurrent boundary must chain,
    none may fall back (the silent-degrade bug class on the new seam)."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.ssm import (mamba_init, mamba_step, rwkv6_block_apply,
                                  rwkv6_block_decode, rwkv6_block_init)
    rng = np.random.default_rng(0)
    recs_all = []
    # rwkv6 token step
    cfg = get_config("rwkv6-7b").reduced()
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    p, _ = rwkv6_block_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 4, cfg.d_model)).astype(np.float32))
    _, st = rwkv6_block_apply(p, x, cfg)
    tok = jnp.asarray(rng.normal(size=(2, 1, cfg.d_model)).astype(np.float32))
    with engine.trace_dispatch() as recs:
        rwkv6_block_decode(p, tok, cfg, st)
    recs_all.extend(recs)
    # hymba mamba token step
    mcfg = get_config("hymba-1.5b").reduced()
    mcfg = dataclasses.replace(mcfg, compute_dtype="float32",
                               ssm=dataclasses.replace(mcfg.ssm, expand=1))
    mp, _ = mamba_init(jax.random.PRNGKey(1), mcfg, d_inner=mcfg.d_model)
    conv = jnp.zeros((2, mcfg.ssm.conv_dim - 1, mcfg.d_model), jnp.float32)
    h = jnp.zeros((2, mcfg.d_model, mcfg.ssm.state_dim), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(2, 1, mcfg.d_model)).astype(np.float32))
    with engine.trace_dispatch() as recs:
        mamba_step(mp, xt, mcfg, (conv, h), with_events=True)
    recs_all.extend(recs)
    rec_recs = [r for r in recs_all if r.get("op") == "recurrent_step"]
    bad = [r for r in rec_recs if r.get("fallback_decode")]
    if bad:
        raise RuntimeError(
            f"lm_decode: an eligible recurrent boundary reported "
            f"fallback_decode — the token-step state update must consume "
            f"the fired event stream: {bad}")
    if not any(r.get("chained") for r in rec_recs):
        raise RuntimeError(
            f"lm_decode: no chained recurrent_step record — the gated "
            f"decode path did not dispatch at all: {rec_recs}")


def lm_decode_rows(out_path: str = "BENCH_engine.json", *, smoke=False,
                   reps=3):
    """Fire-gated recurrent decode (lm_decode entries, DESIGN.md §13).

    Per (kind, backend, threshold) sweep point: fired events/token of the
    delta-fired drive, gated vs dense-step steady-state wall time, output
    drift of the gated step against the ungated dense step (the
    threshold/quality trade the sweep exposes), and the exactness flags —
    ``bit_exact`` at threshold 0 is the block backend's cross-formulation
    contract (gated == dense step bitwise); the pallas kernel's contract is
    within-backend (``bit_within_backend``: gated == the same kernel on an
    all-live drive — interpret mode contracts mul-add chains into FMAs, a
    1-ulp formulation difference vs the jnp tree).  Also runs the mini
    recurrent net structural gate: CI-fatal on any eligible-boundary
    fallback_decode.
    """
    import dataclasses

    from repro.engine.stream import EventStream
    from repro.kernels.mamba_scan.step import mamba_step_ref
    from repro.kernels.wkv6.step import wkv6_step_ref

    _lm_decode_gate()
    rng = np.random.default_rng(0)
    if smoke:
        geoms = dict(wkv6=(8, 16), mamba=(4, 32, 8))
        thresholds = (0.0, 0.3)
        backends = ("block", "pallas")
    else:
        geoms = dict(wkv6=(32, 64), mamba=(8, 128, 16))
        thresholds = (0.0, 0.1, 0.3, 1.0)
        backends = ("block", "pallas")
    entries = []
    for kind in ("wkv6", "mamba"):
        if kind == "wkv6":
            g, d = geoms[kind]
            drive = jnp.asarray(rng.normal(size=(g, d)).astype(np.float32))
            state = jnp.asarray(
                rng.normal(size=(g, d, d)).astype(np.float32))
            ops = dict(
                r=jnp.asarray(rng.normal(size=(g, d)).astype(np.float32)),
                v=jnp.asarray(rng.normal(size=(g, d)).astype(np.float32)),
                w=jnp.asarray(
                    rng.uniform(0.3, 0.99, (g, d)).astype(np.float32)),
                u=jnp.asarray(rng.normal(size=(g, d)).astype(np.float32)))
            dense_ref = wkv6_step_ref
            dense_args = lambda dr: (ops["r"], dr, ops["v"], ops["w"],
                                     ops["u"], state)
            shape = dict(g=g, d=d)
        else:
            b, di, n = geoms[kind]
            g, d = b, di
            drive = jnp.asarray(rng.normal(size=(b, di)).astype(np.float32))
            state = jnp.asarray(
                rng.normal(size=(b, di, n)).astype(np.float32))
            ops = dict(
                da=jnp.asarray(
                    rng.uniform(0.3, 0.99, (b, di, n)).astype(np.float32)),
                bmat=jnp.asarray(
                    rng.normal(size=(b, n)).astype(np.float32)),
                cmat=jnp.asarray(
                    rng.normal(size=(b, n)).astype(np.float32)))
            dense_ref = mamba_step_ref
            dense_args = lambda dr: (dr, ops["da"], ops["bmat"],
                                     ops["cmat"], state)
            shape = dict(b=b, d_inner=di, state_dim=n)
        # The quality yardstick: the ungated dense step on the raw drive.
        # Timing is jitted; the exactness flags compare EAGER evaluations —
        # the contract is formulation-level (event path vs dense step) and
        # must not be confounded by XLA fusion-order differences between a
        # jitted and an un-jitted program.
        o_full = dense_ref(*dense_args(drive))[0]
        dense_us, dense_compile_us, _ = _timeit(
            jax.jit(lambda dr: dense_ref(*dense_args(dr))), drive,
            reps=reps)
        for backend in backends:
            for th in thresholds:
                cfg = engine.EngineConfig(
                    backend=backend,
                    threshold=th).for_recurrent(d).resolved()
                stream = engine.fire_delta(drive, cfg)
                events = float(stream.num_scalar_events)

                # The served token step jits fire + state update as one
                # program — time the same thing here.
                @jax.jit
                def gated(dr, cfg=cfg):
                    st = engine.fire_delta(dr, cfg)
                    return engine.recurrent_step(kind, st, state, cfg,
                                                 **ops)
                us, compile_us, _ = _timeit(gated, drive, reps=reps)
                o, _ = engine.recurrent_step(kind, stream, state, cfg,
                                             **ops)
                fired = jnp.where(jnp.abs(drive) > th, drive, 0.0)
                o_ref = dense_ref(*dense_args(fired))[0]
                bit = bool(jnp.all(o == o_ref)) if th == 0.0 else None
                al = dataclasses.replace(
                    EventStream.encode(stream.dense(), blk_m=1,
                                       blk_k=stream.blk_k, threshold=-1.0),
                    signed=True)
                o_al, _ = engine.recurrent_step(kind, al, state, cfg, **ops)
                drift = float(jnp.max(jnp.abs(o - o_full)))
                entries.append(dict(
                    kind="lm_decode", op=kind, backend=backend,
                    threshold=th, **shape,
                    events_per_token=round(events / max(g, 1), 2),
                    events_total=events,
                    density=round(events / max(g * d, 1), 4),
                    us=round(us, 1), compile_us=round(compile_us, 1),
                    dense_us=round(dense_us, 1),
                    dense_compile_us=round(dense_compile_us, 1),
                    speedup_vs_dense=round(dense_us / max(us, 1e-9), 3),
                    bit_exact=bit,
                    bit_within_backend=bool(jnp.all(o == o_al)),
                    max_drift_vs_dense=drift))
                if th == 0.0 and backend == "block" and not bit:
                    raise RuntimeError(
                        f"lm_decode[{kind}/block]: gated step is not "
                        f"bitwise the dense step at threshold 0 "
                        f"(DESIGN.md §13 contract)")
                if not entries[-1]["bit_within_backend"]:
                    raise RuntimeError(
                        f"lm_decode[{kind}/{backend}@{th}]: gating changed "
                        f"the numbers — gated != all-live through the same "
                        f"kernel (within-backend contract)")
    _merge_bench(out_path, entries, {"lm_decode"})
    return entries


def _adaptive_case(mk: dict, stream, *, op: str, reps=3):
    """One adaptive-vs-static contest on a shared input stream.

    ``mk`` maps route names (one of them "adaptive") to un-jitted
    single-arg callables differing only in their EngineConfig.route.
    Returns (paired_best_us, route, exec_identical):

      * paired_best_us — interleaved-minimum wall time per contender;
      * route — the route the adaptive dispatch actually took (traced
        records, no numeric work);
      * exec_identical — whether the adaptive jaxpr is *textually
        identical* to the chosen static route's jaxpr.  Routing is
        trace-time static, so this is normally True — and it proves the
        adaptive pick costs exactly what that static route costs,
        immunizing the gate against the CPU harness's wall-clock noise
        (identical executables re-timed here spread up to ~35%).
    """
    with engine.trace_dispatch() as recs:
        jax.eval_shape(mk["adaptive"], stream)
    routes = [r["route"] for r in recs if r.get("op") == op]
    route = routes[-1] if routes else None
    exec_identical = bool(
        route in mk and str(jax.make_jaxpr(mk["adaptive"])(stream))
        == str(jax.make_jaxpr(mk[route])(stream)))
    fns = {name: jax.jit(f) for name, f in mk.items()}
    best = _interleaved_best(
        {name: (lambda fn=fn: fn(stream)) for name, fn in fns.items()},
        reps=reps)
    return best, route, exec_identical


def _interleaved_best(fns: dict, reps=3) -> dict:
    """Per-key minimum over interleaved timing rounds.

    Ratios between the keys are what matters (adaptive vs each static
    route): interleaving means a scheduler transient hits every
    contender equally instead of whichever ran back-to-back (the
    ``cnn_chain_rows`` technique)."""
    for fn in fns.values():
        jax.block_until_ready(fn())            # compile outside timing
    best = {k: float("inf") for k in fns}
    for _ in range(max(reps, 3)):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[k] = min(best[k], (time.perf_counter() - t0) * 1e6)
    return best


def _sweep_input(rng, shape, sparsity, blk=8):
    """Non-negative activations with *block-structured* sparsity.

    The engine's occupancy is block-granular (live fraction of the
    row-group × K-block event grid), so elementwise masking saturates it —
    one live element keeps the whole block live.  Masking whole
    (8-row-strip × 8-channel-block) tiles makes stream occupancy track
    ``1 - sparsity`` with exact endpoints: sparsity 0.0 → occupancy 1.0,
    sparsity 1.0 → zero events."""
    x = np.abs(rng.normal(size=shape)).astype(np.float32) + 1e-3
    if sparsity >= 1.0:
        return jnp.zeros(shape, jnp.float32)
    if len(shape) == 4:
        b, h, w0, c = shape
        mask = rng.random((b, h, max(w0 // blk, 1),
                           max(c // blk, 1))) > sparsity
        mask = np.repeat(np.repeat(mask, blk, axis=2), blk, axis=3)
        mask = mask[:, :, :w0, :c]
    else:
        m, kd = shape
        mask = rng.random((max(m // blk, 1), max(kd // 32, 1))) > sparsity
        mask = np.repeat(np.repeat(mask, blk, axis=0), 32, axis=1)
        mask = mask[:m, :kd]
    return jnp.asarray(x * mask)


def sweep_rows(out_path: str = "BENCH_engine.json", *, smoke=False, reps=5):
    """Occupancy sweep 0 → 1 (exact endpoints) over conv / pool / linear
    boundaries: every route timed at matched shapes per sweep point.

    Two entry kinds come out of one pass:

      * ``crossover`` — per (boundary, backend, shape_class, occupancy)
        the measured per-route microseconds.  These seed the calibrated
        :class:`repro.costmodel.crossover.CrossoverTable` that adaptive
        routing consults — the sweep is the calibration run.
      * ``adaptive`` — the ``route="adaptive"`` dispatch re-timed
        end-to-end at each point with the just-measured table installed.
        Routing is trace-time static, so the adaptive executable *is* the
        chosen route's executable; ``overhead_vs_best`` states how far the
        router's pick sits from the best static route at that point
        (≤ 1.05 is the acceptance bar), and ``vs_static_event`` shows the
        win over always-event at the losing shapes (1×1/stride-2 conv,
        full-occupancy pallas linear).

    The pool rows additionally record the *raw* window-major kernel
    against the dense ``reduce_window`` (no re-encode on either side,
    capacity clamped to the probe's live-block maximum — lossless): the
    window-major grid (8/parts step reduction) + capacity clamp is the
    rework that wins on raw steady-state time at high sparsity.

    Raises if any adaptive pick is slower than the best static route by
    more than ROUTE_HYSTERESIS — an unambiguously wrong decision, not
    timing noise.
    """
    from repro.costmodel import crossover as xover
    from repro.kernels.event_pool.ops import pool_window_plan

    rng = np.random.default_rng(0)
    sparsities = (0.0, 0.5, 1.0) if smoke \
        else (0.0, 0.25, 0.5, 0.75, 0.9, 0.95, 1.0)
    entries: list[dict] = []
    adaptive_cases: list[dict] = []

    # -- conv boundaries: strip vs pixel vs dense ---------------------------
    # (B, H, W, CI, CO, k, padding, stride); the second row is the measured
    # losing shape (1×1/stride-2 — taps touch 1/4 of the map, event
    # overhead can't amortize) the adaptive router must route dense; the
    # k3s4 row calibrates the stride-4 straddle-plan class (5 parts,
    # dead-subtap-compacted grid) the AlexNet-head boundary prices.
    conv_shapes = [(2, 16, 16, 8, 16, 3, 1, 1)]
    if not smoke:
        conv_shapes += [(1, 9, 16, 8, 8, 1, 0, 2),
                        (1, 8, 32, 8, 8, 3, 1, 4)]
    for (b, h, w0, ci, co, k, p, st) in conv_shapes:
        wgt = jnp.asarray(rng.normal(size=(k, k, ci, co)).astype(np.float32))
        cfg = engine.EngineConfig(backend="block", blk_m=1, blk_k=8,
                                  blk_n=8)
        strip_ok = engine.strip_eligible(w0, k, st, p, co=co)
        for sp in sparsities:
            # Every route is timed through the engine on a twin-kept
            # stream of the granularity that can ride it (same as the
            # adaptive dispatch will see): the boundary's currency is an
            # EventStream, and dense-by-choice reads the kept twin — the
            # crossover table must price exactly that.
            x = _sweep_input(rng, (b, h, w0, ci), sp)
            pixel = engine.fire_conv(x, cfg, blk_m=1, keep_dense=True)
            occ = float(pixel.occupancy())
            strip = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W,
                                     keep_dense=True) if strip_ok else None
            # Interleaved-minimum timing across the routes of one sweep
            # point: the table's anchors are *ratios* between these keys,
            # so a scheduler transient must hit every route equally — a
            # sequential outlier on one route would mis-teach the table
            # (and the adaptive pass would expose it as a wrong pick).
            fns = {}
            for route, stream_r in ([("strip", strip)] if strip_ok else []) \
                    + [("pixel", pixel),
                       ("dense", strip if strip_ok else pixel)]:
                rcfg = cfg.replace(route=route)
                fn = jax.jit(lambda s, rc=rcfg: engine.conv2d(
                    s, wgt, cfg=rc, stride=st, padding=p))
                fns[route] = (lambda f=fn, s=stream_r: f(s))
            us = _interleaved_best(fns, reps=reps)
            entries.append(dict(
                kind="crossover", boundary="conv", backend="block",
                shape_class=f"k{k}s{st}", b=b, h=h, w=w0, ci=ci, co=co,
                k=k, padding=p, stride=st, sparsity=sp,
                occupancy=round(occ, 4),
                us={r: round(v, 1) for r, v in us.items()}))

            def run_conv(occ=occ, cfg=cfg, wgt=wgt, st=st, p=p,
                         strip_ok=strip_ok, strip=strip, pixel=pixel):
                acfg = cfg.replace(route="adaptive", occupancy_hint=occ)
                s = strip if strip_ok else pixel
                cfgs = {"adaptive": acfg}
                for r in (("strip", "dense") if strip_ok
                          else ("pixel", "dense")):
                    cfgs[r] = cfg.replace(route=r)
                mk = {name: (lambda ss, rc=rc: engine.conv2d(
                    ss, wgt, cfg=rc, stride=st, padding=p))
                    for name, rc in cfgs.items()}
                return _adaptive_case(mk, s, op="conv2d", reps=reps)
            adaptive_cases.append(dict(
                boundary="conv", backend="block", shape_class=f"k{k}s{st}",
                sparsity=sp, occupancy=occ, us=us, run=run_conv,
                achievable=(("strip", "dense") if strip_ok
                            else ("pixel", "dense"))))

    # -- pool boundaries: window vs pixel vs dense-by-choice ----------------
    # (B, H, W, C, k, stride); the wide-channel row is the raw-time contest:
    # reduce_window reads k²·C floats per output pixel while the
    # capacity-clamped window grid touches only live blocks.
    pool_shapes = [(2, 16, 16, 128, 2, 2)]
    if not smoke:
        pool_shapes.append((2, 16, 16, 16, 2, 2))
    for (b, h, w0, c, k, st) in pool_shapes:
        cfg = engine.EngineConfig(backend="block", blk_m=engine.STRIP_W,
                                  blk_k=8)
        for sp in sparsities:
            x = _sweep_input(rng, (b, h, w0, c), sp)
            probe = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W,
                                     keep_dense=False)
            cap = max(int(jnp.max(probe.events.counts)), 1)
            ccfg = cfg.replace(capacity=cap)
            stream = engine.fire_conv(x, ccfg, blk_m=engine.STRIP_W,
                                      keep_dense=True)
            occ = float(stream.occupancy())
            fns = {}
            for route in ("window", "pixel", "dense"):
                rcfg = ccfg.replace(route=route)
                fn = jax.jit(lambda s, rcfg=rcfg: engine.maxpool2d(
                    s, k, st, cfg=rcfg))
                fns[route] = (lambda f=fn: f(stream))
            us = _interleaved_best(fns, reps=reps)
            # Raw kernel vs raw reduce_window: no re-emission on either
            # side — the kernel-rework claim, separated from boundary cost.
            raw_w = jax.jit(lambda s: engine.get_backend(
                "maxpool2d_events_window", "block")(s, k, st, ccfg))
            raw_d = jax.jit(lambda xx: engine.maxpool2d(xx, k, st, cfg=ccfg))
            no_twin = stream.without_dense()
            raw_us = _interleaved_best(
                dict(window=lambda: raw_w(no_twin), dense=lambda: raw_d(x)),
                reps=reps)
            us_rw, us_rd = raw_us["window"], raw_us["dense"]
            yw, yd = raw_w(no_twin), raw_d(x)
            plan = pool_window_plan((b, h, w0, c), k, st,
                                    nkb=stream.events.num_k_blocks,
                                    capacity=cap)
            entries.append(dict(
                kind="crossover", boundary="pool", backend="block",
                shape_class=f"k{k}s{st}c{c}", b=b, h=h, w=w0, c=c, k=k,
                stride=st, sparsity=sp, occupancy=round(occ, 4),
                capacity=cap,
                us={r: round(v, 1) for r, v in us.items()},
                raw_window_us=round(us_rw, 1), raw_dense_us=round(us_rd, 1),
                raw_speedup=round(us_rd / max(us_rw, 1e-9), 3),
                raw_bit_exact=bool(jnp.all(
                    yw.reshape(yd.shape) == yd)),
                grid_reduction=round(plan["grid_reduction"], 2),
                parts=plan["parts"]))

            def run_pool(occ=occ, ccfg=ccfg, stream=stream, k=k, st=st):
                acfg = ccfg.replace(route="adaptive", occupancy_hint=occ)
                mk = {name: (lambda s, rc=rc: engine.maxpool2d(
                    s, k, st, cfg=rc))
                    for name, rc in (("adaptive", acfg),
                                     ("window", ccfg.replace(
                                         route="window")),
                                     ("dense", ccfg.replace(
                                         route="dense")))}
                return _adaptive_case(mk, stream, op="maxpool2d", reps=reps)
            adaptive_cases.append(dict(
                boundary="pool", backend="block",
                shape_class=f"k{k}s{st}c{c}",
                sparsity=sp, occupancy=occ, us=us, run=run_pool,
                achievable=("window", "dense")))

    # -- linear boundaries: event vs dense ----------------------------------
    # The pallas chained linear is the other measured losing case (0.87x at
    # full occupancy) the adaptive router must route dense.
    m, kd, n = 32, 256, 128
    wl = jnp.asarray(rng.normal(size=(kd, n)).astype(np.float32))
    for backend in (("block",) if smoke else ("block", "pallas")):
        cfg = engine.EngineConfig(backend=backend, blk_m=8, blk_k=32,
                                  blk_n=32)
        for sp in sparsities:
            a = _sweep_input(rng, (m, kd), sp)
            stream = engine.fire(a, cfg)       # twin kept, like dispatch
            occ = float(stream.occupancy())
            ecfg2 = cfg.replace(route="event")
            fn_e = jax.jit(lambda s: engine.linear(s, wl, cfg=ecfg2))
            dcfg2 = cfg.replace(route="dense")
            fn_d = jax.jit(lambda s: engine.linear(s, wl, cfg=dcfg2))
            us = _interleaved_best(
                dict(event=lambda: fn_e(stream),
                     dense=lambda: fn_d(stream)), reps=reps)
            entries.append(dict(
                kind="crossover", boundary="linear", backend=backend,
                shape_class=f"n{n}", m=m, k=kd, n=n, sparsity=sp,
                occupancy=round(occ, 4),
                us={r: round(v, 1) for r, v in us.items()}))

            def run_linear(occ=occ, cfg=cfg, stream=stream, wl=wl):
                acfg = cfg.replace(route="adaptive", occupancy_hint=occ)
                mk = {name: (lambda s, rc=rc: engine.linear(s, wl, cfg=rc))
                      for name, rc in (("adaptive", acfg),
                                       ("event", cfg.replace(
                                           route="event")),
                                       ("dense", cfg.replace(
                                           route="dense")))}
                return _adaptive_case(mk, stream, op="linear", reps=reps)
            adaptive_cases.append(dict(
                boundary="linear", backend=backend, shape_class=f"n{n}",
                sparsity=sp, occupancy=occ, us=us, run=run_linear,
                achievable=("event", "dense")))

    # -- adaptive pass: route with the just-measured table installed --------
    table = xover.CrossoverTable(entries)
    prev = xover.set_active_table(table)
    try:
        for case in adaptive_cases:
            # Paired interleaved timings of the adaptive dispatch and the
            # routes *achievable from this stream's granularity* — the
            # flavor is producer-bound (a strip stream cannot
            # retroactively ride the per-tap path), so those are the
            # static choices the router actually arbitrates.
            paired, route, exec_identical = case["run"]()
            adaptive_us = paired.pop("adaptive")
            # The calibration pass timed these exact executables on these
            # exact streams (same cfg, same input — same jit graph): its
            # minima are more samples of the same program, so pool them.
            # This keeps the published table and the adaptive judgment one
            # consistent measurement set — two phases disagreeing inside
            # the noise floor about a near-crossover point must not read
            # as a routing error.
            for r, v in case["us"].items():
                if r in paired:
                    paired[r] = min(paired[r], v)
            best_route = min(paired, key=paired.get)
            best_us = paired[best_route]
            ev_us = [v for r, v in paired.items()
                     if r in xover.EVENT_ROUTES]
            static_event_us = min(ev_us) if ev_us else None
            # When the router picked the paired-best route, the adaptive
            # executable IS that route's executable (jaxpr-identical) —
            # overhead 1.0 by construction, not by a second noisy
            # measurement.  Only a divergent pick is judged on wall time.
            # Judging a divergent pick: the adaptive executable is jaxpr-
            # identical to its chosen static route's, so their timings
            # sample the *same program* — pool the minima.  A pick still
            # over the acceptance bar after pooling gets bounded
            # confirmation rounds (all-route re-timings, minima pooled):
            # near-crossover boundaries sit inside the harness noise floor
            # and a single calibration-vs-judgment disagreement there is
            # not a routing error.  The hysteresis raise below still
            # catches unambiguous misses — pooling sharpens both sides.
            rounds = 0
            while True:
                if exec_identical and route in paired:
                    pooled = min(adaptive_us, paired[route])
                    adaptive_us = paired[route] = pooled
                best_route = min(paired, key=paired.get)
                best_us = paired[best_route]
                if route == best_route and exec_identical:
                    overhead = 1.0
                    break
                overhead = adaptive_us / max(best_us, 1e-9)
                if overhead <= 1.05 or rounds >= 3:
                    break
                rounds += 1
                paired2, _, exec2 = case["run"]()
                adaptive_us = min(adaptive_us, paired2.pop("adaptive"))
                for r, v in paired2.items():
                    paired[r] = min(paired[r], v)
                exec_identical = exec_identical or exec2
            entries.append(dict(
                kind="adaptive", boundary=case["boundary"],
                backend=case["backend"], shape_class=case["shape_class"],
                sparsity=case["sparsity"],
                occupancy=round(case["occupancy"], 4), route=route,
                exec_identical=exec_identical,
                adaptive_us=round(adaptive_us, 1),
                achievable=list(case["achievable"]),
                best_route=best_route, best_us=round(best_us, 1),
                static_event_us=(round(static_event_us, 1)
                                 if static_event_us is not None else None),
                vs_static_event=(round(static_event_us
                                       / max(adaptive_us, 1e-9), 3)
                                 if static_event_us is not None else None),
                overhead_vs_best=round(overhead, 3)))
            if overhead > 1.0 + xover.ROUTE_HYSTERESIS:
                raise RuntimeError(
                    f"sweep[{case['boundary']}/{case['shape_class']}@occ="
                    f"{case['occupancy']:.2f}]: adaptive picked {route} at "
                    f"{adaptive_us:.1f}us, {overhead:.2f}x the best static "
                    f"route {best_route} ({best_us:.1f}us) — beyond the "
                    f"hysteresis band, an unambiguously wrong decision")
    finally:
        xover.set_active_table(prev)
    _merge_bench(out_path, entries, {"crossover", "adaptive"})
    return entries


def route_gate(out_path: str = "BENCH_engine.json"):
    """CI smoke gate (DESIGN.md §11): re-derive every routing decision of
    the smoke nets in adaptive mode across occupancy hints and **fail** if
    any decision contradicts the committed crossover table by more than
    ROUTE_HYSTERESIS (``route_conflicts``), or if adaptive mode ever
    yields a fallback_decode on an eligible net — dense by *choice* is
    ``routed_dense``, never a fallback.  No numeric work: decisions are
    trace-time static, so ``jax.eval_shape`` under the dispatch tracer
    sees exactly what a compiled graph would do."""
    from repro.costmodel import crossover as xover
    from repro.models.cnn import MINI_S4, init_cnn_params, make_cnn_forward

    table = xover.load_crossover_table(out_path)
    if not len(table):
        print(json.dumps(dict(kind="route_gate",
                              skipped=f"no crossover entries in "
                                      f"{out_path} — run --sweep first")))
        return
    prev = xover.set_active_table(table)
    try:
        records = []
        for spec, size in ((_smoke_spec(), 8), (_smoke_ds_spec(), 16),
                           (MINI_S4, 32)):
            spec = spec.scaled(size)
            params = init_cnn_params(jax.random.PRNGKey(0), spec,
                                     weight_sparsity=0.5)
            x = jax.ShapeDtypeStruct(
                (2, spec.input_size, spec.input_size, spec.in_ch),
                jnp.float32)
            for occ in (0.05, 0.5, 1.0):
                cfg = engine.EngineConfig(backend="auto", route="adaptive",
                                          occupancy_hint=occ)
                fwd = make_cnn_forward(spec, mnf=True, engine_cfg=cfg)
                with engine.trace_dispatch() as recs:
                    jax.eval_shape(fwd, params, x)
                records.extend(recs)
        conflicts = xover.route_conflicts(records, table)
        if conflicts:
            raise RuntimeError(
                f"route gate: {len(conflicts)} decision(s) contradict the "
                f"crossover table beyond the {xover.ROUTE_HYSTERESIS:.0%} "
                f"hysteresis band: {conflicts}")
        fallbacks = [r for r in records if r.get("fallback_decode")]
        if fallbacks:
            raise RuntimeError(
                f"route gate: adaptive mode produced fallback_decode on an "
                f"eligible net (dense-by-choice must be routed_dense): "
                f"{fallbacks}")
        decided = [r for r in records if r.get("route") is not None]
        print(json.dumps(dict(
            kind="route_gate", decisions=len(decided), conflicts=0,
            fallback_decodes=0,
            routes={r: sum(1 for d in decided if d["route"] == r)
                    for r in sorted({d["route"] for d in decided})})))
    finally:
        xover.set_active_table(prev)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="sweep EngineConfig.backend and write "
                         "BENCH_engine.json")
    ap.add_argument("--cnn-chain", action="store_true",
                    help="time the event-resident CNN pipeline vs the "
                         "per-layer round-trip (cnn_chain entries)")
    ap.add_argument("--conv-fused", action="store_true",
                    help="time the fused strip-tiled conv kernel (one "
                         "launch/layer) vs the per-tap chained path "
                         "(conv_fused entries)")
    ap.add_argument("--pool", action="store_true",
                    help="time the event-native max-pool (events in -> "
                         "events out) vs the dense pool + re-encode "
                         "round-trip (pool entries)")
    ap.add_argument("--serve", action="store_true",
                    help="benchmark the bucketed AOT-warmed serving "
                         "replica: requests/s + p50/p99 per bucket, cold "
                         "vs persistent-cache-warmed compile and replica "
                         "TTFR (serve_bench entries)")
    ap.add_argument("--mlp", action="store_true",
                    help="benchmark the event-native MLP chain (mlp_chain "
                         "entries): events/token at swept input sparsity, "
                         "int8 vs f32 steady-state, and the per-layer "
                         "exactness-contract flags; fails on any eligible "
                         "FC boundary reporting fallback_decode")
    ap.add_argument("--lm-decode", action="store_true",
                    help="benchmark the fire-gated recurrent decode "
                         "(lm_decode entries): events/token across a "
                         "threshold sweep, gated vs dense-step "
                         "steady-state, output drift, and the exactness "
                         "flags (block bitwise at threshold 0; pallas "
                         "bitwise within-backend); fails on any "
                         "eligible recurrent boundary reporting "
                         "fallback_decode in the mini recurrent net")
    ap.add_argument("--sweep", action="store_true",
                    help="occupancy sweep 0-1 over conv/pool/linear "
                         "boundaries: per-route microseconds at each point "
                         "(crossover entries — the adaptive routing "
                         "table) plus the adaptive router re-timed "
                         "end-to-end against the best static route "
                         "(adaptive entries); combine with --smoke for "
                         "the fast CI subset")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: 1-rep kernel microbench + engine "
                         "sweep + mini-net cnn chains (incl. a stride-4 "
                         "net whose mid-layer must ride the fused straddle "
                         "plan) + stride-1/2/4 conv_fused shapes and "
                         "one pool shape + the MLP mini-net chain + a "
                         "mini serving replica — keeps "
                         "every benchmark path from rotting and fails on "
                         "strip-layer or pool-boundary fallback_decode, "
                         "steady-state recompiles, or padding drift")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args()
    if args.smoke:
        if args.sweep:
            # Slow-lane CI subset: 3 sparsity points, one shape per
            # boundary kind, block backend — exercises the whole sweep +
            # adaptive machinery without the full calibration cost.
            for e in sweep_rows(args.out, smoke=True, reps=2):
                print(json.dumps(e))
            return
        for name, us, compile_us, derived in rows(reps=1):
            print(f"{name},{us:.1f},compile={compile_us:.1f},{derived}")
        for e in engine_rows(args.out, reps=1):
            print(json.dumps(e))
        for e in cnn_chain_rows(args.out, smoke=True, reps=1):
            print(json.dumps(e))
        for e in conv_fused_rows(args.out, smoke=True, reps=1):
            print(json.dumps(e))
        for e in pool_rows(args.out, smoke=True, reps=1):
            print(json.dumps(e))
        for e in mlp_rows(args.out, smoke=True, reps=1):
            print(json.dumps(e))
        for e in lm_decode_rows(args.out, smoke=True, reps=1):
            print(json.dumps(e))
        for e in serve_rows(args.out, smoke=True, reps=1):
            print(json.dumps(e))
        route_gate(args.out)
        return
    if args.engine:
        for e in engine_rows(args.out):
            print(json.dumps(e))
    if args.cnn_chain:
        for e in cnn_chain_rows(args.out):
            print(json.dumps(e))
    if args.conv_fused:
        for e in conv_fused_rows(args.out):
            print(json.dumps(e))
    if args.pool:
        for e in pool_rows(args.out):
            print(json.dumps(e))
    if args.serve:
        for e in serve_rows(args.out):
            print(json.dumps(e))
    if args.mlp:
        for e in mlp_rows(args.out):
            print(json.dumps(e))
    if args.lm_decode:
        for e in lm_decode_rows(args.out):
            print(json.dumps(e))
    if args.sweep:
        for e in sweep_rows(args.out):
            print(json.dumps(e))
    if (args.engine or args.cnn_chain or args.conv_fused or args.pool
            or args.serve or args.mlp or args.sweep or args.lm_decode):
        return
    for name, us, compile_us, derived in rows():
        print(f"{name},{us:.1f},compile={compile_us:.1f},{derived}")


if __name__ == "__main__":
    main()
