"""Kernel microbenchmarks: event_matmul / fire_compact / wkv6 — plus an
engine backend-comparison mode.

Wall-times are interpret-mode on CPU (correctness harness, not TPU perf);
the derived columns carry the *structural* quantities that transfer to TPU:
fraction of weight-tile DMAs skipped (== event sparsity the kernel rides)
and the ref/kernel agreement.

``--engine`` sweeps every registered ``EngineConfig.backend`` of
``engine.linear`` over a sparsity grid, compares the chained
(fire → EventStream → linear) path against the decode→re-encode round-trip,
and writes BENCH_engine.json.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.kernels import (event_matmul, event_matmul_ref, fire_compact,
                           fire_compact_ref, wkv6, wkv6_ref)


def _timeit(fn, *args, reps=3, **kw):
    fn(*args, **kw)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def rows():
    rng = np.random.default_rng(0)
    out = []
    for sparsity in (0.0, 0.7, 0.95):
        m, k, n = 64, 1024, 512
        a = rng.normal(size=(m, k)).astype(np.float32)
        a *= rng.random((m, k)) > sparsity
        w = rng.normal(size=(k, n)).astype(np.float32)
        us, y = _timeit(event_matmul, jnp.asarray(a), jnp.asarray(w),
                        blk_m=8, blk_k=128, interpret=True)
        yr = event_matmul_ref(jnp.asarray(a), jnp.asarray(w), blk_m=8,
                              blk_k=128)
        live = np.abs(a.reshape(8, 8, 8, 128)).max(axis=(1, 3)) > 0
        out.append((f"event_matmul_s{sparsity}", us,
                    f"tiles_skipped={1-live.mean():.2f};"
                    f"allclose={np.allclose(y, yr, atol=1e-4)}"))
    acc = jnp.asarray(rng.normal(size=(128, 1024)), jnp.float32)
    us, (f, occ) = _timeit(fire_compact, acc, blk_m=8, blk_k=128,
                           interpret=True)
    fr, occr = fire_compact_ref(acc, blk_m=8, blk_k=128)
    out.append(("fire_compact", us,
                f"allclose={np.allclose(f, fr)};"
                f"occ_match={np.array_equal(np.asarray(occ), np.asarray(occr))}"))
    b, h, t, d = 2, 2, 64, 32
    r, k2, v = (jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
                for _ in range(3))
    w6 = jnp.asarray(rng.uniform(0.3, 0.99, (b, h, t, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    us, (o, s) = _timeit(wkv6, r, k2, v, w6, u, chunk=16, interpret=True)
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k2, v, w6, u)
    out.append(("wkv6_chunked", us,
                f"allclose={np.allclose(o, orf, atol=1e-4)};"
                f"state_ok={np.allclose(s, srf, atol=1e-4)}"))
    return out


def engine_rows(out_path: str = "BENCH_engine.json"):
    """Backend comparison through the unified engine API.

    Every backend must agree with the dense oracle at threshold 0 — the
    sweep records that check alongside wall-time, then times the chained
    EventStream path vs the dense round-trip between two layers.
    """
    rng = np.random.default_rng(0)
    m, k, n = 32, 256, 128
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    entries = []
    for sparsity in (0.0, 0.7, 0.95):
        a = rng.normal(size=(m, k)).astype(np.float32)
        a *= rng.random((m, k)) > sparsity
        aj = jnp.asarray(a)
        ref = a @ np.asarray(w)
        for name in engine.list_backends("linear"):
            cfg = engine.EngineConfig(backend=name, blk_m=8, blk_k=32,
                                      blk_n=32)
            us, y = _time_thunk(lambda: engine.linear(aj, w, cfg=cfg))
            entries.append(dict(
                kind="linear", backend=name, sparsity=sparsity,
                m=m, k=k, n=n, us=round(us, 1),
                allclose=bool(np.allclose(np.asarray(y), ref, atol=2e-3))))

    # chained vs round-trip: layer1 -> fire -> layer2
    w2 = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    a = rng.normal(size=(m, k)).astype(np.float32)
    a *= rng.random((m, k)) > 0.7
    aj = jnp.asarray(a)
    for name in engine.list_backends("linear_events"):
        cfg = engine.EngineConfig(backend=name, blk_m=8, blk_k=32, blk_n=32)
        acc = engine.linear(aj, w, cfg=cfg)
        stream = engine.fire(acc, cfg)

        def chained():
            return engine.linear(stream.without_dense(), w2, cfg=cfg)

        def roundtrip():
            return engine.linear(stream.dense(), w2, cfg=cfg)

        us_c, yc = _time_thunk(chained)
        us_r, yr = _time_thunk(roundtrip)
        entries.append(dict(
            kind="chained_vs_roundtrip", backend=name,
            events=int(stream.num_events), occupancy=float(stream.occupancy()),
            chained_us=round(us_c, 1), roundtrip_us=round(us_r, 1),
            speedup=round(us_r / max(us_c, 1e-9), 3),
            bit_exact=bool(jnp.all(yc == yr))))
    payload = dict(device=jax.default_backend(),
                   note="CPU interpret-mode wall-times; structural columns "
                        "(allclose, events, bit_exact) are what transfers",
                   entries=entries)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    return entries


def _time_thunk(fn, reps=3):
    fn()                                  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="sweep EngineConfig.backend and write "
                         "BENCH_engine.json")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args()
    if args.engine:
        for e in engine_rows(args.out):
            print(json.dumps(e))
        return
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
