"""Kernel microbenchmarks: event_matmul / fire_compact / wkv6.

Wall-times are interpret-mode on CPU (correctness harness, not TPU perf);
the derived columns carry the *structural* quantities that transfer to TPU:
fraction of weight-tile DMAs skipped (== event sparsity the kernel rides)
and the ref/kernel agreement.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (event_matmul, event_matmul_ref, fire_compact,
                           fire_compact_ref, wkv6, wkv6_ref)


def _timeit(fn, *args, reps=3, **kw):
    fn(*args, **kw)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def rows():
    rng = np.random.default_rng(0)
    out = []
    for sparsity in (0.0, 0.7, 0.95):
        m, k, n = 64, 1024, 512
        a = rng.normal(size=(m, k)).astype(np.float32)
        a *= rng.random((m, k)) > sparsity
        w = rng.normal(size=(k, n)).astype(np.float32)
        us, y = _timeit(event_matmul, jnp.asarray(a), jnp.asarray(w),
                        blk_m=8, blk_k=128, interpret=True)
        yr = event_matmul_ref(jnp.asarray(a), jnp.asarray(w), blk_m=8,
                              blk_k=128)
        live = np.abs(a.reshape(8, 8, 8, 128)).max(axis=(1, 3)) > 0
        out.append((f"event_matmul_s{sparsity}", us,
                    f"tiles_skipped={1-live.mean():.2f};"
                    f"allclose={np.allclose(y, yr, atol=1e-4)}"))
    acc = jnp.asarray(rng.normal(size=(128, 1024)), jnp.float32)
    us, (f, occ) = _timeit(fire_compact, acc, blk_m=8, blk_k=128,
                           interpret=True)
    fr, occr = fire_compact_ref(acc, blk_m=8, blk_k=128)
    out.append(("fire_compact", us,
                f"allclose={np.allclose(f, fr)};"
                f"occ_match={np.array_equal(np.asarray(occ), np.asarray(occr))}"))
    b, h, t, d = 2, 2, 64, 32
    r, k2, v = (jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
                for _ in range(3))
    w6 = jnp.asarray(rng.uniform(0.3, 0.99, (b, h, t, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    us, (o, s) = _timeit(wkv6, r, k2, v, w6, u, chunk=16, interpret=True)
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k2, v, w6, u)
    out.append(("wkv6_chunked", us,
                f"allclose={np.allclose(o, orf, atol=1e-4)};"
                f"state_ok={np.allclose(s, srf, atol=1e-4)}"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
