"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.configs.base import (GLOBAL_WINDOW, SHAPES, MLAConfig, MNFConfig,
                                ModelConfig, MoEConfig, ShapeConfig, SSMConfig)

_REGISTRY = {
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "qwen2-0.5b": "repro.configs.qwen2_0p5b",
    "minitron-8b": "repro.configs.minitron_8b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "whisper-base": "repro.configs.whisper_base",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4p2b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch]).config()


__all__ = ["ARCH_IDS", "GLOBAL_WINDOW", "SHAPES", "MLAConfig", "MNFConfig",
           "ModelConfig", "MoEConfig", "ShapeConfig", "SSMConfig",
           "get_config"]
