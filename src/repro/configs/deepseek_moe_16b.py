"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE: 2 shared + 64 routed top-6, fine-grained.
[arXiv:2401.06066; hf]
"""
from repro.configs.base import MNFConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=102400, head_dim=128,
        act="silu_glu",
        moe=MoEConfig(num_experts=64, num_shared=2, top_k=6,
                      expert_ff=1408, first_dense_layers=1,
                      dense_ff=10944),
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=True),
        fsdp=True, sub_quadratic=False,
    )
