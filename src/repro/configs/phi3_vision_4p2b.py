"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend (stub: precomputed patch
embeddings).  [hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from repro.configs.base import MNFConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32064, head_dim=96,
        act="silu_glu",
        vision_tokens=144,
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=True),
        fsdp=True, sub_quadratic=False,
    )
