"""Config system: one dataclass tree describes every supported architecture.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``config()`` with the exact published numbers; reduced smoke variants come
from ``ModelConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["MoEConfig", "MLAConfig", "SSMConfig", "MNFConfig", "ModelConfig",
           "ShapeConfig", "SHAPES", "GLOBAL_WINDOW"]

# Sentinel window meaning "global attention" in per-layer window arrays.
GLOBAL_WINDOW = 1 << 30


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int               # routed experts
    num_shared: int                # shared (always-on) experts
    top_k: int
    expert_ff: int                 # per-expert FFN hidden size
    first_dense_layers: int = 1    # leading layers use a dense FFN
    dense_ff: int = 0              # hidden size of those dense FFNs
    capacity_factor: float = 1.25
    router_renormalize: bool = False  # renormalize top-k gate weights


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)
    scan_chunk: int = 512          # time-chunked scan (memory-bounded)


@dataclasses.dataclass(frozen=True)
class MNFConfig:
    """Multiply-and-Fire integration (the paper's technique as a feature)."""

    enabled: bool = False
    threshold: float = 0.0         # fire threshold (0 == exact for ReLU nets)
    magnitude: bool = True         # |a| > θ (LM generalization)
    blk_m: int = 8                 # event tile rows
    blk_k: int = 128               # event tile K (VMEM lane width)
    use_pallas: bool = False       # False -> pure-jnp twin (dry-run truthful)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | ssm | moe | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    block_type: str = "attn"       # attn | rwkv6 | hymba
    qkv_bias: bool = False
    act: str = "silu_glu"          # silu_glu | gelu_glu | relu2 | relu | gelu
    # --- attention pattern ---
    sliding_window: Optional[int] = None  # window for local layers
    layer_pattern: str = "all_global"     # all_global | alternating | listed
    global_layer_ids: tuple = ()          # for layer_pattern == "listed"
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    post_block_norm: bool = False          # gemma2 sandwich norms
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- submodules ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- enc-dec / multimodal stubs ---
    encoder_decoder: bool = False
    enc_layers: int = 0
    enc_frames: int = 0            # whisper: precomputed frame embeddings
    vision_tokens: int = 0         # phi-3-vision: precomputed patch embeds
    # --- MNF ---
    mnf: MNFConfig = dataclasses.field(default_factory=MNFConfig)
    # --- distribution / memory ---
    fsdp: bool = False             # shard params+optimizer over data axis
    seq_shard: bool = True         # SP: shard residual stream over model
    moe_dispatch_groups: int = 32  # group-local MoE dispatch (≥ dp shards)
    moe_ep: bool = False           # explicit shard_map expert parallelism
    remat: str = "full"            # full | dots | none
    scan_layers: bool = True
    xent_chunk: int = 1024         # chunked softmax-xent sequence chunk
    attn_chunk: int = 1024         # flash-attention kv chunk
    wkv_chunk: int = 32            # rwkv6 chunk length (jnp path)
    # --- capability flags ---
    sub_quadratic: bool = False    # can run long_500k
    has_decoder: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def window_for_layer(self, i: int) -> int:
        """Per-layer attention window (GLOBAL_WINDOW = full context)."""
        if self.block_type == "rwkv6":
            return 0
        if self.layer_pattern == "all_global" or self.sliding_window is None:
            return GLOBAL_WINDOW
        if self.layer_pattern == "alternating":
            # gemma2: even layers local, odd layers global
            return self.sliding_window if i % 2 == 0 else GLOBAL_WINDOW
        if self.layer_pattern == "listed":
            return (GLOBAL_WINDOW if i in self.global_layer_ids
                    else self.sliding_window)
        raise ValueError(self.layer_pattern)

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 2 if self.moe is None else 2),
            d_model=64, num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2)
            if self.num_kv_heads < self.num_heads else 4,
            d_ff=128, vocab_size=256, head_dim=16,
            enc_layers=min(self.enc_layers, 2),
            enc_frames=min(self.enc_frames, 16) if self.enc_frames else 0,
            vision_tokens=min(self.vision_tokens, 8) if self.vision_tokens else 0,
            xent_chunk=16, attn_chunk=32, wkv_chunk=8,
            sliding_window=8 if self.sliding_window else None,
            global_layer_ids=(0,) if self.layer_pattern == "listed" else (),
            fsdp=False,
        )
        if self.moe is not None:
            # capacity_factor high enough that reduced configs never drop
            # tokens (keeps decode==forward consistency tests exact; full
            # configs keep the production 1.25).
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, num_shared=1, top_k=2, expert_ff=32,
                dense_ff=128, capacity_factor=16.0,
                first_dense_layers=min(1, self.moe.first_dense_layers))
        if self.mla is not None:
            changes["mla"] = MLAConfig(kv_lora_rank=32, qk_rope_dim=8,
                                       qk_nope_dim=16, v_head_dim=16)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(self.ssm, state_dim=4)
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
