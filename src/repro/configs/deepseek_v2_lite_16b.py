"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408
vocab=102400, MLA kv_lora=512, 2 shared + 64 routed top-6.
[arXiv:2405.04434; hf]
"""
from repro.configs.base import MLAConfig, MNFConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=102400, head_dim=128,
        act="silu_glu",
        moe=MoEConfig(num_experts=64, num_shared=2, top_k=6,
                      expert_ff=1408, first_dense_layers=1,
                      dense_ff=10944),
        mla=MLAConfig(kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
                      v_head_dim=128),
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=True),
        fsdp=True, sub_quadratic=False,
    )
