"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 (attn-free) d_ff=14336
vocab=65536 — data-dependent decay.  [arXiv:2404.05892; hf]
"""
from repro.configs.base import MNFConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
        d_ff=14336, vocab_size=65536, head_dim=64,
        block_type="rwkv6", act="relu2",  # channel-mix uses squared ReLU
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=False),
        fsdp=True, sub_quadratic=True,   # constant-size state: runs long_500k
    )
