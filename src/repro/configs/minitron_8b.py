"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron (squared-ReLU FFN).  [arXiv:2407.14679; hf]
"""
from repro.configs.base import MNFConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=16384, vocab_size=256000, head_dim=128,
        act="relu2",  # squared-ReLU: natively sparse -> MNF is exact here
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=False),
        fsdp=True, sub_quadratic=False,
    )
