"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA, QKV bias.  [arXiv:2407.10671; hf]
"""
from repro.configs.base import MNFConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        d_ff=8960, vocab_size=151936, head_dim=128,
        qkv_bias=True, act="silu_glu", rope_theta=1e6,
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=True),
        fsdp=False, sub_quadratic=False,
    )
