"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads; SWA everywhere except
3 global layers (first/middle/last).  [arXiv:2411.13676; hf]
"""
from repro.configs.base import MNFConfig, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        d_ff=5504, vocab_size=32001, head_dim=64,
        block_type="hymba", act="silu_glu",
        sliding_window=1024, layer_pattern="listed",
        global_layer_ids=(0, 15, 31),
        ssm=SSMConfig(state_dim=16, conv_dim=4, expand=1),
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=True),
        fsdp=False,
        # SWA + constant SSM state: runs long_500k (global layers use a
        # bounded 32k sink window at 500k — see DESIGN.md shape skips).
        sub_quadratic=True,
    )
