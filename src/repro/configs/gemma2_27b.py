"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating, logit softcap.  [arXiv:2408.00118; hf]
"""
from repro.configs.base import MNFConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense",
        num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
        d_ff=36864, vocab_size=256000, head_dim=128,
        act="gelu_glu",
        sliding_window=4096, layer_pattern="alternating",
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_block_norm=True, tie_embeddings=True,
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=True),
        fsdp=True, sub_quadratic=False,
    )
