"""whisper-base [audio] — 6L d_model=512 8H d_ff=2048 vocab=51865 —
enc-dec, conv frontend (stub: precomputed frame embeddings).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import MNFConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
        d_ff=2048, vocab_size=51865, head_dim=64,
        act="gelu",  # whisper MLP: gelu, no GLU
        encoder_decoder=True, enc_layers=6, enc_frames=1500,
        mnf=MNFConfig(enabled=True, threshold=0.0, magnitude=True),
        fsdp=False, sub_quadratic=False,
    )
