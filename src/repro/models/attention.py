"""Attention: chunked online-softmax (flash-style) GQA/MHA + MLA, KV caches.

``chunked_attention`` is the workhorse for train/prefill — it never
materializes the (S, S) score matrix (lax.scan over KV chunks with online
max/sum), supports causal masking, sliding windows (traced per-layer window
scalars — one scan body serves gemma2's alternating local/global and hymba's
listed global layers), GQA head grouping, logit soft-capping, and a valid-
length bound for cache attention.  Decode uses a single-chunk fast path.

MLA (DeepSeek-V2) implements both the expanded formulation (train/prefill)
and the absorbed formulation for decode (scores taken directly against the
compressed KV cache — the production decode path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL_WINDOW, ModelConfig
from repro.models.layers import apply_rope
from repro.models.param_utils import Init

__all__ = ["chunked_attention", "attn_init", "attn_apply", "mla_init",
           "mla_apply"]

_NEG = -1e30


def _softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q_positions: jax.Array, window, kv_len=None,
                      causal: bool = True, softcap: float | None = None,
                      chunk: int = 1024, scale: float | None = None
                      ) -> jax.Array:
    """q: (B, Sq, H, Dk); k: (B, Skv, KH, Dk); v: (B, Skv, KH, Dv).

    q_positions: (Sq,) global positions of the queries (KV positions are
    0..Skv-1).  window: traced or static int — attend iff
    0 <= q_pos - kv_pos < window (GLOBAL_WINDOW = unbounded).  kv_len:
    optional scalar — KV slots >= kv_len are invalid (decode caches).
    Returns (B, Sq, H, Dv) in q.dtype; softmax math in f32.
    """
    b, sq, h, dk = q.shape
    _, skv, kh, _ = k.shape
    dv = v.shape[-1]
    assert h % kh == 0, (h, kh)
    g = h // kh
    scale = dk ** -0.5 if scale is None else scale
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkc = (skv + pad) // chunk
    if kv_len is None:
        kv_len = skv
    kv_len = jnp.asarray(kv_len, jnp.int32)
    window = jnp.asarray(window, jnp.int32)

    qr = (q.astype(jnp.float32) * scale).reshape(b, sq, kh, g, dk)
    qpos = q_positions.astype(jnp.int32)

    kc = k.reshape(b, nkc, chunk, kh, dk).swapaxes(0, 1)   # (nkc, B, C, KH, D)
    vc = v.reshape(b, nkc, chunk, kh, dv).swapaxes(0, 1)

    m0 = jnp.full((b, sq, kh, g), _NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kh, g, dv), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        ci, kci, vci = xs
        logits = jnp.einsum("bskgd,bckd->bskgc", qr,
                            kci.astype(jnp.float32))       # (B,Sq,KH,G,C)
        logits = _softcap(logits, softcap)
        kvpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        delta = qpos[:, None] - kvpos[None, :]             # (Sq, C)
        ok = kvpos[None, :] < kv_len
        if causal:
            ok = ok & (delta >= 0) & (delta < window)
        else:
            ok = ok & (jnp.abs(delta) < window)
        logits = jnp.where(ok[None, :, None, None, :], logits, _NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        # Probabilities in the K/V dtype (bf16 on TPU): halves the dominant
        # HBM term; the running max/sum stay f32 (flash-attention numerics).
        p = jnp.exp(logits - m_new[..., None]).astype(kci.dtype)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.astype(jnp.float32).sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", p, vci,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    # Flash-attention backward: remat the chunk body so the (nkc, B, Sq, …)
    # probability stack is never saved for autodiff — backward recomputes
    # each chunk's p from q/k (O(S·chunk) live memory instead of O(S·S)).
    body = jax.checkpoint(body, policy=jax.checkpoint_policies
                          .nothing_saveable, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(nkc, dtype=jnp.int32), kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Standard GQA/MHA attention layer
# ---------------------------------------------------------------------------

def attn_init(key: jax.Array, cfg: ModelConfig):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    b = Init(key, jnp.dtype(cfg.param_dtype))
    b.dense("wq", (d, qd), ("embed", "q_heads"))
    b.dense("wk", (d, kvd), ("embed", "kv_heads"))
    b.dense("wv", (d, kvd), ("embed", "kv_heads"))
    b.dense("wo", (qd, d), ("q_heads", "embed"))
    if cfg.qkv_bias:
        b.zeros("bq", (qd,), ("q_heads",))
        b.zeros("bk", (kvd,), ("kv_heads",))
        b.zeros("bv", (kvd,), ("kv_heads",))
    return b.done()


def attn_apply(p, x: jax.Array, *, cfg: ModelConfig, positions: jax.Array,
               window, cache=None, decode_pos=None, causal: bool = True,
               kv_override: tuple | None = None, sc=lambda x, ax: x):
    """x: (B, S, d).  Returns (out (B, S, d), new_cache or (k, v)).

    Modes:
      train/prefill: cache None; returns computed (k, v) for cache fill.
      decode: cache = dict(k=(B, Smax, KH, D), v=..., len=scalar);
              decode_pos = scalar position of the new token(s).
      cross-attention: kv_override = (k, v) precomputed; cache unused.

    Sharding: heads shard over the model axis when divisible; otherwise the
    query sequence shards (attn_seq — sequence parallelism inside attention)
    with the small GQA K/V replicated.  Decode caches shard kv_heads-first,
    falling back to cache_seq.
    """
    bsz, s, d = x.shape
    cdt = x.dtype
    q = x @ p["wq"].astype(cdt)
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
    q = q.reshape(bsz, s, cfg.num_heads, cfg.head_dim)

    if kv_override is None:
        k = x @ p["wk"].astype(cdt)
        v = x @ p["wv"].astype(cdt)
        if "bk" in p:
            k = k + p["bk"].astype(cdt)
            v = v + p["bv"].astype(cdt)
        k = k.reshape(bsz, s, cfg.num_kv_heads, cfg.head_dim)
        v = v.reshape(bsz, s, cfg.num_kv_heads, cfg.head_dim)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        q = apply_rope(q, positions, cfg.rope_theta) if causal else q

    q = sc(q, ("batch", "attn_seq", "heads", None))
    new_cache = (k, v)
    kv_len = None
    if cache is not None:
        # Functional cache update at decode_pos.
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, decode_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, decode_pos, 0, 0))
        k, v = ck, cv
        kv_len = decode_pos + s
        new_cache = dict(k=ck, v=cv)
        k = sc(k, ("batch", "cache_seq", "kv_heads", None))
        v = sc(v, ("batch", "cache_seq", "kv_heads", None))
    else:
        k = sc(k, ("batch", None, "kv_heads", None))
        v = sc(v, ("batch", None, "kv_heads", None))

    out = chunked_attention(q, k.astype(cdt), v.astype(cdt),
                            q_positions=positions, window=window,
                            kv_len=kv_len, causal=causal,
                            softcap=cfg.attn_logit_softcap,
                            chunk=cfg.attn_chunk)
    out = sc(out, ("batch", "attn_seq", "heads", None))
    out = out.reshape(bsz, s, cfg.q_dim) @ p["wo"].astype(cdt)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed KV with decoupled RoPE
# ---------------------------------------------------------------------------

def mla_init(key: jax.Array, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    b = Init(key, jnp.dtype(cfg.param_dtype))
    b.dense("wq", (d, h * qk), ("embed", "q_heads"))
    b.dense("w_dkv", (d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "kv_lora"))
    b.dense("w_uk", (m.kv_lora_rank, h * m.qk_nope_dim), ("kv_lora", "q_heads"))
    b.dense("w_uv", (m.kv_lora_rank, h * m.v_head_dim), ("kv_lora", "q_heads"))
    b.dense("wo", (h * m.v_head_dim, d), ("q_heads", "embed"))
    return b.done()


def mla_apply(p, x: jax.Array, *, cfg: ModelConfig, positions: jax.Array,
              window, cache=None, decode_pos=None, sc=lambda x, ax: x):
    """MLA attention.  cache = dict(c=(B, Smax, lora), kr=(B, Smax, rope))."""
    m = cfg.mla
    bsz, s, d = x.shape
    cdt = x.dtype
    h = cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    scale = qk ** -0.5

    q = (x @ p["wq"].astype(cdt)).reshape(bsz, s, h, qk)
    q = sc(q, ("batch", "attn_seq", "heads", None))
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckr = x @ p["w_dkv"].astype(cdt)                        # (B, S, lora+rope)
    c, kr = ckr[..., :m.kv_lora_rank], ckr[..., m.kv_lora_rank:]
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        # Expanded formulation (train / prefill).
        k_nope = (c @ p["w_uk"].astype(cdt)).reshape(bsz, s, h, m.qk_nope_dim)
        value = (c @ p["w_uv"].astype(cdt)).reshape(bsz, s, h, m.v_head_dim)
        k_nope = sc(k_nope, ("batch", None, "heads", None))
        value = sc(value, ("batch", None, "heads", None))
        kfull = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                      (bsz, s, h, m.qk_rope_dim))], axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(qfull, kfull, value, q_positions=positions,
                                window=window, causal=True,
                                softcap=cfg.attn_logit_softcap,
                                chunk=cfg.attn_chunk, scale=scale)
        out = out.reshape(bsz, s, h * m.v_head_dim) @ p["wo"].astype(cdt)
        return out, (c, kr)

    # Absorbed decode: score directly against the compressed cache.
    cc = jax.lax.dynamic_update_slice(
        cache["c"], c.astype(cache["c"].dtype), (0, decode_pos, 0))
    ckr_c = jax.lax.dynamic_update_slice(
        cache["kr"], kr.astype(cache["kr"].dtype), (0, decode_pos, 0))
    kv_len = decode_pos + s
    wk = p["w_uk"].astype(cdt).reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    q_c = jnp.einsum("bshn,lhn->bshl", q_nope, wk)          # absorb W_uk
    logits = (jnp.einsum("bshl,btl->bsht", q_c.astype(jnp.float32),
                         cc.astype(jnp.float32)) +
              jnp.einsum("bshr,btr->bsht", q_rope.astype(jnp.float32),
                         ckr_c.astype(jnp.float32))) * scale
    tpos = jnp.arange(cc.shape[1], dtype=jnp.int32)
    qpos = positions.astype(jnp.int32)
    ok = ((tpos[None, :] < kv_len) & (qpos[:, None] - tpos[None, :] >= 0) &
          (qpos[:, None] - tpos[None, :] < jnp.asarray(window, jnp.int32)))
    logits = jnp.where(ok[None, :, None, :], logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx_c = jnp.einsum("bsht,btl->bshl", probs,
                       cc.astype(jnp.float32)).astype(cdt)  # (B,S,H,lora)
    wv = p["w_uv"].astype(cdt).reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bshl,lhv->bshv", ctx_c, wv)           # absorb W_uv
    out = out.reshape(bsz, s, h * m.v_head_dim) @ p["wo"].astype(cdt)
    return out, dict(c=cc, kr=ckr_c)
