"""The paper's evaluation workloads: AlexNet and VGG16 with MNF inference.

Two execution paths over identical params, both dispatched through
``repro.engine`` (DESIGN.md §3):
  * dense  — the engine's dense backend + ReLU (the oracle),
  * mnf    — event-resident: one ``EventStream`` threads the whole network.
             Each conv's fire phase emits a conv stream
             (``engine.fire_conv``) that the next conv consumes directly:
             strip-aligned (8-pixel row strips) whenever the consumer can
             ride the fused-tap kernel — one launch per layer, 8x smaller
             event grid — and pixel-granular per-tap row-group gathers
             otherwise (DESIGN.md §5/§6).  The dense feature map is never
             materialized between conv layers.  Pools run **in the event
             domain** too (``engine.maxpool2d`` — a segment max over the
             stream's events, bit-identical to the dense pool, DESIGN.md
             §7), so conv→pool→conv boundaries carry no dense twin and no
             re-encode.  The conv→FC seam re-tiles the conv stream to the
             flattened (B, H·W·C) view by static address plan
             (``EventStream.retile_fc``, DESIGN.md §12) and FC layers
             chain ``EventStream``s onward — the whole forward has zero
             densify points, input encode to logits.

``make_cnn_pipeline`` wraps the whole forward in a **single jitted
function** with a donated input buffer — one jit per network, no per-layer
dispatch or retracing (DESIGN.md §5.1).  ``run_with_stats`` rides the same
single-jit body and instruments every layer with the event counts the cost
model needs: input events fired (non-zero activations), MACs a dense
accelerator would do, and MACs the MNF multiply phase actually does
(Σ_events touched_outputs × C_out — Algorithm 1's walk length).  All
counters derive from ``EventStream``'s compacted event values, so the
instrumented pipeline runs twin-free — same event-resident graph as
serving, just with counter outputs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro import engine
from repro.core.fire import FireConfig, fire
from repro.core.mnf_conv import conv_out_size
from repro.models.layers import max_pool_nhwc

__all__ = ["ConvSpec", "FCSpec", "PoolSpec", "CNNSpec", "ALEXNET", "VGG16",
           "ALEXNET_DS", "ALEXNET_FF", "VGG16_DS", "MINI", "MINI_S4",
           "conv_downsampled", "init_cnn_params", "cnn_forward",
           "make_cnn_forward", "make_cnn_pipeline", "run_with_stats",
           "layer_dense_macs", "chain_boundary_summary", "fc_in_events"]


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    out_ch: int
    k: int
    stride: int = 1
    padding: int = 0


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    k: int = 2
    stride: int = 2


@dataclasses.dataclass(frozen=True)
class FCSpec:
    out: int


@dataclasses.dataclass(frozen=True)
class CNNSpec:
    name: str
    input_size: int
    in_ch: int
    layers: tuple
    num_classes: int = 1000

    def scaled(self, input_size: int) -> "CNNSpec":
        """Same topology at a smaller input resolution (CPU tests)."""
        return dataclasses.replace(self, input_size=input_size)


ALEXNET = CNNSpec(
    "alexnet", 224, 3,
    (ConvSpec(96, 11, 4, 2), PoolSpec(3, 2),
     ConvSpec(256, 5, 1, 2), PoolSpec(3, 2),
     ConvSpec(384, 3, 1, 1), ConvSpec(384, 3, 1, 1), ConvSpec(256, 3, 1, 1),
     PoolSpec(3, 2),
     FCSpec(4096), FCSpec(4096), FCSpec(1000)))

VGG16 = CNNSpec(
    "vgg16", 224, 3,
    (ConvSpec(64, 3, 1, 1), ConvSpec(64, 3, 1, 1), PoolSpec(),
     ConvSpec(128, 3, 1, 1), ConvSpec(128, 3, 1, 1), PoolSpec(),
     ConvSpec(256, 3, 1, 1), ConvSpec(256, 3, 1, 1), ConvSpec(256, 3, 1, 1),
     PoolSpec(),
     ConvSpec(512, 3, 1, 1), ConvSpec(512, 3, 1, 1), ConvSpec(512, 3, 1, 1),
     PoolSpec(),
     ConvSpec(512, 3, 1, 1), ConvSpec(512, 3, 1, 1), ConvSpec(512, 3, 1, 1),
     PoolSpec(),
     FCSpec(4096), FCSpec(4096), FCSpec(1000)))


def conv_downsampled(spec: CNNSpec, *, k: int = 3) -> CNNSpec:
    """All-conv downsampling variant: every max-pool becomes a stride-2
    k×k conv (padding k//2, channel-preserving) — the "VGG-style stride-2
    block" of all-convolutional nets (Springenberg et al.) and of SCNN-class
    sparse accelerators, where the downsampling layer itself must ride the
    compressed dataflow.  These are exactly the layers the stride-2 strip
    plan keeps on the fused event path (DESIGN.md §6): each replacement
    conv consumes its producer's strip stream with interleaved half-strip
    gathers instead of falling back to the pixel-granular grid.
    """
    layers = []
    c = spec.in_ch
    for layer in spec.layers:
        if isinstance(layer, PoolSpec):
            layers.append(ConvSpec(c, k, 2, k // 2))
        else:
            layers.append(layer)
            if isinstance(layer, ConvSpec):
                c = layer.out_ch
    return dataclasses.replace(spec, name=spec.name + "_ds",
                               layers=tuple(layers))


#: Downsampling variants of the paper workloads: pools replaced by stride-2
#: conv blocks.  At the CPU harness sizes (ALEXNET_DS@68, VGG16_DS@32) their
#: chained forwards put every eligible downsampling conv on the fused strip
#: path — the layer class that used to be stride-1-only fallback.
ALEXNET_DS = conv_downsampled(ALEXNET)
VGG16_DS = conv_downsampled(VGG16)

#: Fully-fused AlexNet: the geometry variant whose *entire* chained forward
#: rides the fused strip kernel — zero pixel-granular conv layers, the
#: stride-4 conv1 included (one launch instead of its 121 per-tap event
#: matmuls; the chained path strip-encodes the input image itself).  Two
#: deviations from stock AlexNet@224 make every layer width tile into
#: 8-pixel strips, and both are forced by arithmetic, not taste:
#:   * conv1 padding 2 -> 4: at stride 4 an input width of 8m yields
#:     OW = 2m - 1 with p = 2 (odd — never a strip multiple at ANY input
#:     size, 224 included), but OW = (W - 3)//4 + 1 with p = 4;
#:   * input 224 -> 256 with stride-2 conv downsampling blocks: the three
#:     halvings after conv1 need conv1's output width to be 8·2³ = 64,
#:     i.e. W = 256 (the smallest fully-fused size; stock 224 -> 56 -> 28
#:     breaks at the second stage).
#: Same depth/channel plan as ALEXNET_DS otherwise.
ALEXNET_FF = CNNSpec(
    "alexnet_ff", 256, 3,
    (ConvSpec(96, 11, 4, 4), ConvSpec(96, 3, 2, 1),
     ConvSpec(256, 5, 1, 2), ConvSpec(256, 3, 2, 1),
     ConvSpec(384, 3, 1, 1), ConvSpec(384, 3, 1, 1), ConvSpec(256, 3, 1, 1),
     ConvSpec(256, 3, 2, 1),
     FCSpec(4096), FCSpec(4096), FCSpec(1000)))

#: Seconds-scale smoke network exercising every chain seam — conv→conv,
#: the event-native conv→pool→conv boundary, pool→FC.  The serving-tier
#: smoke loop and the benchmark smoke both bucket-serve this net.
MINI = CNNSpec("mini", 8, 3,
               (ConvSpec(8, 3, 1, 1), ConvSpec(8, 3, 1, 1), PoolSpec(),
                ConvSpec(8, 3, 1, 1), FCSpec(10)), num_classes=10)

#: Stride-4 smoke network: a strip-eligible stride-4 downsampling conv
#: (32 -> 8, the AlexNet-conv1 layer class at toy scale) between two
#: stride-1 convs.  Every conv is strip-eligible, so its chained forward
#: must report zero fallback_decode — the CI gate for the stride-4 plan
#: (``kernel_bench --smoke``).
MINI_S4 = CNNSpec("mini_s4", 32, 3,
                  (ConvSpec(8, 3, 1, 1), ConvSpec(8, 3, 4, 1),
                   ConvSpec(8, 3, 1, 1), FCSpec(10)), num_classes=10)


def _trace_shapes(spec: CNNSpec):
    """(H, W, C) entering each layer, plus flattened FC input size."""
    h = w = spec.input_size
    c = spec.in_ch
    shapes = []
    for layer in spec.layers:
        shapes.append((h, w, c))
        if isinstance(layer, ConvSpec):
            h = conv_out_size(h, layer.k, layer.stride, layer.padding)
            w = conv_out_size(w, layer.k, layer.stride, layer.padding)
            c = layer.out_ch
        elif isinstance(layer, PoolSpec):
            h = (h - layer.k) // layer.stride + 1
            w = (w - layer.k) // layer.stride + 1
        elif isinstance(layer, FCSpec):
            h, w, c = 1, 1, layer.out
    return shapes


def init_cnn_params(key: jax.Array, spec: CNNSpec,
                    weight_sparsity: float = 0.0):
    """He-initialized params; optional unstructured weight pruning (the
    paper prunes to ~50-60% weight density before deployment)."""
    shapes = _trace_shapes(spec)
    params = []
    for i, layer in enumerate(spec.layers):
        k = jax.random.fold_in(key, i)
        h, w, c = shapes[i]
        if isinstance(layer, ConvSpec):
            fan_in = layer.k * layer.k * c
            wgt = jax.random.normal(
                k, (layer.k, layer.k, c, layer.out_ch), jnp.float32)
            wgt = wgt * (2.0 / fan_in) ** 0.5
        elif isinstance(layer, FCSpec):
            fan_in = h * w * c
            wgt = jax.random.normal(k, (fan_in, layer.out), jnp.float32)
            wgt = wgt * (2.0 / fan_in) ** 0.5
        else:
            params.append(None)
            continue
        if weight_sparsity > 0.0:
            keep = jax.random.uniform(jax.random.fold_in(k, 1), wgt.shape)
            wgt = jnp.where(keep >= weight_sparsity, wgt, 0.0)
        params.append(wgt)
    return params


def _touched_outputs(h: int, w: int, k: int, stride: int, padding: int):
    """(H, W) map: #output positions each input pixel contributes to."""
    oy = conv_out_size(h, k, stride, padding)
    ox = conv_out_size(w, k, stride, padding)
    iy = jnp.arange(h)[:, None]
    ix = jnp.arange(w)[None, :]

    def jumps(i, osz):
        lo = jnp.maximum(0, -(-(i + padding - k + 1) // stride))
        hi = jnp.minimum(osz - 1, (i + padding) // stride)
        return jnp.maximum(hi - lo + 1, 0)

    return jumps(iy, oy) * jumps(ix, ox)


def layer_dense_macs(spec: CNNSpec):
    """Per-compute-layer dense MAC counts (what a dense accelerator does)."""
    shapes = _trace_shapes(spec)
    out = []
    for i, layer in enumerate(spec.layers):
        h, w, c = shapes[i]
        if isinstance(layer, ConvSpec):
            oy = conv_out_size(h, layer.k, layer.stride, layer.padding)
            ox = conv_out_size(w, layer.k, layer.stride, layer.padding)
            out.append(oy * ox * layer.k * layer.k * c * layer.out_ch)
        elif isinstance(layer, FCSpec):
            out.append(h * w * c * layer.out)
    return out


def chain_boundary_summary(spec: CNNSpec, *, batch: int = 1,
                           fire_cfg: FireConfig = FireConfig(),
                           engine_cfg: engine.EngineConfig | None = None
                           ) -> dict:
    """Static per-boundary accounting of the chained pipeline.

    Shape-derived (no tracing): how many compute layers of each kind, how
    many pool boundaries ride the event-native segment max
    (``pool_events``), how many conv→FC seams ride the re-tiler
    (``retile``), and how many densify points remain on the chain
    (``densify`` — dense-pool fallbacks plus re-tile-ineligible FC seams;
    0 when every boundary is eligible, the DESIGN.md §7/§12 invariant
    serving and benchmarks report).  ``routes`` lists, in chain order, the
    routing decision of every boundary that consumes an EventStream — the
    same ``engine.route_conv`` / ``engine.route_pool`` /
    ``engine.route_linear`` calls the dispatch makes (DESIGN.md §11), so
    serving's boundary report can state each compiled boundary's route
    without tracing.
    """
    cfg = _layer_cfg(engine_cfg, mnf=True, fire_cfg=fire_cfg)
    conv_base = cfg.replace(blk_m=1, blk_k=min(8, cfg.blk_k))
    shapes = _trace_shapes(spec)
    out = dict(conv=0, fc=0, pool=0, pool_events=0, densify=0,
               input_encode=0, retile=0, routes=[])
    # Mirrors _forward's chained dataflow: a pool sees a *conv stream* only
    # when fed by a conv or by a pool that itself chained; a conv with a
    # dense input (the chain head) strip-encodes it when the fused kernel
    # can consume it (``input_encode`` counts those seams), and FC streams
    # take the dense-pool fallback.  ``blk_m`` tracks the granularity of
    # the stream currently in flight — what _next_conv_blk_m made the
    # producer emit.
    conv_stream_in = False
    fc_stream_in = False
    blk_m = 1
    for i, layer in enumerate(spec.layers):
        h, w, c = shapes[i]
        nxt = spec.layers[i + 1] if i + 1 < len(spec.layers) else None
        if isinstance(layer, ConvSpec):
            out["conv"] += 1
            if not conv_stream_in:
                bm_in = _input_stream_blk_m(layer, (batch, h, w, c),
                                            conv_base)
                if bm_in:
                    out["input_encode"] += 1
                    conv_stream_in = True
                    blk_m = bm_in
            if conv_stream_in:
                dec = engine.route_conv(
                    (batch, h, w, c), (layer.k, layer.k, c, layer.out_ch),
                    conv_base, stride=layer.stride, padding=layer.padding,
                    blk_m=blk_m)
                out["routes"].append(dict(
                    op="conv2d", route=dec.route, occupancy=dec.occupancy,
                    est_event_cost=dec.est_event_cost,
                    est_dense_cost=dec.est_dense_cost, source=dec.source,
                    shape_class=f"k{layer.k}s{layer.stride}"))
            oy = conv_out_size(h, layer.k, layer.stride, layer.padding)
            ox = conv_out_size(w, layer.k, layer.stride, layer.padding)
            blk_m = _next_conv_blk_m(nxt, (batch, oy, ox, layer.out_ch))
            conv_stream_in = True
        elif isinstance(layer, FCSpec):
            out["fc"] += 1
            if conv_stream_in or fc_stream_in:
                kf = h * w * c
                reason = None
                if conv_stream_in:
                    reason = engine.retile_ineligible_reason(
                        (batch, h, w, c), blk_m,
                        min(conv_base.blk_k, max(c, 1)))
                dec = engine.route_linear(batch, kf, layer.out, cfg,
                                          eligible=reason is None)
                rec = dict(op="linear", route=dec.route,
                           occupancy=dec.occupancy,
                           est_event_cost=dec.est_event_cost,
                           est_dense_cost=dec.est_dense_cost,
                           source=dec.source,
                           shape_class=engine.linear_shape_class(
                               batch, kf, layer.out))
                if conv_stream_in and reason is None:
                    rec["retile"] = True
                    out["retile"] += 1
                if reason is not None:
                    rec["reason"] = reason
                    out["densify"] += 1
                out["routes"].append(rec)
            conv_stream_in = False
            fc_stream_in = layer is not spec.layers[-1]
        elif isinstance(layer, PoolSpec):
            out["pool"] += 1
            if conv_stream_in and engine.pool_ineligible_reason(
                    (batch, h, w, c), layer.k, layer.stride,
                    conv_base) is None:
                out["pool_events"] += 1
                dec = engine.route_pool((batch, h, w, c), layer.k,
                                        layer.stride, conv_base,
                                        blk_m=blk_m)
                out["routes"].append(dict(
                    op="maxpool2d", route=dec.route,
                    occupancy=dec.occupancy,
                    est_event_cost=dec.est_event_cost,
                    est_dense_cost=dec.est_dense_cost, source=dec.source,
                    shape_class=f"k{layer.k}s{layer.stride}c{c}"))
                oh = (h - layer.k) // layer.stride + 1
                ow = (w - layer.k) // layer.stride + 1
                blk_m = _next_conv_blk_m(nxt, (batch, oh, ow, c))
            else:
                out["densify"] += 1
                conv_stream_in = False
    return out


def _layer_cfg(base: engine.EngineConfig | None, *, mnf: bool,
               fire_cfg: FireConfig) -> engine.EngineConfig:
    cfg = base or engine.EngineConfig(backend="block")
    if not mnf:
        cfg = cfg.replace(backend="dense")
    return cfg.replace(threshold=fire_cfg.threshold,
                       magnitude=fire_cfg.magnitude,
                       int8_events=cfg.int8_events
                       or fire_cfg.quantize_to_int8)


def _dense(x) -> jax.Array:
    return x.dense() if isinstance(x, engine.EventStream) else x


def _dense_nhwc(x) -> jax.Array:
    return x.dense_nhwc() if isinstance(x, engine.EventStream) else x


def _next_conv_blk_m(nxt, out_shape: tuple) -> int:
    """Granularity of the stream a fired conv layer emits, chosen from its
    *consumer*: strip-aligned (STRIP_W-pixel row strips — the fused-tap
    kernel's unit, one launch per layer and an 8x smaller event grid) when
    the next layer is a strip-eligible conv or a window-eligible pool (the
    window-major pool grid consumes strip streams, DESIGN.md §7),
    pixel-granular otherwise.  ``out_shape`` is the emitted map's NHWC
    shape."""
    out_w = out_shape[2]
    if isinstance(nxt, ConvSpec) and engine.strip_eligible(
            out_w, nxt.k, nxt.stride, nxt.padding, co=nxt.out_ch):
        return engine.STRIP_W
    if isinstance(nxt, PoolSpec) and engine.pool_window_ineligible_reason(
            tuple(out_shape), nxt.k, nxt.stride, engine.STRIP_W) is None:
        return engine.STRIP_W
    return 1


def _input_stream_blk_m(layer: "ConvSpec", x_shape: tuple,
                        cfg: engine.EngineConfig) -> int:
    """Granularity at which the chained path encodes a *dense* conv input
    (the input image at the chain head, or a densified seam): STRIP_W when
    the conv is strip-eligible off an encoded strip stream *and* the
    boundary routes to the event path, 0 = stay dense (the per-tap dense
    dispatch).  This is what puts AlexNet-class stride-4 first layers on
    the fused kernel — 1 launch instead of k² — and it is bitwise-safe
    because the encoded stream is lossless at threshold 0 and the fused
    kernel is bit-exact against the per-tap oracle the dense dispatch runs
    (DESIGN.md §6).  Pixel-granular encoding is never chosen: it would
    trade the dense per-tap path for an identical-launch-count event
    per-tap path.
    """
    b, h, w, c = x_shape
    if not engine.strip_eligible(w, layer.k, layer.stride, layer.padding,
                                 co=layer.out_ch):
        return 0
    dec = engine.route_conv((b, h, w, c),
                            (layer.k, layer.k, c, layer.out_ch), cfg,
                            stride=layer.stride, padding=layer.padding,
                            blk_m=engine.STRIP_W)
    return engine.STRIP_W if dec.route == "strip" else 0


def _next_boundary_route(nxt, out_shape: tuple, cfg: engine.EngineConfig,
                         blk_m: int):
    """The routing decision the *next* boundary will take on the stream a
    layer is about to emit — the same ``engine.route_conv`` /
    ``engine.route_pool`` / ``engine.route_linear`` call the dispatch
    makes, with identical inputs, so the planner's keep-twin choices and
    the dispatcher's routes can never disagree (DESIGN.md §11)."""
    if isinstance(nxt, ConvSpec):
        return engine.route_conv(
            out_shape, (nxt.k, nxt.k, out_shape[3], nxt.out_ch), cfg,
            stride=nxt.stride, padding=nxt.padding, blk_m=blk_m)
    if isinstance(nxt, FCSpec):
        b, oh, ow, c = out_shape
        return engine.route_linear(b, oh * ow * c, nxt.out, cfg)
    return engine.route_pool(out_shape, nxt.k, nxt.stride, cfg, blk_m=blk_m)


def _fc_chains(nxt, out_shape: tuple, cfg: engine.EngineConfig,
               blk_m: int) -> bool:
    """Whether a conv/pool stream emitted at ``blk_m`` granularity chains
    into a next-layer FC through the re-tiler — the same
    ``retile_ineligible_reason`` rule ``engine.linear`` applies at
    dispatch, so the planner drops the twin exactly when the seam will
    stay events-only (DESIGN.md §12)."""
    if not isinstance(nxt, FCSpec):
        return False
    blk_k = min(cfg.blk_k, max(out_shape[-1], 1))
    return engine.retile_ineligible_reason(tuple(out_shape), blk_m,
                                           blk_k) is None


def _pixel_events(x):
    """(B, H, W) fired-activation counts per pixel + the NHWC shape.

    Stream inputs derive the map from the compacted event values
    (twin-free — DESIGN.md §6); dense inputs count non-zeros directly.
    """
    if isinstance(x, engine.EventStream):
        b, h, w, c = x.logical_shape
        return x.per_row_scalar_events().reshape(b, h, w), (b, h, w, c)
    nz = jnp.sum(jnp.abs(x) > 0, axis=-1, dtype=jnp.float32)
    return nz, x.shape


def fc_in_events(x, threshold: float = 0.0) -> jax.Array:
    """Events entering an FC boundary — the one counting rule CNN and MLP
    stats share (Algorithm 2 charges ``in_events * out`` MACs).

    Stream inputs count their compacted non-zero event values (twin-free);
    dense inputs count activations at the *configured* fire threshold,
    matching the chained stream's semantics (its events are the
    supra-threshold survivors).  Counting ``|x| > 0`` on the dense side
    would also count int8 dequantization artifacts below the threshold and
    diverge from the chained path for threshold > 0; int8 *streams* count
    quantized events, the one documented divergence (DESIGN.md §12).
    """
    if isinstance(x, engine.EventStream):
        return x.num_scalar_events
    return jnp.sum(jnp.abs(x) > threshold, dtype=jnp.float32)


def _density(x) -> jax.Array:
    """Fired fraction of an activation (stream: twin-free event count).

    Zero-row streams / empty tensors (dead layer, empty batch) have no
    elements; their density is defined as 0, not 0/0.
    """
    if isinstance(x, engine.EventStream):
        m, k = x.shape
        if m * k == 0:
            return jnp.zeros((), jnp.float32)
        return x.num_scalar_events / (m * k)
    if x.size == 0:
        return jnp.zeros((), jnp.float32)
    return jnp.mean(jnp.abs(x) > 0)


def _forward(params, x, spec: CNNSpec, *, mnf: bool, fire_cfg: FireConfig,
             cfg: engine.EngineConfig, chain: bool, stats: list | None = None):
    """The one traced forward body behind ``cnn_forward`` /
    ``make_cnn_pipeline`` / ``run_with_stats``.

    ``chain=True`` threads one EventStream through conv→fire→conv→…→FC:
    conv→conv boundaries stay event-only (the fired twin is dropped) and
    pools run in the event domain (``engine.maxpool2d`` segment max,
    DESIGN.md §7) — conv→pool→conv carries no twin and no re-encode, so
    the chain densifies nowhere between the first conv and the FC head.
    Only an *ineligible* pool (magnitude fire, degenerate window) falls
    back to the dense pool + re-encode, visibly.  ``chain=False`` is the
    per-layer round-trip twin (dense at every boundary, identical compute
    geometry) that the chained path is measured against — its dense pool
    is the event pool's bitwise oracle.  ``stats`` (a list to append to)
    requests per-layer event accounting, derived from the compacted event
    values themselves on the chained path (twin-free — no dense twin, no
    decode).
    """
    layers = spec.layers
    # The conv *dispatch* config stays pixel-granular (blk_m == 1) so the
    # round-trip twin multiplies identical tiles in identical order as the
    # chained path — bit-for-bit equality, not just allclose (DESIGN.md §5).
    # The chained path's granularity rides the *stream*: fired streams are
    # strip-aligned (blk_m == STRIP_W) whenever the consuming layer can ride
    # the fused-tap kernel, which only interleaves exact zeros into the same
    # reduction tree, so bitwise equality with the per-tap twin survives
    # (DESIGN.md §6).
    conv_base = cfg.replace(blk_m=1, blk_k=min(8, cfg.blk_k))
    for i, (layer, wgt) in enumerate(zip(layers, params)):
        nxt = layers[i + 1] if i + 1 < len(layers) else None
        if isinstance(layer, ConvSpec):
            if chain and not isinstance(x, engine.EventStream):
                # Chain head (or densified seam): strip-encode the dense
                # input when this conv can ride the fused kernel off it —
                # the stride-4 AlexNet conv1 goes from k² per-tap event
                # matmuls to one launch.  Lossless at threshold 0, bitwise
                # vs the dense dispatch (see _input_stream_blk_m).
                bm_in = _input_stream_blk_m(layer, tuple(x.shape), conv_base)
                if bm_in:
                    x = engine.EventStream.encode_nhwc(
                        x, blk_k=min(conv_base.blk_k, max(x.shape[-1], 1)),
                        blk_m=bm_in, keep_dense=False)
            ci = x.logical_shape[-1] if isinstance(x, engine.EventStream) \
                else x.shape[-1]
            ccfg = conv_base.replace(threshold=0.0).for_conv(ci)
            if stats is not None:
                nzmap, (b, h, w, c) = _pixel_events(x)   # twin-free on chain
                touched = _touched_outputs(h, w, layer.k, layer.stride,
                                           layer.padding)
                stats.append(dict(
                    event_macs=jnp.sum(nzmap * touched[None].astype(
                        jnp.float32)) * layer.out_ch,
                    in_events=jnp.sum(nzmap)))
            acc = engine.conv2d(x, wgt, cfg=ccfg, stride=layer.stride,
                                padding=layer.padding)
            if chain:
                # Drop the dense twin at conv→conv boundaries, at
                # conv→pool boundaries the event-native pool will consume,
                # AND at conv→FC seams the re-tiler serves (events-only —
                # instrumentation reads event values, never the twin);
                # keep it only where an ineligible consumer genuinely
                # reads it densely.
                pool_chains = (isinstance(nxt, PoolSpec)
                               and engine.pool_ineligible_reason(
                                   tuple(acc.shape), nxt.k, nxt.stride,
                                   conv_base) is None)
                bm_next = _next_conv_blk_m(nxt, tuple(acc.shape))
                keep = not (isinstance(nxt, ConvSpec) or pool_chains
                            or _fc_chains(nxt, tuple(acc.shape), conv_base,
                                          bm_next))
                if not keep and conv_base.route != "auto":
                    # Adaptive/forced routing may send the next boundary
                    # dense; keep the twin so its ``dense_nhwc`` is a free
                    # read, not a decode.  Same decision function the
                    # dispatch uses — plan and dispatch cannot disagree.
                    keep = not _next_boundary_route(
                        nxt, tuple(acc.shape), conv_base, bm_next).is_event
                x = engine.fire_conv(acc, conv_base, keep_dense=keep,
                                     blk_m=bm_next)
            else:
                x = fire(acc, fire_cfg)              # fire phase == ReLU @ 0
            if stats is not None:
                stats[-1]["out_density"] = _density(x)
        elif isinstance(layer, PoolSpec):
            if chain and isinstance(x, engine.EventStream) \
                    and engine.pool_ineligible_reason(
                        x, layer.k, layer.stride, conv_base) is None:
                # Event-native pool (DESIGN.md §7): segment max over the
                # stream's events, re-emitted at the granularity the
                # consumer wants — conv→pool→conv stays events-only (no
                # twin, no re-encode).  The pooled twin is kept only when
                # the FC head (or the network output) reads it densely.
                c = x.logical_shape[-1]
                oh = (x.logical_shape[1] - layer.k) // layer.stride + 1
                pw = (x.logical_shape[2] - layer.k) // layer.stride + 1
                pooled_shape = (x.logical_shape[0], oh, pw, c)
                # Emitted granularity from the consumer (same rule as the
                # conv fire): strips for a strip-eligible conv *or* a
                # window-eligible next pool, pixels otherwise.
                pcfg = conv_base.for_pool(c).replace(
                    blk_m=_next_conv_blk_m(nxt, pooled_shape))
                keep_pool = not (isinstance(nxt, ConvSpec)
                                 or _fc_chains(nxt, pooled_shape, conv_base,
                                               pcfg.blk_m))
                if not keep_pool and conv_base.route != "auto":
                    keep_pool = not _next_boundary_route(
                        nxt, pooled_shape, conv_base,
                        pcfg.blk_m).is_event
                x = engine.maxpool2d(x, layer.k, layer.stride, cfg=pcfg,
                                     keep_dense=keep_pool)
            else:
                pooled = max_pool_nhwc(_dense_nhwc(x), layer.k, layer.stride)
                if chain and isinstance(nxt, ConvSpec):
                    # Dense-pool fallback (round-trip twin, or a stream the
                    # event pool cannot consume): re-encode at the
                    # granularity the next conv consumes.
                    x = engine.EventStream.encode_nhwc(
                        pooled, blk_k=conv_base.blk_k,
                        blk_m=_next_conv_blk_m(nxt, tuple(pooled.shape)),
                        keep_dense=False)
                else:
                    x = pooled
        elif isinstance(layer, FCSpec):
            # Conv-derived inputs (a chained conv stream, or the round-trip
            # twin's dense NHWC map) dispatch under the *re-tiled* geometry:
            # blk_m = 1 and the conv chain's channel-clamped blk_k, so the
            # twin's encode of the flattened map produces the exact
            # BlockEvents the re-tiler emits — bitwise equality across the
            # conv→FC seam, not just allclose (DESIGN.md §12).  FC→FC
            # boundaries keep the plain cfg (the fire emitted that
            # geometry).
            if isinstance(x, engine.EventStream) \
                    and x.logical_shape is not None:
                fcfg = cfg.replace(threshold=0.0, blk_m=1, blk_k=x.blk_k)
            elif not isinstance(x, engine.EventStream) and x.ndim == 4:
                fcfg = cfg.replace(
                    threshold=0.0, blk_m=1,
                    blk_k=min(conv_base.blk_k, max(x.shape[-1], 1)))
            else:
                fcfg = cfg.replace(threshold=0.0)
            flat = x if isinstance(x, engine.EventStream) \
                else x.reshape(x.shape[0], -1)
            if stats is not None:
                in_ev = fc_in_events(flat, fire_cfg.threshold)
                stats.append(dict(event_macs=in_ev * layer.out,  # Algorithm 2
                                  in_events=in_ev))
            acc = engine.linear(flat, wgt, cfg=fcfg)
            last = layer is spec.layers[-1]
            if last:
                x = acc
            elif chain:
                x = engine.fire(acc, cfg, keep_dense=False)
            else:
                x = fire(acc, fire_cfg)
            if stats is not None:
                stats[-1]["out_density"] = _density(x)
    if isinstance(x, engine.EventStream) and x.logical_shape is not None:
        return x.dense_nhwc()        # conv-final spec: keep the NHWC view
    return _dense(x)


def cnn_forward(params, x: jax.Array, spec: CNNSpec, *, mnf: bool = True,
                fire_cfg: FireConfig = FireConfig(),
                engine_cfg: engine.EngineConfig | None = None,
                chain: bool | None = None):
    """x: (B, H, W, C) -> logits (B, classes).  mnf=False is the oracle.

    All compute dispatches through ``repro.engine``; ``engine_cfg`` picks
    the backend (default: pure-jnp block events).  ``chain`` selects the
    event-resident path (default: on for MNF; int8 requantization chains
    too — fire emits int8 event values and the round-trip twin is the
    fake-quant forward, DESIGN.md §12); ``chain=False`` forces the
    per-layer dense round-trip twin.
    """
    cfg = _layer_cfg(engine_cfg, mnf=mnf, fire_cfg=fire_cfg)
    if chain is None:
        chain = mnf
    return _forward(params, x, spec, mnf=mnf, fire_cfg=fire_cfg, cfg=cfg,
                    chain=chain and mnf)


def make_cnn_forward(spec: CNNSpec, *, mnf: bool = True,
                     fire_cfg: FireConfig = FireConfig(),
                     engine_cfg: engine.EngineConfig | None = None,
                     chain: bool | None = None):
    """The un-jitted whole-network closure: ``fwd(params, x) -> logits``.

    The seam the serving tier wraps: a bucket-shaped jit, or a
    batch-parallel ``shard_map`` body (each device runs this closure over
    its batch shard — the forward is per-sample independent, so the
    sharded result is bitwise the unsharded one).  ``make_cnn_pipeline``
    is exactly ``jax.jit`` of this.
    """
    cfg = _layer_cfg(engine_cfg, mnf=mnf, fire_cfg=fire_cfg)
    if chain is None:
        chain = mnf
    chain = chain and mnf

    def fwd(params, x):
        return _forward(params, x, spec, mnf=mnf, fire_cfg=fire_cfg,
                        cfg=cfg, chain=chain)

    return fwd


def make_cnn_pipeline(spec: CNNSpec, *, mnf: bool = True,
                      fire_cfg: FireConfig = FireConfig(),
                      engine_cfg: engine.EngineConfig | None = None,
                      chain: bool | None = None, donate: bool = True):
    """One jitted forward per network: ``fn(params, x) -> logits``.

    The whole conv→fire→…→FC pipeline compiles as a single ``jax.jit`` —
    no per-layer dispatch, one trace per input shape (DESIGN.md §5.1).
    ``donate=True`` donates the input image buffer (serving never reuses a
    consumed batch; pass ``donate=False`` when the caller does).
    """
    fwd = make_cnn_forward(spec, mnf=mnf, fire_cfg=fire_cfg,
                           engine_cfg=engine_cfg, chain=chain)
    return jax.jit(fwd, donate_argnums=(1,) if donate else ())


def _static_layer_stats(spec: CNNSpec, batch: int):
    """Shape-derived stats fields (no tracing): dense MACs, element counts.

    ``dense_macs`` comes from :func:`layer_dense_macs` (one accounting,
    shared with the cost model) scaled by the batch size.
    """
    shapes = _trace_shapes(spec)
    macs = iter(layer_dense_macs(spec))
    out = []
    for i, layer in enumerate(spec.layers):
        h, w, c = shapes[i]
        if isinstance(layer, (ConvSpec, FCSpec)):
            out.append(dict(
                kind="conv" if isinstance(layer, ConvSpec) else "fc",
                c_out=layer.out_ch if isinstance(layer, ConvSpec)
                else layer.out,
                dense_macs=float(batch * next(macs)),
                in_elems=float(batch * h * w * c)))
    return out


@functools.lru_cache(maxsize=64)
def _stats_pipeline(spec: CNNSpec, fire_cfg: FireConfig,
                    cfg: engine.EngineConfig):
    """Cached single-jit instrumented forward for ``run_with_stats``."""

    def fwd(params, x):
        stats: list = []
        logits = _forward(params, x, spec, mnf=True, fire_cfg=fire_cfg,
                          cfg=cfg, chain=True, stats=stats)
        return logits, tuple(stats)

    return jax.jit(fwd)


def run_with_stats(params, x: jax.Array, spec: CNNSpec,
                   fire_cfg: FireConfig = FireConfig(),
                   engine_cfg: engine.EngineConfig | None = None):
    """MNF forward + per-layer event accounting (via ``repro.engine``).

    One jitted call per (network, shape): the traced body returns per-layer
    event counters alongside the logits; shape-only quantities are derived
    statically.  Returns (logits, stats list).  Each compute layer's stats:
      dense_macs  — MACs of the dense dataflow
      event_macs  — MACs the MNF multiply phase performs (Algorithm 1 walk)
      in_events   — input events fired into the layer
      in_elems    — dense input element count
      out_density — fraction of outputs that fire
    """
    cfg = _layer_cfg(engine_cfg, mnf=True, fire_cfg=fire_cfg)
    logits, traced = _stats_pipeline(spec, fire_cfg, cfg)(params, x)
    stats = []
    for st, tr in zip(_static_layer_stats(spec, x.shape[0]), traced):
        d = dict(st)
        d.update({k: float(v) for k, v in tr.items()})
        d["avg_touched"] = (
            d["event_macs"] / max(d["in_events"] * d["c_out"], 1.0)
            if d["kind"] == "conv" else 1.0)
        stats.append(d)
    return logits, stats
