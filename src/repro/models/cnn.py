"""The paper's evaluation workloads: AlexNet and VGG16 with MNF inference.

Two execution paths over identical params, both dispatched through
``repro.engine`` (DESIGN.md §3):
  * dense  — the engine's dense backend + ReLU (the oracle),
  * mnf    — event-driven: engine conv2d/linear on the configured event
             backend, with the fire phase between layers (numerically
             identical at threshold 0).  Consecutive FC layers chain
             ``EventStream``s — the fired events of layer L feed layer L+1's
             multiply phase with no decode→re-encode round-trip.

``run_with_stats`` instruments every layer with the event counts the cost
model needs: input events fired (non-zero activations), MACs a dense
accelerator would do, and MACs the MNF multiply phase actually does
(Σ_events touched_outputs × C_out — Algorithm 1's walk length).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import engine
from repro.core.fire import FireConfig, fire
from repro.core.mnf_conv import conv_out_size

__all__ = ["ConvSpec", "FCSpec", "PoolSpec", "CNNSpec", "ALEXNET", "VGG16",
           "init_cnn_params", "cnn_forward", "run_with_stats",
           "layer_dense_macs"]


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    out_ch: int
    k: int
    stride: int = 1
    padding: int = 0


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    k: int = 2
    stride: int = 2


@dataclasses.dataclass(frozen=True)
class FCSpec:
    out: int


@dataclasses.dataclass(frozen=True)
class CNNSpec:
    name: str
    input_size: int
    in_ch: int
    layers: tuple
    num_classes: int = 1000

    def scaled(self, input_size: int) -> "CNNSpec":
        """Same topology at a smaller input resolution (CPU tests)."""
        return dataclasses.replace(self, input_size=input_size)


ALEXNET = CNNSpec(
    "alexnet", 224, 3,
    (ConvSpec(96, 11, 4, 2), PoolSpec(3, 2),
     ConvSpec(256, 5, 1, 2), PoolSpec(3, 2),
     ConvSpec(384, 3, 1, 1), ConvSpec(384, 3, 1, 1), ConvSpec(256, 3, 1, 1),
     PoolSpec(3, 2),
     FCSpec(4096), FCSpec(4096), FCSpec(1000)))

VGG16 = CNNSpec(
    "vgg16", 224, 3,
    (ConvSpec(64, 3, 1, 1), ConvSpec(64, 3, 1, 1), PoolSpec(),
     ConvSpec(128, 3, 1, 1), ConvSpec(128, 3, 1, 1), PoolSpec(),
     ConvSpec(256, 3, 1, 1), ConvSpec(256, 3, 1, 1), ConvSpec(256, 3, 1, 1),
     PoolSpec(),
     ConvSpec(512, 3, 1, 1), ConvSpec(512, 3, 1, 1), ConvSpec(512, 3, 1, 1),
     PoolSpec(),
     ConvSpec(512, 3, 1, 1), ConvSpec(512, 3, 1, 1), ConvSpec(512, 3, 1, 1),
     PoolSpec(),
     FCSpec(4096), FCSpec(4096), FCSpec(1000)))


def _trace_shapes(spec: CNNSpec):
    """(H, W, C) entering each layer, plus flattened FC input size."""
    h = w = spec.input_size
    c = spec.in_ch
    shapes = []
    for layer in spec.layers:
        shapes.append((h, w, c))
        if isinstance(layer, ConvSpec):
            h = conv_out_size(h, layer.k, layer.stride, layer.padding)
            w = conv_out_size(w, layer.k, layer.stride, layer.padding)
            c = layer.out_ch
        elif isinstance(layer, PoolSpec):
            h = (h - layer.k) // layer.stride + 1
            w = (w - layer.k) // layer.stride + 1
        elif isinstance(layer, FCSpec):
            h, w, c = 1, 1, layer.out
    return shapes


def init_cnn_params(key: jax.Array, spec: CNNSpec,
                    weight_sparsity: float = 0.0):
    """He-initialized params; optional unstructured weight pruning (the
    paper prunes to ~50-60% weight density before deployment)."""
    shapes = _trace_shapes(spec)
    params = []
    for i, layer in enumerate(spec.layers):
        k = jax.random.fold_in(key, i)
        h, w, c = shapes[i]
        if isinstance(layer, ConvSpec):
            fan_in = layer.k * layer.k * c
            wgt = jax.random.normal(
                k, (layer.k, layer.k, c, layer.out_ch), jnp.float32)
            wgt = wgt * (2.0 / fan_in) ** 0.5
        elif isinstance(layer, FCSpec):
            fan_in = h * w * c
            wgt = jax.random.normal(k, (fan_in, layer.out), jnp.float32)
            wgt = wgt * (2.0 / fan_in) ** 0.5
        else:
            params.append(None)
            continue
        if weight_sparsity > 0.0:
            keep = jax.random.uniform(jax.random.fold_in(k, 1), wgt.shape)
            wgt = jnp.where(keep >= weight_sparsity, wgt, 0.0)
        params.append(wgt)
    return params


def _touched_outputs(h: int, w: int, k: int, stride: int, padding: int):
    """(H, W) map: #output positions each input pixel contributes to."""
    oy = conv_out_size(h, k, stride, padding)
    ox = conv_out_size(w, k, stride, padding)
    iy = jnp.arange(h)[:, None]
    ix = jnp.arange(w)[None, :]

    def jumps(i, osz):
        lo = jnp.maximum(0, -(-(i + padding - k + 1) // stride))
        hi = jnp.minimum(osz - 1, (i + padding) // stride)
        return jnp.maximum(hi - lo + 1, 0)

    return jumps(iy, oy) * jumps(ix, ox)


def layer_dense_macs(spec: CNNSpec):
    """Per-compute-layer dense MAC counts (what a dense accelerator does)."""
    shapes = _trace_shapes(spec)
    out = []
    for i, layer in enumerate(spec.layers):
        h, w, c = shapes[i]
        if isinstance(layer, ConvSpec):
            oy = conv_out_size(h, layer.k, layer.stride, layer.padding)
            ox = conv_out_size(w, layer.k, layer.stride, layer.padding)
            out.append(oy * ox * layer.k * layer.k * c * layer.out_ch)
        elif isinstance(layer, FCSpec):
            out.append(h * w * c * layer.out)
    return out


def _layer_cfg(base: engine.EngineConfig | None, *, mnf: bool,
               fire_cfg: FireConfig) -> engine.EngineConfig:
    cfg = base or engine.EngineConfig(backend="block")
    if not mnf:
        cfg = cfg.replace(backend="dense")
    return cfg.replace(threshold=fire_cfg.threshold,
                       magnitude=fire_cfg.magnitude)


def cnn_forward(params, x: jax.Array, spec: CNNSpec, *, mnf: bool = True,
                fire_cfg: FireConfig = FireConfig(),
                engine_cfg: engine.EngineConfig | None = None):
    """x: (B, H, W, C) -> logits (B, classes).  mnf=False is the oracle.

    All compute dispatches through ``repro.engine``; ``engine_cfg`` picks the
    backend (default: pure-jnp block events).  On the MNF path consecutive
    FC layers pass an ``EventStream`` directly — the inter-layer densify
    only happens where a pool/flatten genuinely needs spatial form.
    """
    cfg = _layer_cfg(engine_cfg, mnf=mnf, fire_cfg=fire_cfg)
    # Event chaining preserves fire semantics only for the plain-threshold
    # fire decision (no int8 requantization between layers).
    chain = mnf and not fire_cfg.quantize_to_int8
    for layer, wgt in zip(spec.layers, params):
        if isinstance(layer, ConvSpec):
            xd = _dense(x)
            ccfg = cfg.replace(blk_k=min(8, xd.shape[-1]), threshold=0.0)
            acc = engine.conv2d(xd, wgt, cfg=ccfg, stride=layer.stride,
                                padding=layer.padding)
            x = fire(acc, fire_cfg)                  # fire phase == ReLU @ 0
        elif isinstance(layer, PoolSpec):
            x = jax.lax.reduce_window(
                _dense(x), -jnp.inf, jax.lax.max,
                (1, layer.k, layer.k, 1), (1, layer.stride, layer.stride, 1),
                "VALID")
        elif isinstance(layer, FCSpec):
            flat = x if isinstance(x, engine.EventStream) \
                else x.reshape(x.shape[0], -1)
            acc = engine.linear(flat, wgt, cfg=cfg.replace(threshold=0.0))
            last = layer is spec.layers[-1]
            if last:
                x = acc
            elif chain:
                x = engine.fire(acc, cfg)            # fire -> EventStream
            else:
                x = fire(acc, fire_cfg)
    return _dense(x)


def _dense(x) -> jax.Array:
    return x.dense() if isinstance(x, engine.EventStream) else x


def run_with_stats(params, x: jax.Array, spec: CNNSpec,
                   fire_cfg: FireConfig = FireConfig(),
                   engine_cfg: engine.EngineConfig | None = None):
    """MNF forward + per-layer event accounting (via ``repro.engine``).

    Returns (logits, stats list).  Each compute layer's stats:
      dense_macs  — MACs of the dense dataflow
      event_macs  — MACs the MNF multiply phase performs (Algorithm 1 walk)
      in_events   — input events fired into the layer
      in_elems    — dense input element count
      out_density — fraction of outputs that fire
    """
    cfg = _layer_cfg(engine_cfg, mnf=True, fire_cfg=fire_cfg)
    cfg = cfg.replace(threshold=0.0)     # encode lossless; fire() thresholds
    stats = []
    for layer, wgt in zip(spec.layers, params):
        if isinstance(layer, ConvSpec):
            b, h, w, c = x.shape
            nz = (jnp.abs(x) > 0).astype(jnp.float32)            # (B,H,W,C)
            touched = _touched_outputs(h, w, layer.k, layer.stride,
                                       layer.padding).astype(jnp.float32)
            event_macs = jnp.sum(nz * touched[None, :, :, None]) \
                * layer.out_ch
            in_events = jnp.sum(nz)
            acc = engine.conv2d(x, wgt, cfg=cfg.replace(blk_k=min(8, c)),
                                stride=layer.stride, padding=layer.padding)
            oy = conv_out_size(h, layer.k, layer.stride, layer.padding)
            ox = conv_out_size(w, layer.k, layer.stride, layer.padding)
            dense_macs = b * oy * ox * layer.k * layer.k * c * layer.out_ch
            x = fire(acc, fire_cfg)
            ev_f = float(in_events)
            stats.append(dict(
                kind="conv", dense_macs=float(dense_macs),
                event_macs=float(event_macs), in_events=ev_f,
                in_elems=float(b * h * w * c), c_out=layer.out_ch,
                avg_touched=float(event_macs) / max(ev_f * layer.out_ch, 1.0),
                out_density=float(jnp.mean(jnp.abs(x) > 0))))
        elif isinstance(layer, PoolSpec):
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, layer.k, layer.k, 1), (1, layer.stride, layer.stride, 1),
                "VALID")
        elif isinstance(layer, FCSpec):
            flat = x.reshape(x.shape[0], -1)
            nz = (jnp.abs(flat) > 0).astype(jnp.float32)
            in_events = jnp.sum(nz)
            event_macs = in_events * layer.out                   # Algorithm 2
            dense_macs = flat.shape[0] * flat.shape[1] * layer.out
            acc = engine.linear(flat, wgt, cfg=cfg)
            last = layer is spec.layers[-1]
            x = acc if last else fire(acc, fire_cfg)
            stats.append(dict(
                kind="fc", dense_macs=float(dense_macs),
                event_macs=float(event_macs), in_events=float(in_events),
                in_elems=float(flat.size), c_out=layer.out, avg_touched=1.0,
                out_density=float(jnp.mean(jnp.abs(x) > 0))))
    return x, stats
