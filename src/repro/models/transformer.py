"""Decoder-LM assembly for all assigned architectures.

One scan-over-layers body serves every uniform stack; per-layer attention
windows are traced scalars (gemma2's alternating local/global, hymba's
listed global layers).  MoE archs unroll their leading dense layers.
Whisper adds an encoder stack + cross-attention.  Phi-3-vision fuses
precomputed patch embeddings into the leading positions.

Activation sharding is injected via ``sc(x, logical_axes)`` — the launch
layer installs a resolver that maps logical axes to mesh axes
(with_sharding_constraint); defaults to identity so models run un-meshed on
CPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL_WINDOW, ModelConfig, ShapeConfig
from repro.models import attention, hymba, layers, moe, ssm
from repro.models.param_utils import Init, stack_layer_params

__all__ = ["init_params", "forward", "lm_loss", "init_cache", "decode_step",
           "prefill", "input_specs", "count_params", "active_params"]

Sharder = Callable[[jax.Array, tuple], jax.Array]
_id_sc: Sharder = lambda x, ax: x


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _layer_init(key: jax.Array, cfg: ModelConfig, *, moe_layer: bool,
                cross_attn: bool = False):
    """One decoder layer's params + specs."""
    b = Init(key, jnp.dtype(cfg.param_dtype))
    if cfg.block_type == "rwkv6":
        p, s = ssm.rwkv6_block_init(key, cfg)
        return p, s
    b.ones("ln_attn", (cfg.d_model,), ("embed",))
    if cfg.block_type == "hymba":
        p, s = hymba.hymba_block_init(jax.random.fold_in(key, 1), cfg)
        b.params["mix"], b.specs["mix"] = p, s
    elif cfg.mla is not None:
        p, s = attention.mla_init(jax.random.fold_in(key, 1), cfg)
        b.params["mix"], b.specs["mix"] = p, s
    else:
        p, s = attention.attn_init(jax.random.fold_in(key, 1), cfg)
        b.params["mix"], b.specs["mix"] = p, s
    if cross_attn:
        p, s = attention.attn_init(jax.random.fold_in(key, 2), cfg)
        b.params["cross"], b.specs["cross"] = p, s
        b.ones("ln_cross", (cfg.d_model,), ("embed",))
    b.ones("ln_mlp", (cfg.d_model,), ("embed",))
    if cfg.post_block_norm:
        b.ones("ln_attn_post", (cfg.d_model,), ("embed",))
        b.ones("ln_mlp_post", (cfg.d_model,), ("embed",))
    if moe_layer:
        p, s = moe.moe_init(jax.random.fold_in(key, 3), cfg)
        b.params["ffn"], b.specs["ffn"] = p, s
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and not moe_layer:
            d_ff = cfg.moe.dense_ff or cfg.d_ff
        p, s = layers.mlp_init(jax.random.fold_in(key, 4), cfg, d_ff=d_ff)
        b.params["ffn"], b.specs["ffn"] = p, s
    return b.done()


def _enc_layer_init(key: jax.Array, cfg: ModelConfig):
    b = Init(key, jnp.dtype(cfg.param_dtype))
    b.ones("ln_attn", (cfg.d_model,), ("embed",))
    p, s = attention.attn_init(jax.random.fold_in(key, 1), cfg)
    b.params["mix"], b.specs["mix"] = p, s
    b.ones("ln_mlp", (cfg.d_model,), ("embed",))
    p, s = layers.mlp_init(jax.random.fold_in(key, 2), cfg)
    b.params["ffn"], b.specs["ffn"] = p, s
    return b.done()


def init_params(key: jax.Array, cfg: ModelConfig):
    """Returns (params, specs) — specs mirror params with logical axes."""
    b = Init(key, jnp.dtype(cfg.param_dtype))
    ep, es = layers.embed_init(jax.random.fold_in(key, 0), cfg)
    b.params["embed"], b.specs["embed"] = ep, es
    b.ones("final_norm", (cfg.d_model,), ("embed",))

    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.num_layers - n_dense
    lkeys = jax.random.split(jax.random.fold_in(key, 1), n_scan)
    lp, ls = stack_layer_params(
        lambda k: _layer_init(k, cfg, moe_layer=cfg.moe is not None,
                              cross_attn=cfg.encoder_decoder), lkeys)
    b.params["layers"], b.specs["layers"] = lp, ls

    if n_dense:
        dkeys = jax.random.split(jax.random.fold_in(key, 2), n_dense)
        dp, dsx = stack_layer_params(
            lambda k: _layer_init(k, cfg, moe_layer=False), dkeys)
        b.params["dense_layers"], b.specs["dense_layers"] = dp, dsx

    if cfg.encoder_decoder:
        ekeys = jax.random.split(jax.random.fold_in(key, 3), cfg.enc_layers)
        ep2, es2 = stack_layer_params(lambda k: _enc_layer_init(k, cfg),
                                      ekeys)
        b.params["encoder"], b.specs["encoder"] = ep2, es2
        b.ones("enc_final_norm", (cfg.d_model,), ("embed",))
    return b.done()


# ---------------------------------------------------------------------------
# Layer application (one body for scan)
# ---------------------------------------------------------------------------

def _apply_layer(p, x, *, cfg: ModelConfig, positions, window, cache=None,
                 decode_pos=None, enc_out=None, enc_len=None, moe_layer=False,
                 sc: Sharder = _id_sc):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    train_mode = cache is None and decode_pos is None
    if cfg.block_type == "rwkv6":
        if cache is not None and x.shape[1] == 1:
            x, new_cache = ssm.rwkv6_block_decode(p, x, cfg, cache)
        else:
            x, new_cache = ssm.rwkv6_block_apply(p, x, cfg, sc=sc)
        if train_mode:
            new_cache = None  # don't stack per-layer states through scan
        return sc(x, ("batch", "seq", None)), new_cache, aux

    h = layers.rms_norm(x, p["ln_attn"] - 1.0, cfg.norm_eps)
    if cfg.block_type == "hymba":
        a, new_cache = hymba.hymba_block_apply(
            p["mix"], h, cfg=cfg, positions=positions, window=window,
            cache=cache, decode_pos=decode_pos, sc=sc)
    elif cfg.mla is not None:
        a, new_cache = attention.mla_apply(
            p["mix"], h, cfg=cfg, positions=positions, window=window,
            cache=cache, decode_pos=decode_pos, sc=sc)
    else:
        a, new_cache = attention.attn_apply(
            p["mix"], h, cfg=cfg, positions=positions, window=window,
            cache=cache, decode_pos=decode_pos, sc=sc)
    if cfg.post_block_norm:
        a = layers.rms_norm(a, p["ln_attn_post"] - 1.0, cfg.norm_eps)
    if train_mode:
        new_cache = None  # don't stack per-layer K/V through the train scan
    x = x + a
    x = sc(x, ("batch", "seq", None))

    if "cross" in p:
        hc = layers.rms_norm(x, p["ln_cross"] - 1.0, cfg.norm_eps)
        if enc_out is None and cache is not None:
            # decode: the encoder is NOT re-run; cross K/V come from the
            # cache filled at prefill (EXPERIMENTS.md §Perf W1).
            kv = (cache["cross_k"].astype(x.dtype),
                  cache["cross_v"].astype(x.dtype))
        else:
            kv = enc_out  # (k, v) tuple precomputed per layer
        c, _ = attention.attn_apply(
            p["cross"], hc, cfg=cfg, positions=positions,
            window=GLOBAL_WINDOW, causal=False, kv_override=kv, sc=sc)
        x = x + c
        if new_cache is not None and cfg.encoder_decoder:
            if enc_out is not None:
                new_cache = dict(new_cache, cross_k=kv[0].astype(
                    new_cache["k"].dtype), cross_v=kv[1].astype(
                        new_cache["k"].dtype))
            elif cache is not None:
                new_cache = dict(new_cache, cross_k=cache["cross_k"],
                                 cross_v=cache["cross_v"])

    h2 = layers.rms_norm(x, p["ln_mlp"] - 1.0, cfg.norm_eps)
    if moe_layer:
        moe_fn = moe.moe_apply_ep if cfg.moe_ep else moe.moe_apply
        f, moe_aux = moe_fn(p["ffn"], h2, cfg, sc=sc)
        aux = aux + moe_aux["load_balance_loss"]
    else:
        f = layers.mlp_apply(p["ffn"], h2, cfg, sc=sc)
    if cfg.post_block_norm:
        f = layers.rms_norm(f, p["ln_mlp_post"] - 1.0, cfg.norm_eps)
    x = x + f
    return sc(x, ("batch", "seq", None)), new_cache, aux


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.everything_saveable


def _window_array(cfg: ModelConfig, n_dense: int) -> jax.Array:
    return jnp.asarray(
        [cfg.window_for_layer(i)
         for i in range(n_dense, cfg.num_layers)], jnp.int32)


def _scan_stack(params, x, cfg: ModelConfig, *, positions, cache=None,
                decode_pos=None, enc_out=None, sc: Sharder = _id_sc,
                moe_layers: bool):
    """lax.scan over the uniform layer stack.  cache/enc_out leaves carry a
    leading L dim; returns (x, new_cache, aux_sum)."""
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    windows = _window_array(cfg, n_dense)

    def body(carry, xs_in):
        xx, aux = carry
        p_l, cache_l, enc_l, win = xs_in
        xx, new_cache, a = _apply_layer(
            p_l, xx, cfg=cfg, positions=positions, window=win,
            cache=cache_l, decode_pos=decode_pos, enc_out=enc_l,
            moe_layer=moe_layers, sc=sc)
        return (xx, aux + a), new_cache

    body = jax.checkpoint(body, policy=_remat_policy(cfg),
                          prevent_cse=False)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params, cache, enc_out, windows))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whisper encoder
# ---------------------------------------------------------------------------

def _encode_audio(params, frames: jax.Array, cfg: ModelConfig,
                  sc: Sharder = _id_sc):
    """frames: (B, F, d) precomputed conv-frontend embeddings (stub)."""
    b, f, d = frames.shape
    pos = jnp.arange(f, dtype=jnp.float32)
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    pe = jnp.concatenate([jnp.sin(pos[:, None] * freqs),
                          jnp.cos(pos[:, None] * freqs)], axis=-1)
    x = frames + pe.astype(frames.dtype)
    positions = jnp.arange(f, dtype=jnp.int32)

    def body(carry, p_l):
        xx = carry
        h = layers.rms_norm(xx, p_l["ln_attn"] - 1.0, cfg.norm_eps)
        a, _ = attention.attn_apply(p_l["mix"], h, cfg=cfg,
                                    positions=positions,
                                    window=GLOBAL_WINDOW, causal=False,
                                    sc=sc)
        xx = xx + a
        h2 = layers.rms_norm(xx, p_l["ln_mlp"] - 1.0, cfg.norm_eps)
        xx = xx + layers.mlp_apply(p_l["ffn"], h2, cfg, sc=sc)
        return sc(xx, ("batch", "seq", None)), None

    body = jax.checkpoint(body, policy=_remat_policy(cfg), prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.rms_norm(x, params["enc_final_norm"] - 1.0, cfg.norm_eps)


def _cross_kv(params, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute per-layer cross-attention K/V from encoder output."""
    def per_layer(p_l):
        cdt = enc_out.dtype
        k = (enc_out @ p_l["cross"]["wk"].astype(cdt))
        v = (enc_out @ p_l["cross"]["wv"].astype(cdt))
        b, f, _ = enc_out.shape
        return (k.reshape(b, f, cfg.num_kv_heads, cfg.head_dim),
                v.reshape(b, f, cfg.num_kv_heads, cfg.head_dim))
    return jax.vmap(per_layer)(params["layers"])


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def forward(params, tokens: jax.Array, cfg: ModelConfig, *,
            vision_embeds=None, audio_frames=None, cache=None,
            decode_pos=None, sc: Sharder = _id_sc):
    """tokens: (B, S) -> (hidden (B, S, d), new_cache, aux)."""
    bsz, s = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cfg)
    if cfg.vision_tokens and vision_embeds is not None:
        # VLM stub: patch embeddings replace the leading positions.
        nv = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, nv:]],
                            axis=1)
    x = sc(x, ("batch", "seq", None))
    if decode_pos is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    else:
        positions = decode_pos + jnp.arange(s, dtype=jnp.int32)

    enc_out = None
    if cfg.encoder_decoder and not (s == 1 and cache is not None):
        assert audio_frames is not None
        enc_h = _encode_audio(params, audio_frames.astype(x.dtype), cfg, sc)
        enc_out = _cross_kv(params, enc_h, cfg)

    aux = jnp.zeros((), jnp.float32)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    dense_cache_new = []
    if n_dense:
        for i in range(n_dense):
            p_l = jax.tree.map(lambda a: a[i], params["dense_layers"])
            c_l = (jax.tree.map(lambda a: a[i], cache["dense"])
                   if cache is not None else None)
            x, nc, a = _apply_layer(
                p_l, x, cfg=cfg, positions=positions,
                window=cfg.window_for_layer(i), cache=c_l,
                decode_pos=decode_pos, moe_layer=False, sc=sc)
            aux = aux + a
            dense_cache_new.append(nc)

    scan_cache = cache["scan"] if cache is not None else None
    x, new_scan_cache, a2 = _scan_stack(
        params["layers"], x, cfg, positions=positions, cache=scan_cache,
        decode_pos=decode_pos, enc_out=enc_out, sc=sc,
        moe_layers=cfg.moe is not None)
    aux = aux + a2
    x = layers.rms_norm(x, params["final_norm"] - 1.0, cfg.norm_eps)

    new_cache = None
    if cache is not None or decode_pos is not None:
        new_cache = dict(scan=new_scan_cache)
        if n_dense:
            new_cache["dense"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *dense_cache_new) \
                if len(dense_cache_new) > 1 else jax.tree.map(
                    lambda a: a[None], dense_cache_new[0])
    return x, new_cache, aux


def lm_loss(params, batch: dict, cfg: ModelConfig, *, sc: Sharder = _id_sc):
    """Chunked softmax-xent: logits materialized one seq-chunk at a time."""
    h, _, aux = forward(params, batch["tokens"], cfg,
                        vision_embeds=batch.get("vision_embeds"),
                        audio_frames=batch.get("audio_frames"), sc=sc)
    w = layers.unembed_matrix(params["embed"], cfg)
    targets = batch["labels"]
    bsz, s, d = h.shape
    chunk = min(cfg.xent_chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hs = h.reshape(bsz, nc, chunk, d).swapaxes(0, 1)
    ts = targets.reshape(bsz, nc, chunk).swapaxes(0, 1)

    def body(acc, xs):
        hc, tc = xs
        logits = (hc.astype(jnp.float32) @ w.astype(jnp.float32))
        logits = sc(logits, ("batch", None, "vocab"))
        if cfg.final_logit_softcap:
            logits = cfg.final_logit_softcap * jnp.tanh(
                logits / cfg.final_logit_softcap)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(tc, 0)[..., None],
                                 axis=-1)[..., 0]
        valid = tc >= 0
        loss = jnp.where(valid, lse - ll, 0.0)
        return (acc[0] + loss.sum(), acc[1] + valid.sum()), None

    body = jax.checkpoint(body, policy=_remat_policy(cfg), prevent_cse=False)
    (tot, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hs, ts))
    loss = tot / jnp.maximum(n, 1)
    if cfg.moe is not None:
        loss = loss + 0.01 * aux
    return loss


# ---------------------------------------------------------------------------
# KV-cache / decode
# ---------------------------------------------------------------------------

def _layer_cache_spec(cfg: ModelConfig, bsz: int, max_len: int):
    """ShapeDtypeStructs for ONE layer's cache (no leading L dim)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.block_type == "rwkv6":
        out = dict(
            shift_att=jax.ShapeDtypeStruct((bsz, cfg.d_model), cdt),
            shift_ffn=jax.ShapeDtypeStruct((bsz, cfg.d_model), cdt),
            wkv=jax.ShapeDtypeStruct(
                (bsz, cfg.num_heads, cfg.head_dim, cfg.head_dim),
                jnp.float32))
        if cfg.mnf.enabled:
            # Per-token fired-event count of the gated decode (DESIGN.md
            # §13) — the serving loop reads it for events/token stats.
            out["events"] = jax.ShapeDtypeStruct((), jnp.float32)
        return out
    if cfg.block_type == "hymba":
        di = cfg.d_model
        out = dict(
            attn=dict(
                k=jax.ShapeDtypeStruct(
                    (bsz, max_len, cfg.num_kv_heads, cfg.head_dim), cdt),
                v=jax.ShapeDtypeStruct(
                    (bsz, max_len, cfg.num_kv_heads, cfg.head_dim), cdt)),
            conv=jax.ShapeDtypeStruct((bsz, cfg.ssm.conv_dim - 1, di), cdt),
            ssm=jax.ShapeDtypeStruct((bsz, di, cfg.ssm.state_dim),
                                     jnp.float32))
        if cfg.mnf.enabled:
            out["events"] = jax.ShapeDtypeStruct((), jnp.float32)
        return out
    if cfg.mla is not None:
        return dict(
            c=jax.ShapeDtypeStruct((bsz, max_len, cfg.mla.kv_lora_rank), cdt),
            kr=jax.ShapeDtypeStruct((bsz, max_len, cfg.mla.qk_rope_dim), cdt))
    out = dict(
        k=jax.ShapeDtypeStruct((bsz, max_len, cfg.num_kv_heads,
                                cfg.head_dim), cdt),
        v=jax.ShapeDtypeStruct((bsz, max_len, cfg.num_kv_heads,
                                cfg.head_dim), cdt))
    if cfg.encoder_decoder:
        # cross-attention K/V computed once at prefill, static thereafter
        out["cross_k"] = jax.ShapeDtypeStruct(
            (bsz, cfg.enc_frames, cfg.num_kv_heads, cfg.head_dim), cdt)
        out["cross_v"] = jax.ShapeDtypeStruct(
            (bsz, cfg.enc_frames, cfg.num_kv_heads, cfg.head_dim), cdt)
    return out


def cache_specs(cfg: ModelConfig, bsz: int, max_len: int):
    """ShapeDtypeStruct pytree of the full decode cache."""
    one = _layer_cache_spec(cfg, bsz, max_len)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.num_layers - n_dense
    stack = lambda n: jax.tree.map(
        lambda sds: jax.ShapeDtypeStruct((n,) + sds.shape, sds.dtype), one)
    out = dict(scan=stack(n_scan))
    if n_dense:
        out["dense"] = stack(n_dense)
    return out


def init_cache(cfg: ModelConfig, bsz: int, max_len: int):
    return jax.tree.map(lambda sds: jnp.zeros(sds.shape, sds.dtype),
                        cache_specs(cfg, bsz, max_len))


def _layer_cache_axes(cfg: ModelConfig):
    """Logical axes for ONE layer's cache (matches _layer_cache_spec)."""
    if cfg.block_type == "rwkv6":
        out = dict(shift_att=("batch", None), shift_ffn=("batch", None),
                   wkv=("batch", "heads", None, None))
        if cfg.mnf.enabled:
            out["events"] = ()                   # scalar — replicated
        return out
    if cfg.block_type == "hymba":
        out = dict(
            attn=dict(k=("batch", "cache_seq", "kv_heads", None),
                      v=("batch", "cache_seq", "kv_heads", None)),
            conv=("batch", None, "ff"),
            ssm=("batch", "ff", None))
        if cfg.mnf.enabled:
            out["events"] = ()
        return out
    if cfg.mla is not None:
        return dict(c=("batch", "cache_seq", None),
                    kr=("batch", "cache_seq", None))
    out = dict(k=("batch", "cache_seq", "kv_heads", None),
               v=("batch", "cache_seq", "kv_heads", None))
    if cfg.encoder_decoder:
        out["cross_k"] = ("batch", None, "kv_heads", None)
        out["cross_v"] = ("batch", None, "kv_heads", None)
    return out


def cache_axes(cfg: ModelConfig):
    """Logical-axes tree matching cache_specs (leading 'layers' dim)."""
    one = _layer_cache_axes(cfg)
    stacked = jax.tree.map(lambda ax: ("layers",) + tuple(ax), one,
                           is_leaf=lambda x: isinstance(x, tuple))
    out = dict(scan=stacked)
    if cfg.moe and cfg.moe.first_dense_layers:
        out["dense"] = stacked
    return out


def decode_step(params, cache, tokens: jax.Array, decode_pos, cfg: ModelConfig,
                *, enc_out=None, audio_frames=None, sc: Sharder = _id_sc):
    """serve_step: one new token per sequence against a filled cache.

    tokens: (B, 1).  Returns (logits (B, 1, V), new_cache).
    """
    h, new_cache, _ = forward(params, tokens, cfg, cache=cache,
                              decode_pos=decode_pos,
                              audio_frames=audio_frames, sc=sc)
    w = layers.unembed_matrix(params["embed"], cfg)
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(
            logits / cfg.final_logit_softcap)
    return logits, new_cache


def prefill(params, tokens: jax.Array, cfg: ModelConfig, *,
            vision_embeds=None, audio_frames=None, max_len: int | None = None,
            sc: Sharder = _id_sc):
    """Run the prompt; returns (last-position logits, filled cache)."""
    bsz, s = tokens.shape
    max_len = max_len or s
    cache = init_cache(cfg, bsz, max_len)
    h, new_cache, _ = forward(params, tokens, cfg, cache=cache, decode_pos=0,
                              vision_embeds=vision_embeds,
                              audio_frames=audio_frames, sc=sc)
    w = layers.unembed_matrix(params["embed"], cfg)
    logits = h[:, -1:].astype(jnp.float32) @ w.astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(
            logits / cfg.final_logit_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins) & parameter counting
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        out = dict(tokens=jax.ShapeDtypeStruct((b, s), jnp.int32),
                   labels=jax.ShapeDtypeStruct((b, s), jnp.int32))
    elif shape.kind == "prefill":
        out = dict(tokens=jax.ShapeDtypeStruct((b, s), jnp.int32))
    else:  # decode: one new token against an s-long cache
        out = dict(tokens=jax.ShapeDtypeStruct((b, 1), jnp.int32))
    if cfg.vision_tokens:
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), cdt)
    if cfg.encoder_decoder and shape.kind != "decode":
        # decode serves off the prefill-filled cross-KV cache (§Perf W1)
        out["audio_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_frames, cfg.d_model), cdt)
    return out


def count_params(cfg: ModelConfig) -> int:
    import math
    shapes = jax.eval_shape(lambda k: init_params(k, cfg)[0],
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k + shared experts only)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    d, f = cfg.d_model, m.expert_ff
    n_moe = cfg.num_layers - m.first_dense_layers
    per_expert = (3 if cfg.act.endswith("_glu") else 2) * d * f
    inactive = n_moe * (m.num_experts - m.top_k) * per_expert
    return total - inactive
