"""Mixture-of-Experts with sort-based capacity dispatch.

This layer is the clearest LM-scale image of the paper's technique: routing
IS multiply-and-fire.  The router thresholds (top-k) decide which experts a
token *fires* to; the dispatch carries (value, direct expert address) events
— exactly the NoC multicast of §5 — and non-selected experts do no work for
that token.  The load-balance auxiliary loss plays the role of the paper's
mapping balance across PEs.

Dispatch algorithm (jit-static shapes, GSPMD-shardable):
  1. top-k of softmax(router logits) -> (expert id, gate) per assignment.
  2. stable sort assignments by expert id; rank-within-expert via
     searchsorted; assignments whose rank exceeds capacity C are *dropped*
     (classic capacity-factor semantics — counted in aux stats).
  3. gather tokens into a dense (E, C, d) buffer (one direct-addressed slot
     per event), run every expert's FFN as one batched einsum, and
     scatter-add results back weighted by the gates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.param_utils import Init

__all__ = ["moe_init", "moe_apply", "moe_capacity"]


def moe_capacity(num_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(num_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8


# ---------------------------------------------------------------------------
# Explicit expert parallelism (shard_map + lax.all_to_all) — §Perf D3
# ---------------------------------------------------------------------------

def moe_apply_ep(p, x: jax.Array, cfg: ModelConfig, sc=lambda x, ax: x):
    """Explicit expert parallelism under shard_map.

    GSPMD cannot be coaxed into an efficient schedule for gather/scatter
    dispatch — it stages masked all-reduces over full assignment tensors
    (§Perf D2 left ~330 GB/device of AR).  This path takes manual control:
    shard_map over (dp × ep=model).  Activations are replicated within the
    ep group (the SP-boundary all-gather already pays for this), so each
    shard routes every local-dp token, keeps only the events addressed to
    *its own* expert slice, runs those experts, and a single token-sized
    ``psum`` over ep sums the k expert contributions.

    Wire-cost napkin (per token of width d): replicate+reduce = AG(d) +
    AR(2d) = 3d, vs a dispatch/return all-to-all = 2·k·d = 12d at top-6 —
    replication wins whenever k > 1.5, which covers both DeepSeek configs.

    Falls back to the GSPMD path when no ("model") mesh is ambient (CPU
    tests) — numerics match exactly when capacity is not binding.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        # legacy `with mesh:` context (pre-use_mesh callers)
        from jax._src.mesh import thread_resources
        phys = thread_resources.env.physical_mesh
        mesh = None if phys.empty else phys
    if mesh is None or getattr(mesh, "empty", True) or \
            "model" not in mesh.axis_names:
        return moe_apply(p, x, cfg, sc=sc)
    m = cfg.moe
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ep = "model"
    ep_size = mesh.shape[ep]
    e = m.num_experts
    if e % ep_size:
        return moe_apply(p, x, cfg, sc=sc)
    e_loc = e // ep_size
    bsz, s, d = x.shape
    k = m.top_k
    cdt = x.dtype
    P = jax.sharding.PartitionSpec

    def local_fn(xl, router, w_gate, w_up, w_down):
        # xl: (B_loc, S, d) — tokens local to the dp shard, replicated on ep.
        my = jax.lax.axis_index(ep)
        bl = xl.shape[0]
        tl = bl * s
        xf = xl.reshape(tl, d)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, topi = jax.lax.top_k(probs, k)
        if m.router_renormalize:
            gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        flat_e = topi.reshape(-1).astype(jnp.int32)
        flat_t = jnp.arange(tl * k, dtype=jnp.int32) // k
        flat_g = gates.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        rank = jnp.arange(tl * k, dtype=jnp.int32) - jnp.searchsorted(
            se, se, side="left").astype(jnp.int32)
        cap = moe_capacity(tl, cfg)
        # fire only the events addressed to MY expert slice
        mine = (se >= my * e_loc) & (se < (my + 1) * e_loc)
        keep = (rank < cap) & mine
        slot = jnp.where(keep, (se - my * e_loc) * cap + rank, e_loc * cap)

        inv = jnp.full((e_loc * cap + 1,), -1, jnp.int32).at[slot].set(st)
        inv = inv[:e_loc * cap]
        de = jnp.where((inv >= 0)[:, None],
                       jnp.take(xf, jnp.maximum(inv, 0), axis=0), 0)
        de = de.reshape(e_loc, cap, d)

        act = layers.activation_fn(cfg.act)
        up = jnp.einsum("ecd,edf->ecf", de, w_up.astype(cdt))
        if layers.is_glu(cfg.act):
            h = act(jnp.einsum("ecd,edf->ecf", de,
                               w_gate.astype(cdt))) * up
        else:
            h = act(up)
        h = layers.mnf_sparsify(h, cfg)
        y_ec = jnp.einsum("ecf,efd->ecd", h, w_down.astype(cdt))

        y_pad = jnp.pad(y_ec.reshape(e_loc * cap, d), ((0, 1), (0, 0)))
        contrib = jnp.where(keep[:, None], jnp.take(y_pad, slot, axis=0), 0)
        contrib = contrib * sg[:, None].astype(cdt)
        y = jnp.zeros((tl, d), cdt).at[st].add(contrib)
        # each ep shard holds contributions of ITS experts only → psum
        y = jax.lax.psum(y, ep)

        ce_keep = (rank < cap)
        me = jax.lax.pmean(probs.mean(axis=0), dp)
        ce = jax.lax.pmean(
            jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (tl * k), dp)
        aux_lb = e * jnp.sum(me * ce)
        aux_drop = 1.0 - jax.lax.pmean(ce_keep.mean(), dp)
        return y.reshape(bl, s, d), aux_lb, aux_drop

    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp, None, None), P(), P()),
        check_vma=False)
    w_gate = p.get("w_gate", p["w_up"])          # non-GLU: unused dummy
    y, aux_lb, aux_drop = fn(x, p["router"], w_gate, p["w_up"], p["w_down"])
    y = sc(y, ("batch", "seq", None))
    if m.num_shared:
        y = y + layers.mlp_apply(p["shared"], x, cfg, sc=sc)
    aux = dict(load_balance_loss=aux_lb, drop_fraction=aux_drop)
    return y, aux


def moe_init(key: jax.Array, cfg: ModelConfig):
    m = cfg.moe
    d, f = cfg.d_model, m.expert_ff
    e = m.num_experts
    b = Init(key, jnp.dtype(cfg.param_dtype))
    b.dense("router", (d, e), ("embed", "experts"))
    if layers.is_glu(cfg.act):
        b.dense("w_gate", (e, d, f), ("experts", "embed", "ff_expert"))
    b.dense("w_up", (e, d, f), ("experts", "embed", "ff_expert"))
    b.dense("w_down", (e, f, d), ("experts", "ff_expert", "embed"))
    if m.num_shared:
        sp, ss = layers.mlp_init(jax.random.fold_in(key, 7), cfg,
                                 d_ff=m.num_shared * f)
        b.params["shared"], b.specs["shared"] = sp, ss
    return b.done()


def moe_apply(p, x: jax.Array, cfg: ModelConfig, sc=lambda x, ax: x):
    """x: (B, S, d) -> (y (B, S, d), aux dict with load-balance loss).

    Group-local dispatch: tokens are processed in G independent groups
    (G = cfg.moe_dispatch_groups, aligned with the data-parallel shards), so
    the sort / rank / scatter machinery is *local to a shard* — the only
    cross-device traffic is the (G, E, C, d) dispatch tensor itself, i.e.
    the expert all-to-all that carries fired events to their expert
    addresses.  A naive global sort forces GSPMD to all-gather the full
    token stream (measured: 205 s collective term on deepseek-moe/train_4k,
    see EXPERIMENTS.md §Perf iteration D1).
    """
    m = cfg.moe
    bsz, s, d = x.shape
    t = bsz * s
    k = m.top_k
    e = m.num_experts
    cdt = x.dtype
    g = max(1, min(cfg.moe_dispatch_groups, t))
    while t % g:
        g //= 2
    tg = t // g                                              # tokens / group
    xf = x.reshape(g, tg, d)
    xf = sc(xf, ("batch", None, None))

    # --- router: fire decisions ---
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, Tg, E)
    gates, topi = jax.lax.top_k(probs, k)                    # (G, Tg, k)
    if m.router_renormalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- group-local event list: sorted by expert address within group ---
    flat_e = topi.reshape(g, tg * k).astype(jnp.int32)
    flat_t = jnp.broadcast_to(
        (jnp.arange(tg * k, dtype=jnp.int32) // k)[None], (g, tg * k))
    flat_g = gates.reshape(g, tg * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    rank = (jnp.arange(tg * k, dtype=jnp.int32)[None] -
            jax.vmap(lambda row: jnp.searchsorted(
                row, row, side="left").astype(jnp.int32))(se))
    cap = moe_capacity(tg, cfg)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)         # overflow slot

    # --- dispatch: direct-addressed event buffers (G, E*C [+1], d).
    # Two-step: scatter only the int32 *event addresses* into slot->token
    # (tiny payload), then row-GATHER tokens into the expert buffer.  A
    # direct row-scatter makes GSPMD stage full-width f32/u32 all-reduces
    # (measured 489 GB/device on deepseek-moe/train_4k — §Perf D2).
    inv = jnp.full((g, e * cap + 1), -1, jnp.int32)
    inv = jax.vmap(lambda ii, sl, tt: ii.at[sl].set(tt))(inv, slot, st)
    inv = inv[:, :e * cap]
    de = jax.vmap(lambda xx, ii: jnp.where(
        (ii >= 0)[:, None], jnp.take(xx, jnp.maximum(ii, 0), axis=0), 0))(
        xf, inv)
    de = de.reshape(g, e, cap, d)
    de = sc(de, ("batch", "experts", None, None))  # EP all-to-all happens here

    # --- expert FFNs, one batched einsum over live slots ---
    act = layers.activation_fn(cfg.act)
    up = jnp.einsum("gecd,edf->gecf", de, p["w_up"].astype(cdt))
    if layers.is_glu(cfg.act):
        h = act(jnp.einsum("gecd,edf->gecf", de,
                           p["w_gate"].astype(cdt))) * up
    else:
        h = act(up)
    h = sc(h, ("batch", "experts", None, None))
    h = layers.mnf_sparsify(h, cfg)
    y_ec = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cdt))
    y_ec = sc(y_ec, ("batch", "experts", None, None))

    # --- combine: gather gated expert outputs back to tokens (local) ---
    y_flat = y_ec.reshape(g, e * cap, d)
    y_pad = jnp.pad(y_flat, ((0, 0), (0, 1), (0, 0)))
    contrib = jax.vmap(lambda yy, sl: jnp.take(yy, sl, axis=0))(y_pad, slot)
    contrib = jnp.where(keep[..., None], contrib, 0) * \
        sg[..., None].astype(cdt)
    y = jax.vmap(lambda tt, cc: jnp.zeros((tg, d), cdt).at[tt].add(cc))(
        st, contrib)

    if m.num_shared:
        y = y + layers.mlp_apply(p["shared"], xf, cfg, sc=sc)

    # --- aux: switch-style load-balance loss + drop stats ---
    me = probs.reshape(t, e).mean(axis=0)                    # mean gate / e
    ce = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(
        1.0) / (t * k)
    aux = dict(load_balance_loss=e * jnp.sum(me * ce),
               drop_fraction=1.0 - keep.mean())
    return y.reshape(bsz, s, d), aux
