"""Event-native MLP workloads: the paper's FC/MNIST-class networks.

The MNF paper evaluates FC networks (MNIST MLPs) alongside the CNNs; this
module is the FC twin of ``models/cnn.py``, riding the exact same engine
seams (DESIGN.md §12):

  * dense  — the engine's dense backend + ReLU (the oracle),
  * mnf    — event-resident: ``engine.fire`` emits an ``EventStream`` after
             every hidden layer and the next ``engine.linear`` consumes it
             directly.  Every boundary is FC→FC, which is always
             re-tile-free (the stream already lives in the flattened view),
             so the chained forward has **zero densify points** by
             construction — input encode to logits.  With
             ``cfg.int8_events`` the fire phase emits int8 event values
             carrying ``QParams`` and every boundary requantizes; the
             round-trip twin is then the fake-quant forward, and the chain
             matches it bitwise within a backend (DESIGN.md §12).

``make_mlp_pipeline`` is the single-jit whole-network closure the serving
tier buckets (``launch/serve.py --mlp``); ``mlp_boundary_summary`` is the
static per-boundary accounting serving's boundary report states, with the
same record schema as ``chain_boundary_summary`` so CNN and MLP cells
report through one code path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import engine
from repro.core.fire import FireConfig, fire
from repro.models.cnn import FCSpec, fc_in_events

__all__ = ["MLPSpec", "LENET_300_100", "MLP_MINI", "init_mlp_params",
           "mlp_forward", "make_mlp_forward", "make_mlp_pipeline",
           "mlp_boundary_summary", "mlp_layer_dense_macs",
           "run_mlp_with_stats"]


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    """A fully-connected network: ``in_features -> widths[0] -> ... ->
    widths[-1]`` with a fire (ReLU-family) boundary between layers and raw
    logits out of the last.  ``widths[-1]`` is the class count."""

    name: str
    in_features: int
    widths: tuple

    @property
    def num_classes(self) -> int:
        return self.widths[-1]

    @property
    def layers(self) -> tuple:
        """FCSpec view of the stack — the same layer vocabulary the CNN
        models use, so spec-polymorphic code (serving, benchmarks) can walk
        ``spec.layers`` without caring which family it holds."""
        return tuple(FCSpec(w) for w in self.widths)

    def feature_sizes(self) -> tuple:
        """Input width entering each layer."""
        return (self.in_features,) + self.widths[:-1]


#: The paper's MNIST-class workload: LeNet-300-100 (784 -> 300 -> 100 -> 10),
#: the standard FC benchmark of sparse-accelerator papers.
LENET_300_100 = MLPSpec("lenet_300_100", 784, (300, 100, 10))

#: Seconds-scale smoke MLP exercising both FC→FC chain boundaries — the
#: serving smoke and ``kernel_bench --smoke`` bucket-serve this net.
MLP_MINI = MLPSpec("mlp_mini", 64, (32, 16, 10))


def init_mlp_params(key: jax.Array, spec: MLPSpec,
                    weight_sparsity: float = 0.0):
    """He-initialized FC params; optional unstructured pruning (the paper
    prunes MNIST MLPs to ~10% weight density)."""
    params = []
    for i, (fan_in, out) in enumerate(zip(spec.feature_sizes(), spec.widths)):
        k = jax.random.fold_in(key, i)
        wgt = jax.random.normal(k, (fan_in, out), jnp.float32)
        wgt = wgt * (2.0 / fan_in) ** 0.5
        if weight_sparsity > 0.0:
            keep = jax.random.uniform(jax.random.fold_in(k, 1), wgt.shape)
            wgt = jnp.where(keep >= weight_sparsity, wgt, 0.0)
        params.append(wgt)
    return params


def mlp_layer_dense_macs(spec: MLPSpec):
    """Per-layer dense MAC counts (what a dense accelerator does)."""
    return [fan_in * out
            for fan_in, out in zip(spec.feature_sizes(), spec.widths)]


def mlp_boundary_summary(spec: MLPSpec, *, batch: int = 1,
                         fire_cfg: FireConfig = FireConfig(),
                         engine_cfg: engine.EngineConfig | None = None
                         ) -> dict:
    """Static per-boundary accounting of the chained MLP (no tracing).

    Same schema as ``models.cnn.chain_boundary_summary`` so serving's
    boundary report handles both families through one code path.  Every
    boundary past the input is FC→FC — always eligible, never re-tiled —
    so ``densify`` and ``retile`` are structurally 0; ``routes`` lists the
    ``engine.route_linear`` decision of each stream-consuming boundary
    (DESIGN.md §11/§12).
    """
    cfg = _mlp_cfg(engine_cfg, mnf=True, fire_cfg=fire_cfg)
    out = dict(conv=0, fc=len(spec.widths), pool=0, pool_events=0,
               densify=0, input_encode=0, retile=0, routes=[])
    for fan_in, width in list(zip(spec.feature_sizes(), spec.widths))[1:]:
        dec = engine.route_linear(batch, fan_in, width, cfg)
        out["routes"].append(dict(
            op="linear", route=dec.route, occupancy=dec.occupancy,
            est_event_cost=dec.est_event_cost,
            est_dense_cost=dec.est_dense_cost, source=dec.source,
            shape_class=engine.linear_shape_class(batch, fan_in, width)))
    return out


def _mlp_cfg(base: engine.EngineConfig | None, *, mnf: bool,
             fire_cfg: FireConfig) -> engine.EngineConfig:
    cfg = base or engine.EngineConfig(backend="block")
    if not mnf:
        cfg = cfg.replace(backend="dense")
    return cfg.replace(threshold=fire_cfg.threshold,
                       magnitude=fire_cfg.magnitude,
                       int8_events=cfg.int8_events
                       or fire_cfg.quantize_to_int8)


def _forward(params, x, spec: MLPSpec, *, fire_cfg: FireConfig,
             cfg: engine.EngineConfig, chain: bool,
             stats: list | None = None):
    """The one traced forward body behind ``mlp_forward`` /
    ``make_mlp_pipeline``.

    ``chain=True`` threads one EventStream through fire→linear→fire→…:
    every hidden boundary stays event-only (the fired twin is dropped).
    The chain head passes the dense input straight into ``engine.linear``
    — event backends encode it losslessly at threshold 0, the same encode
    the round-trip twin's first layer performs, so the two paths multiply
    identical tiles from the first layer on and agree bitwise within a
    backend (DESIGN.md §12).  ``chain=False`` is that per-layer round-trip
    twin (dense at every boundary, identical compute geometry).
    """
    # Dispatch at threshold 0: the fire phase already zeroed sub-threshold
    # activations, so the boundary encode must be lossless (DESIGN.md §5).
    fcfg = cfg.replace(threshold=0.0)
    layers = spec.layers
    for i, (layer, wgt) in enumerate(zip(layers, params)):
        if stats is not None:
            in_ev = fc_in_events(x, fire_cfg.threshold)
            stats.append(dict(event_macs=in_ev * layer.out,  # Algorithm 2
                              in_events=in_ev))
        acc = engine.linear(x, wgt, cfg=fcfg)
        last = i == len(layers) - 1
        if last:
            x = acc
        elif chain:
            x = engine.fire(acc, cfg, keep_dense=False)
        else:
            x = fire(acc, fire_cfg)
    return x


def mlp_forward(params, x: jax.Array, spec: MLPSpec, *, mnf: bool = True,
                fire_cfg: FireConfig = FireConfig(),
                engine_cfg: engine.EngineConfig | None = None,
                chain: bool | None = None):
    """x: (B, in_features) -> logits (B, classes).  mnf=False is the oracle.

    ``chain`` selects the event-resident path (default: on for MNF; int8
    requantization chains too); ``chain=False`` forces the per-layer dense
    round-trip twin the chained path is bitwise-measured against.
    """
    cfg = _mlp_cfg(engine_cfg, mnf=mnf, fire_cfg=fire_cfg)
    if chain is None:
        chain = mnf
    return _forward(params, x, spec, fire_cfg=fire_cfg, cfg=cfg,
                    chain=chain and mnf)


def make_mlp_forward(spec: MLPSpec, *, mnf: bool = True,
                     fire_cfg: FireConfig = FireConfig(),
                     engine_cfg: engine.EngineConfig | None = None,
                     chain: bool | None = None):
    """The un-jitted whole-network closure: ``fwd(params, x) -> logits`` —
    the seam the serving tier wraps (bucket-shaped jit or batch-parallel
    ``shard_map`` body, same as ``make_cnn_forward``)."""
    cfg = _mlp_cfg(engine_cfg, mnf=mnf, fire_cfg=fire_cfg)
    if chain is None:
        chain = mnf
    chain = chain and mnf

    def fwd(params, x):
        return _forward(params, x, spec, fire_cfg=fire_cfg, cfg=cfg,
                        chain=chain)

    return fwd


def make_mlp_pipeline(spec: MLPSpec, *, mnf: bool = True,
                      fire_cfg: FireConfig = FireConfig(),
                      engine_cfg: engine.EngineConfig | None = None,
                      chain: bool | None = None, donate: bool = True):
    """One jitted forward per network: ``fn(params, x) -> logits``."""
    fwd = make_mlp_forward(spec, mnf=mnf, fire_cfg=fire_cfg,
                           engine_cfg=engine_cfg, chain=chain)
    return jax.jit(fwd, donate_argnums=(1,) if donate else ())


def run_mlp_with_stats(params, x: jax.Array, spec: MLPSpec,
                       fire_cfg: FireConfig = FireConfig(),
                       engine_cfg: engine.EngineConfig | None = None):
    """Chained MNF forward + per-layer event accounting.

    Returns (logits, stats list); each layer's stats carry ``dense_macs``
    (static), ``event_macs`` (Algorithm 2: in_events × out) and
    ``in_events`` — the events/token quantity ``kernel_bench --mlp``
    sweeps over input sparsity.
    """
    cfg = _mlp_cfg(engine_cfg, mnf=True, fire_cfg=fire_cfg)

    def fwd(p, xx):
        stats: list = []
        logits = _forward(p, xx, spec, fire_cfg=fire_cfg, cfg=cfg,
                          chain=True, stats=stats)
        return logits, tuple(stats)

    logits, traced = jax.jit(fwd)(params, x)
    stats = []
    for macs, tr in zip(mlp_layer_dense_macs(spec), traced):
        d = dict(kind="fc", dense_macs=float(x.shape[0] * macs))
        d.update({k: float(v) for k, v in tr.items()})
        stats.append(d)
    return logits, stats
