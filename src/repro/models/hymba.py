"""Hymba block: parallel attention + Mamba(SSM) heads (arXiv:2411.13676).

Both paths read the same pre-normed input; outputs are RMS-normalized and
averaged (the paper's fused-head mean combination).  Sliding-window
attention everywhere except the listed global layers; the SSM path is
window-free (its state carries unbounded context) — which is what makes the
arch sub-quadratic for the long_500k cell.  Meta-tokens are not modeled
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, ssm
from repro.models.layers import rms_norm
from repro.models.param_utils import Init

__all__ = ["hymba_block_init", "hymba_block_apply", "hymba_block_decode"]


def hymba_block_init(key: jax.Array, cfg: ModelConfig):
    b = Init(key, jnp.dtype(cfg.param_dtype))
    ap, asx = attention.attn_init(jax.random.fold_in(key, 1), cfg)
    mp, msx = ssm.mamba_init(jax.random.fold_in(key, 2), cfg,
                             d_inner=cfg.d_model)
    b.params["attn"], b.specs["attn"] = ap, asx
    b.params["mamba"], b.specs["mamba"] = mp, msx
    b.ones("norm_attn", (cfg.d_model,), ("embed",))
    b.ones("norm_mamba", (cfg.d_model,), ("embed",))
    return b.done()


def hymba_block_apply(p, x: jax.Array, *, cfg: ModelConfig,
                      positions: jax.Array, window, cache=None,
                      decode_pos=None, sc=lambda x, ax: x):
    """x: (B, S, d) pre-normed.  cache: dict(attn=..., conv=..., ssm=...)."""
    attn_cache = cache.get("attn") if cache else None
    a_out, a_cache = attention.attn_apply(
        p["attn"], x, cfg=cfg, positions=positions, window=window,
        cache=attn_cache, decode_pos=decode_pos, sc=sc)
    # Single-token step (decode) vs. sequence scan (train/prefill) is a
    # *static* dispatch on the sequence length.
    if cache is not None and x.shape[1] == 1:
        m_out, m_state, m_events = ssm.mamba_step(
            p["mamba"], x, cfg, (cache["conv"], cache["ssm"]),
            with_events=True)
    else:
        m_out, m_state = ssm.mamba_apply(p["mamba"], x, cfg, sc=sc)
        m_events = jnp.zeros((), jnp.float32)
    y = 0.5 * (rms_norm(a_out, p["norm_attn"] - 1.0, cfg.norm_eps) +
               rms_norm(m_out, p["norm_mamba"] - 1.0, cfg.norm_eps))
    new_cache = dict(attn=a_cache, conv=m_state[0], ssm=m_state[1])
    if cfg.mnf.enabled:
        # Per-token fired-event count of the gated state update; prefill
        # seeds zero so the cache pytree structure is step-invariant.
        new_cache["events"] = m_events
    return y, new_cache


def hymba_block_decode(p, x, *, cfg, positions, window, cache, decode_pos,
                       sc=lambda x, ax: x):
    return hymba_block_apply(p, x, cfg=cfg, positions=positions,
                             window=window, cache=cache,
                             decode_pos=decode_pos, sc=sc)
