"""Parameter creation with paired logical-axis specs.

Params are plain nested dicts of jnp arrays.  Every leaf has a *spec*: a
tuple of logical axis names (one per dim) living in a structurally identical
dict.  ``repro.parallel.sharding`` resolves specs -> NamedSharding via the
per-arch rule table; scan-stacked layers prepend the "layers" axis.
"""
from __future__ import annotations

import zlib
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["Init", "stack_layer_params", "tree_paths"]


class Init:
    """Collects (params, specs) while initializing one module tree."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self.key = key
        self.dtype = dtype
        self.params: dict[str, Any] = {}
        self.specs: dict[str, Any] = {}

    def _leaf_key(self, name: str) -> jax.Array:
        return jax.random.fold_in(self.key, zlib.crc32(name.encode()))

    def dense(self, name: str, shape: tuple[int, ...], axes: tuple[str, ...],
              *, scale: float | None = None) -> None:
        """LeCun-normal initialized weight (fan-in = shape[-2] by default)."""
        assert len(shape) == len(axes), (name, shape, axes)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        s = (1.0 / fan_in) ** 0.5 if scale is None else scale
        self.params[name] = (
            jax.random.normal(self._leaf_key(name), shape, self.dtype) * s)
        self.specs[name] = axes

    def zeros(self, name: str, shape: tuple[int, ...],
              axes: tuple[str, ...]) -> None:
        self.params[name] = jnp.zeros(shape, self.dtype)
        self.specs[name] = axes

    def ones(self, name: str, shape: tuple[int, ...],
             axes: tuple[str, ...]) -> None:
        self.params[name] = jnp.ones(shape, self.dtype)
        self.specs[name] = axes

    def const(self, name: str, value: jax.Array,
              axes: tuple[str, ...]) -> None:
        self.params[name] = value.astype(self.dtype)
        self.specs[name] = axes

    def sub(self, name: str, child: "Init") -> None:
        self.params[name] = child.params
        self.specs[name] = child.specs

    def done(self):
        return self.params, self.specs


def stack_layer_params(init_layer_fn, keys: jax.Array):
    """vmap a per-layer init over a (L,)-keys array -> stacked params.

    Returns (stacked params with leading L dim, specs with "layers"
    prepended).
    """
    params = jax.vmap(lambda k: init_layer_fn(k)[0])(keys)
    # Specs are python data; a second (DCE'd under jit) call extracts them.
    specs = init_layer_fn(keys[0])[1]
    specs = jax.tree.map(lambda ax: ("layers",) + tuple(ax), specs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return params, specs


def tree_paths(tree) -> list[str]:
    """Flat list of '/'-joined key paths (debug/checkpoint naming)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, _leaf in flat:
        out.append("/".join(str(getattr(p, "key", p)) for p in path))
    return out
