"""Model zoo: LM stacks for the assigned architectures + the paper's CNNs."""
from repro.models.transformer import (active_params, cache_specs,
                                      count_params, decode_step, forward,
                                      init_cache, init_params, input_specs,
                                      lm_loss, prefill)

__all__ = ["active_params", "cache_specs", "count_params", "decode_step",
           "forward", "init_cache", "init_params", "input_specs", "lm_loss",
           "prefill"]
