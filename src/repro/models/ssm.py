"""State-space / linear-recurrence blocks: RWKV6 (Finch) and Mamba1.

RWKV6 here is the pure-XLA model path: a chunked matmul formulation
(lax.scan over chunks, intra-chunk work on the MXU) that matches the exact
recurrence (and the Pallas kernel in repro.kernels.wkv6) whenever the
per-step log-decay respects the stability clamp ``WKV_LOG_DECAY_MIN``; the
clamp is a documented deviation (DESIGN.md §8) needed because the chunked
factorization exponentiates inverse decays.  The Pallas kernel has no clamp.

Mamba1 (hymba's parallel-SSM heads) uses an associative scan over time for
train/prefill and an O(1)-state update for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.param_utils import Init

__all__ = ["WKV_LOG_DECAY_MIN", "wkv6_chunked", "wkv6_step",
           "wkv6_step_gated", "rwkv6_block_init", "rwkv6_block_apply",
           "rwkv6_block_decode", "mamba_init", "mamba_apply", "mamba_step"]

# Per-step log-decay clamp for the chunked-parallel path: with chunk C the
# largest inverse-decay exponent is C*|min|; C=32 * 2.5 = 80 < log(f32 max).
WKV_LOG_DECAY_MIN = -2.5


# ---------------------------------------------------------------------------
# WKV6 recurrence — chunked matmul formulation (XLA path)
# ---------------------------------------------------------------------------

def wkv6_chunked(r, k, v, w, u, s0=None, *, chunk: int = 32):
    """r,k,v,w: (B, H, T, D); u: (H, D); s0: (B, H, D, D) or None.

    Exact (vs. the sequential recurrence) for w >= exp(WKV_LOG_DECAY_MIN);
    smaller decays are clamped.  Returns (o (B,H,T,D) f32, s_final).
    """
    b, h, t, d = r.shape
    pad = (-t) % chunk
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)),
                    constant_values=1.0)
    tp = t + pad
    nc = tp // chunk
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)

    f32 = jnp.float32
    rc = r.astype(f32).reshape(b, h, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    kc = k.astype(f32).reshape(b, h, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.astype(f32).reshape(b, h, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    lw = jnp.log(jnp.clip(w.astype(f32), jnp.exp(WKV_LOG_DECAY_MIN), 1.0))
    lwc = lw.reshape(b, h, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    uf = u.astype(f32)

    tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)      # strict lower

    def body(s, xs):
        rci, kci, vci, lwi = xs                              # (B,H,C,D)
        lp = jnp.cumsum(lwi, axis=2) - lwi                   # exclusive
        lpc = lp[:, :, -1:, :] + lwi[:, :, -1:, :]           # total decay
        rq = rci * jnp.exp(lp)
        kk = kci * jnp.exp(-(lp + lwi))                      # bounded by clamp
        a = jnp.einsum("bhtd,bhsd->bhts", rq, kk) * tri
        diag = jnp.einsum("bhtd,hd,bhtd->bht", rci, uf, kci)
        o = (jnp.einsum("bhts,bhsd->bhtd", a, vci) +
             diag[..., None] * vci +
             jnp.einsum("bhtd,bhde->bhte", rq, s))
        ks = kci * jnp.exp(lpc - (lp + lwi))                 # <= 1, safe
        s = (jnp.exp(lpc[:, :, 0, :])[..., None] * s +
             jnp.einsum("bhtd,bhte->bhde", ks, vci))
        return s, o

    s_fin, o = jax.lax.scan(body, s0.astype(f32), (rc, kc, vc, lwc))
    o = o.transpose(1, 2, 0, 3, 4).reshape(b, h, tp, d)[:, :, :t]
    return o, s_fin


def _decode_engine_cfg(cfg: ModelConfig):
    """The EngineConfig the fire-gated decode runs under, or None when MNF
    is off (the dense step stays the only path)."""
    if not cfg.mnf.enabled:
        return None
    from repro.engine import EngineConfig
    return EngineConfig.from_mnf(cfg.mnf)


def wkv6_step(r, k, v, w, u, s):
    """Single decode step.  r,k,v,w: (B, H, D); u: (H, D); s: (B, H, D, D).

    Delegates to the shared dense oracle ``kernels.wkv6.step.wkv6_step_ref``
    — the same formulation the event-gated decode runs — so the θ=0
    contract (gated step bitwise-equal to the dense step on the block
    backend) is by construction, not by coincidence (DESIGN.md §13).
    """
    from repro.kernels.wkv6.step import wkv6_step_ref
    b, h, d = r.shape
    fl = lambda z: z.reshape(b * h, d)
    uf = jnp.broadcast_to(u, (b, h, d)).reshape(b * h, d)
    o, s_new = wkv6_step_ref(fl(r), fl(k), fl(v), fl(w), uf,
                             s.reshape(b * h, d, d))
    return o.reshape(b, h, d), s_new.reshape(b, h, d, d)


def wkv6_step_gated(r, k, v, w, u, s, ecfg):
    """Fire-gated single decode step (DESIGN.md §13).

    Same signature/shapes as :func:`wkv6_step` plus the engine config; the
    key vector — the state update's increment drive — is thresholded by
    signed fire and the state update skips dead channel-blocks.  Returns
    (o, s_new, n_events) with ``n_events`` the traced per-token scalar
    event count (what the serving loop reports per layer).
    """
    from repro import engine
    b, h, d = r.shape
    f32 = jnp.float32
    fl = lambda z: z.reshape(b * h, d).astype(f32)
    uf = jnp.broadcast_to(u, (b, h, d)).reshape(b * h, d).astype(f32)
    stream = engine.fire_delta(fl(k), ecfg)
    o, s_new = engine.recurrent_step(
        "wkv6", stream, s.reshape(b * h, d, d), ecfg.for_recurrent(d),
        r=fl(r), v=fl(v), w=fl(w), u=uf)
    return (o.reshape(b, h, d), s_new.reshape(b, h, d, d),
            stream.num_scalar_events.astype(f32))


# ---------------------------------------------------------------------------
# RWKV6 block (time-mix + channel-mix)
# ---------------------------------------------------------------------------

def rwkv6_block_init(key: jax.Array, cfg: ModelConfig):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    assert h * hd == d, "rwkv6: heads * head_dim must equal d_model"
    b = Init(key, jnp.dtype(cfg.param_dtype))
    b.ones("ln1", (d,), ("embed",))
    b.ones("ln2", (d,), ("embed",))
    # time-mix lerp coefficients (per-channel, one per r/k/v/w/g)
    for nm in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"):
        b.const(nm, jnp.full((d,), 0.5), ("embed",))
    b.dense("wr", (d, d), ("embed", "q_heads"))
    b.dense("wk", (d, d), ("embed", "q_heads"))
    b.dense("wv", (d, d), ("embed", "q_heads"))
    b.dense("wg", (d, d), ("embed", "q_heads"))
    b.dense("wo", (d, d), ("q_heads", "embed"))
    # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
    lora = max(32, d // 64)
    b.const("w0", jnp.full((d,), -0.6), ("embed",))          # soft init decay
    b.dense("w_a", (d, lora), ("embed", "lora"))
    b.dense("w_b", (lora, d), ("lora", "embed"))
    b.const("u", jnp.zeros((h, hd)), ("q_heads", None))      # bonus
    b.ones("gn", (d,), ("embed",))                           # group norm gain
    # channel mix
    b.const("mu_ck", jnp.full((d,), 0.5), ("embed",))
    b.const("mu_cr", jnp.full((d,), 0.5), ("embed",))
    b.dense("ck", (d, cfg.d_ff), ("embed", "ff"))
    b.dense("cv", (cfg.d_ff, d), ("ff", "embed"))
    b.dense("cr", (d, d), ("embed", "q_heads"))
    return b.done()


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Shifted-by-one sequence; position 0 sees ``prev`` (decode carry)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _time_mix_inputs(p, xn, xs):
    mix = lambda mu: xn + (xs - xn) * mu.astype(xn.dtype)
    return (mix(p["mu_r"]), mix(p["mu_k"]), mix(p["mu_v"]),
            mix(p["mu_w"]), mix(p["mu_g"]))


def _rwkv_time_mix(p, xn, xs, cfg, state, step: bool, sc=lambda x, ax: x):
    """xn, xs: (B, T, d) (T == 1 for decode steps)."""
    b, t, _ = xn.shape
    h, hd = cfg.num_heads, cfg.head_dim
    cdt = xn.dtype
    xr, xk, xv, xw, xg = _time_mix_inputs(p, xn, xs)
    r = xr @ p["wr"].astype(cdt)
    k = xk @ p["wk"].astype(cdt)
    v = xv @ p["wv"].astype(cdt)
    g = jax.nn.silu(xg @ p["wg"].astype(cdt))
    lw_arg = (p["w0"].astype(jnp.float32) +
              jnp.tanh(xw.astype(jnp.float32) @ p["w_a"].astype(jnp.float32))
              @ p["w_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(lw_arg))                            # (…, d) in (0,1)

    n_ev = None
    if step:
        sh = lambda z: z.reshape(b, h, hd)
        ecfg = _decode_engine_cfg(cfg)
        if ecfg is not None:
            o, s_new, n_ev = wkv6_step_gated(
                sh(r), sh(k), sh(v), sh(w.astype(jnp.float32)), p["u"],
                state, ecfg)
        else:
            o, s_new = wkv6_step(sh(r), sh(k), sh(v),
                                 sh(w.astype(jnp.float32)), p["u"], state)
        o = o.reshape(b, 1, h * hd)
    else:
        sh = lambda z: sc(z.reshape(b, t, h, hd).transpose(0, 2, 1, 3),
                          ("batch", "heads", None, None))
        o, s_new = wkv6_chunked(sh(r), sh(k), sh(v),
                                sh(w.astype(jnp.float32)), p["u"],
                                state, chunk=cfg.wkv_chunk)
        o = sc(o, ("batch", "heads", None, None))
        o = o.transpose(0, 2, 1, 3).reshape(b, t, h * hd)
    # per-head group norm + gate
    oshape = o.shape
    og = o.reshape(*oshape[:-1], h, hd).astype(jnp.float32)
    mu = og.mean(-1, keepdims=True)
    var = og.var(-1, keepdims=True)
    og = (og - mu) * jax.lax.rsqrt(var + 64e-5)
    o = (og.reshape(oshape) * p["gn"].astype(jnp.float32)).astype(cdt)
    out = (o * g) @ p["wo"].astype(cdt)
    return out, s_new, n_ev


def _rwkv_channel_mix(p, xn, xs, cfg, sc=lambda x, ax: x):
    cdt = xn.dtype
    xk = xn + (xs - xn) * p["mu_ck"].astype(cdt)
    xr = xn + (xs - xn) * p["mu_cr"].astype(cdt)
    k = jnp.square(jax.nn.relu(xk @ p["ck"].astype(cdt)))    # relu^2: sparse
    k = sc(k, ("batch",) + (None,) * (k.ndim - 2) + ("ff",))
    k = layers.mnf_sparsify(k, cfg)                          # MNF exact here
    return jax.nn.sigmoid(xr @ p["cr"].astype(cdt)) * (
        k @ p["cv"].astype(cdt))


def rwkv6_block_apply(p, x: jax.Array, cfg: ModelConfig, wkv_state=None,
                      sc=lambda x, ax: x):
    """Train/prefill.  x: (B, T, d).  Returns (y, decode-ready state dict)."""
    xn = layers.rms_norm(x, p["ln1"] - 1.0, cfg.norm_eps)
    xs = _token_shift(xn, None)
    att, s_fin, _ = _rwkv_time_mix(p, xn, xs, cfg, wkv_state, step=False,
                                   sc=sc)
    x = x + att
    xn2 = layers.rms_norm(x, p["ln2"] - 1.0, cfg.norm_eps)
    xs2 = _token_shift(xn2, None)
    x = x + _rwkv_channel_mix(p, xn2, xs2, cfg, sc=sc)
    state = dict(shift_att=xn[:, -1], shift_ffn=xn2[:, -1], wkv=s_fin)
    if cfg.mnf.enabled:
        # Decode fills this with the per-token fired-event count; prefill
        # seeds it so the cache pytree structure is step-invariant.
        state["events"] = jnp.zeros((), jnp.float32)
    return x, state


def rwkv6_block_decode(p, x: jax.Array, cfg: ModelConfig, state: dict):
    """Decode one token.  x: (B, 1, d); state carries shifts + wkv."""
    xn = layers.rms_norm(x, p["ln1"] - 1.0, cfg.norm_eps)
    xs = state["shift_att"][:, None, :].astype(xn.dtype)
    att, s_new, n_ev = _rwkv_time_mix(p, xn, xs, cfg, state["wkv"], step=True)
    x = x + att
    xn2 = layers.rms_norm(x, p["ln2"] - 1.0, cfg.norm_eps)
    xs2 = state["shift_ffn"][:, None, :].astype(xn2.dtype)
    x = x + _rwkv_channel_mix(p, xn2, xs2, cfg)
    new_state = dict(shift_att=xn[:, 0], shift_ffn=xn2[:, 0], wkv=s_new)
    if cfg.mnf.enabled:
        new_state["events"] = n_ev if n_ev is not None \
            else jnp.zeros((), jnp.float32)
    return x, new_state


# ---------------------------------------------------------------------------
# Mamba1 (selective SSM) — hymba's parallel-SSM heads
# ---------------------------------------------------------------------------

def mamba_init(key: jax.Array, cfg: ModelConfig, d_inner: int | None = None):
    ssm = cfg.ssm
    d = cfg.d_model
    di = d_inner or ssm.expand * d
    n = ssm.state_dim
    dt_rank = ssm.dt_rank or -(-d // 16)
    b = Init(key, jnp.dtype(cfg.param_dtype))
    b.dense("w_in", (d, 2 * di), ("embed", "ff"))            # x and z
    b.dense("conv_w", (ssm.conv_dim, di), (None, "ff"), scale=0.5)
    b.zeros("conv_b", (di,), ("ff",))
    b.dense("w_bcdt", (di, 2 * n + dt_rank), ("ff", None))
    b.dense("w_dt", (dt_rank, di), (None, "ff"), scale=1.0)
    b.zeros("dt_bias", (di,), ("ff",))
    b.const("a_log", jnp.log(jnp.broadcast_to(
        jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))), ("ff", None))
    b.ones("d_skip", (di,), ("ff",))
    b.dense("w_out", (di, d), ("ff", "embed"))
    return b.done()


def _mamba_bcdt(p, xc, cfg):
    ssm = cfg.ssm
    n = ssm.state_dim
    dt_rank = ssm.dt_rank or -(-cfg.d_model // 16)
    bcdt = xc @ p["w_bcdt"].astype(xc.dtype)
    bmat = bcdt[..., :n]
    cmat = bcdt[..., n:2 * n]
    dt = jax.nn.softplus(
        bcdt[..., 2 * n:] @ p["w_dt"].astype(xc.dtype) +
        p["dt_bias"].astype(xc.dtype))                       # (.., di)
    return bmat, cmat, dt


def mamba_apply(p, x: jax.Array, cfg: ModelConfig, sc=lambda x, ax: x):
    """Train/prefill.  x: (B, T, d) -> (y (B, T, d), (conv_state, ssm_state))."""
    ssm = cfg.ssm
    bsz, t, d = x.shape
    cdt = x.dtype
    xz = x @ p["w_in"].astype(cdt)
    xz = sc(xz, ("batch", None, "ff"))
    xc, z = jnp.split(xz, 2, axis=-1)                        # (B, T, di)
    di = xc.shape[-1]
    # causal depthwise conv, width ssm.conv_dim
    cw = ssm.conv_dim
    xpad = jnp.pad(xc, ((0, 0), (cw - 1, 0), (0, 0)))
    xconv = sum(xpad[:, i:i + t, :] * p["conv_w"][i].astype(cdt)
                for i in range(cw)) + p["conv_b"].astype(cdt)
    xs = jax.nn.silu(xconv)
    bmat, cmat, dt = _mamba_bcdt(p, xs, cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # (di, n)
    di = xc.shape[-1]
    n = ssm.state_dim

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    # Time-chunked selective scan: associative scan within a chunk, carried
    # state across chunks — live memory O(B·C·di·n) instead of O(B·T·di·n).
    ch = min(ssm.scan_chunk, t)
    pad = (-t) % ch
    if pad:
        zp = lambda u: jnp.pad(u, ((0, 0), (0, pad)) + ((0, 0),) * (u.ndim - 2))
        xs_p, bmat_p, cmat_p, dt_p = zp(xs), zp(bmat), zp(cmat), zp(dt)
    else:
        xs_p, bmat_p, cmat_p, dt_p = xs, bmat, cmat, dt
    nc = (t + pad) // ch
    resh = lambda u: u.reshape(bsz, nc, ch, u.shape[-1]).swapaxes(0, 1)

    def chunk_body(h_in, xs_c):
        xc_c, b_c, c_c, dt_c = xs_c                          # (B, C, …)
        da_c = jnp.exp(dt_c.astype(jnp.float32)[..., None] * a)
        dbx_c = (dt_c.astype(jnp.float32) *
                 xc_c.astype(jnp.float32))[..., None] \
            * b_c.astype(jnp.float32)[..., None, :]          # (B,C,di,n)
        da_c = sc(da_c, ("batch", None, "ff", None))
        dbx_c = sc(dbx_c, ("batch", None, "ff", None))
        da_cum, h_loc = jax.lax.associative_scan(
            combine, (da_c, dbx_c), axis=1)
        # associative_scan drops annotations; re-pin the state sharding or
        # SPMD replicates (B, C, di, n) f32 every chunk (§Perf H1).
        h = sc(h_loc + da_cum * h_in[:, None],
               ("batch", None, "ff", None))                  # carry in
        y_c = jnp.einsum("bcdn,bcn->bcd", h, c_c.astype(jnp.float32))
        return h[:, -1], sc(y_c, ("batch", None, "ff"))

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    chunk_body = jax.checkpoint(chunk_body, policy=jax.checkpoint_policies
                                .nothing_saveable, prevent_cse=False)
    h_fin, y = jax.lax.scan(chunk_body, h0,
                            (resh(xs_p), resh(bmat_p), resh(cmat_p),
                             resh(dt_p)))
    y = y.swapaxes(0, 1).reshape(bsz, t + pad, di)[:, :t]
    y = (y + p["d_skip"].astype(jnp.float32) * xs.astype(jnp.float32))
    y = y.astype(cdt) * jax.nn.silu(z)
    out = y @ p["w_out"].astype(cdt)
    assert cw > 1, "conv width must exceed 1"
    conv_state = xpad[:, -(cw - 1):, :]                      # last cw-1 inputs
    return out, (conv_state, h_fin)


def mamba_step(p, x: jax.Array, cfg: ModelConfig, state, *,
               with_events: bool = False):
    """Decode one token.  x: (B, 1, d); state = (conv_state (B, cw-1, di),
    ssm_state (B, di, n)).

    With MNF enabled the state update is fire-gated (DESIGN.md §13): the
    increment gate g = Δt·silu(xconv) is thresholded by signed fire and the
    h update skips dead channel-blocks.  The dense path delegates to the
    shared oracle ``kernels.mamba_scan.step.mamba_step_ref`` — the same
    formulation the gated backends run, so the θ=0 contract is by
    construction.  ``with_events=True`` additionally returns the traced
    per-token scalar event count (out, state, n_events).
    """
    from repro.kernels.mamba_scan.step import mamba_step_ref
    ssm = cfg.ssm
    conv_state, h = state
    bsz = x.shape[0]
    cdt = x.dtype
    f32 = jnp.float32
    xz = x[:, 0] @ p["w_in"].astype(cdt)
    xc, z = jnp.split(xz, 2, axis=-1)
    cw = ssm.conv_dim
    win = jnp.concatenate([conv_state, xc[:, None, :]], axis=1)  # (B, cw, di)
    xconv = jnp.einsum("bcd,cd->bd", win, p["conv_w"].astype(cdt)) \
        + p["conv_b"].astype(cdt)
    xs = jax.nn.silu(xconv)
    bmat, cmat, dt = _mamba_bcdt(p, xs, cfg)
    a = -jnp.exp(p["a_log"].astype(f32))
    da = jnp.exp(dt.astype(f32)[..., None] * a)              # (B, di, n)
    gdrive = dt.astype(f32) * xs.astype(f32)                 # increment gate
    ecfg = _decode_engine_cfg(cfg)
    if ecfg is not None:
        from repro import engine
        stream = engine.fire_delta(gdrive, ecfg)
        y, h = engine.recurrent_step(
            "mamba", stream, h, ecfg.for_recurrent(gdrive.shape[-1]),
            da=da, bmat=bmat.astype(f32), cmat=cmat.astype(f32))
        n_ev = stream.num_scalar_events.astype(f32)
    else:
        y, h = mamba_step_ref(gdrive, da, bmat.astype(f32),
                              cmat.astype(f32), h)
        n_ev = jnp.zeros((), f32)
    y = y + p["d_skip"].astype(f32) * xs.astype(f32)
    y = y.astype(cdt) * jax.nn.silu(z)
    out = (y @ p["w_out"].astype(cdt))[:, None, :]
    if with_events:
        return out, (win[:, 1:], h), n_ev
    return out, (win[:, 1:], h)
