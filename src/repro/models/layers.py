"""Shared layer primitives: norms, RoPE, embeddings, MLP (with MNF fire).

All apply-functions are pure; params are dicts built by ``Init`` with
logical-axis specs (see param_utils).  Compute runs in cfg.compute_dtype
(bf16 by default) with f32 norm/softmax internals.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param_utils import Init

__all__ = ["rms_norm", "layer_norm", "apply_rope", "activation_fn",
           "max_pool_nhwc", "mlp_init", "mlp_apply", "embed_init",
           "embed_apply", "mnf_sparsify"]


def max_pool_nhwc(x: jax.Array, k: int, stride: int) -> jax.Array:
    """VALID max-pool over the spatial axes of a (B, H, W, C) feature map.

    The dense oracle of the event-native pool: the chained MNF path pools
    in the event domain (``engine.maxpool2d`` — segment max over stream
    events, bit-identical to this, DESIGN.md §7); this dense form serves
    the round-trip twin and ineligible-stream fallbacks.
    """
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1),
        "VALID")


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))
            ).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
            ).astype(dt)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(theta) *
                    (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..S,half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activation_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    if name in ("silu_glu", "silu"):
        return jax.nn.silu
    if name in ("gelu_glu", "gelu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def is_glu(name: str) -> bool:
    return name.endswith("_glu")


# ---------------------------------------------------------------------------
# MNF integration point
# ---------------------------------------------------------------------------

def mnf_sparsify(h: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Fire phase on hidden activations + block-event masking for the down
    projection — the MNF multiply phase's *semantics* on the pure-XLA path.

    With threshold 0 and a ReLU-family activation this is the identity (the
    activation already fired), so dense == MNF exactly.  Delegates to
    ``repro.engine.sparsify`` (the engine owns tile geometry and the
    event_matmul kernel parity — DESIGN.md §3); this wrapper only adapts the
    model-level MNFConfig.
    """
    m = cfg.mnf
    if not m.enabled:
        return h
    from repro import engine
    return engine.sparsify(h, engine.EngineConfig.from_mnf(m))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None,
             d_model: int | None = None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    b = Init(key, jnp.dtype(cfg.param_dtype))
    if is_glu(cfg.act):
        b.dense("w_gate", (d, f), ("embed", "ff"))
    b.dense("w_up", (d, f), ("embed", "ff"))
    b.dense("w_down", (f, d), ("ff", "embed"))
    return b.done()


def mlp_apply(p, x: jax.Array, cfg: ModelConfig,
              sc=lambda x, ax: x) -> jax.Array:
    """x: (..., d_model) -> (..., d_model); fire phase between up and down."""
    act = activation_fn(cfg.act)
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    up = xc @ p["w_up"].astype(cdt)
    if is_glu(cfg.act):
        h = act(xc @ p["w_gate"].astype(cdt)) * up
    else:
        h = act(up)
    h = sc(h, ("batch",) + (None,) * (h.ndim - 2) + ("ff",))
    h = mnf_sparsify(h, cfg)          # MNF fire phase (exact for ReLU-family)
    return (h @ p["w_down"].astype(cdt)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, cfg: ModelConfig):
    b = Init(key, jnp.dtype(cfg.param_dtype))
    # 1/sqrt(d) rows: keeps tied-unembedding logits at unit scale (the
    # embed_apply path re-scales inputs by sqrt(d) for tied configs).
    b.dense("tok", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
            scale=cfg.d_model ** -0.5)
    if not cfg.tie_embeddings:
        b.dense("unembed", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return b.done()


def embed_apply(p, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    emb = jnp.take(p["tok"], tokens, axis=0).astype(cdt)
    if cfg.tie_embeddings:
        emb = emb * jnp.asarray(cfg.d_model, jnp.float32).astype(cdt) ** 0.5
    return emb


def unembed_matrix(p, cfg: ModelConfig) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        return p["tok"].T.astype(cdt)
    return p["unembed"].astype(cdt)
