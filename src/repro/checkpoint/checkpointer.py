"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout:  <dir>/step_<N>/
           meta.json            (step, leaf paths, shapes, dtypes)
           arrays.npz           (one entry per leaf, path-keyed)
         <dir>/LATEST           (atomic pointer file)

Writes go to a tmp dir + os.replace rename — a crash mid-save never corrupts
the previous checkpoint (step-atomicity).  ``save_async`` runs serialization
on a background thread (training continues).  ``restore`` takes an optional
shardings tree and device_puts each leaf — restoring onto a *different* mesh
(elastic scale-up/down) is just passing the new mesh's shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "all_steps"]

_SEP = "::"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no bf16 descriptor: store the raw bits; restore views
            # them back via the target leaf dtype.
            arr = arr.view(np.uint16)
        out[key] = arr
    return out


def save(tree, ckpt_dir: str, step: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = dict(step=step,
                leaves={k: dict(shape=list(v.shape), dtype=str(v.dtype))
                        for k, v in arrays.items()})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def save_async(tree, ckpt_dir: str, step: int) -> threading.Thread:
    """Snapshot to host memory synchronously, write on a worker thread."""
    host_tree = jax.tree.map(np.asarray, tree)   # device->host copy now
    t = threading.Thread(target=save, args=(host_tree, ckpt_dir, step),
                         daemon=False)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(tree_like, ckpt_dir: str, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``tree_like`` (shapes must match).

    shardings: optional matching tree of NamedSharding — leaves are
    device_put with them (reshard-on-restore for elastic meshes).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    leaves = []
    for (pathk, leaf), shd in zip(flat, shard_flat):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pathk)
        arr = data[key]
        if (jnp.dtype(leaf.dtype) == jnp.bfloat16
                and arr.dtype != np.dtype(jnp.bfloat16)):
            arr = arr.view(np.dtype(jnp.bfloat16))
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        if shd is not None:
            leaves.append(jax.device_put(jnp.asarray(arr), shd))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, leaves), step
