from repro.checkpoint.checkpointer import (all_steps, latest_step, restore,
                                           save, save_async)

__all__ = ["all_steps", "latest_step", "restore", "save", "save_async"]
