"""Batched serving driver: prefill + decode loop with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mnf", action="store_true",
                    help="enable the MNF fire phase in MLP blocks")
    ap.add_argument("--mnf-threshold", type=float, default=0.0)
    ap.add_argument("--mnf-pallas", action="store_true",
                    help="route the MNF multiply phase through the Pallas "
                         "engine backend (default: pure-XLA block backend)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # --mnf-threshold / --mnf-pallas imply --mnf: a sub-flag alone must not
    # silently benchmark the dense path.
    if args.mnf or args.mnf_pallas or args.mnf_threshold != 0.0:
        cfg = dataclasses.replace(
            cfg, mnf=dataclasses.replace(cfg.mnf, enabled=True,
                                         threshold=args.mnf_threshold,
                                         use_pallas=args.mnf_pallas))
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("serve", max_len, args.batch, "decode")
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    pre = make_prefill_step(cfg, ShapeConfig("pf", max_len, args.batch,
                                             "prefill"), mesh)
    srv = make_serve_step(cfg, shape, mesh)

    key = jax.random.PRNGKey(0)
    with mesh:
        params = jax.jit(lambda k: init_params(k, cfg)[0])(key)
        toks = jax.random.randint(key, (args.batch, max_len), 0,
                                  cfg.vocab_size, jnp.int32)
        batch = dict(tokens=toks)
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        if cfg.encoder_decoder:
            batch["audio_frames"] = jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))

        t0 = time.time()
        logits, cache = pre.fn(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        out_tokens = []
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for i in range(args.gen):
            step_batch = dict(batch, tokens=cur)
            logits, cache = srv.fn(params, cache, step_batch,
                                   jnp.asarray(args.prompt_len + i,
                                               jnp.int32))
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            cur = cur.astype(jnp.int32)
            out_tokens.append(cur)
        jax.block_until_ready(cur)
        t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(json.dumps(dict(
        arch=cfg.name, batch=args.batch, prompt_len=args.prompt_len,
        generated=args.gen,
        prefill_s=round(t_prefill, 3),
        decode_tok_per_s=round(args.gen * args.batch / t_decode, 1),
        mnf=cfg.mnf.enabled,
        engine=dataclasses.asdict(srv.engine),
        sample_tokens=[int(t) for t in gen[0][:8]])))


if __name__ == "__main__":
    main()
