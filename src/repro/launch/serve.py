"""Batched serving driver: LM prefill + decode loop, or event-resident CNN.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16

CNN mode serves batched image requests through the single-jit MNF pipeline
(models/cnn.make_cnn_pipeline — activations stay event-resident between conv
layers, DESIGN.md §5/§5.1).  MNF is the default; ``--dense`` serves the
oracle path instead:

  PYTHONPATH=src python -m repro.launch.serve --cnn alexnet --cnn-size 64 \
      --batch 4 --batches 8
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import (make_cnn_serve_step, make_prefill_step,
                                make_serve_step)
from repro.models import init_params


def serve_cnn(args) -> None:
    """Batched CNN inference through the compiled event-resident pipeline."""
    from repro import engine
    from repro.core.fire import FireConfig
    from repro.models.cnn import (ALEXNET, ALEXNET_DS, VGG16, VGG16_DS,
                                  init_cnn_params)

    spec = {"alexnet": ALEXNET, "vgg16": VGG16, "alexnet_ds": ALEXNET_DS,
            "vgg16_ds": VGG16_DS}[args.cnn].scaled(args.cnn_size)
    ecfg = engine.EngineConfig(
        backend="pallas" if args.mnf_pallas else "auto",
        threshold=args.mnf_threshold)
    plan = make_cnn_serve_step(spec, args.batch, mnf=not args.dense,
                               engine_cfg=ecfg,
                               fire_cfg=FireConfig(
                                   threshold=args.mnf_threshold))

    key = jax.random.PRNGKey(0)
    params = init_cnn_params(key, spec, weight_sparsity=args.weight_sparsity)

    def batch_at(step: int) -> jax.Array:
        # Fresh buffer per request — the pipeline donates its input.
        return jax.nn.relu(jax.random.normal(
            jax.random.fold_in(key, step),
            (args.batch, spec.input_size, spec.input_size, spec.in_ch)))

    t0 = time.time()
    logits = plan.fn(params, batch_at(0))
    jax.block_until_ready(logits)
    t_compile = time.time() - t0

    t0 = time.time()
    preds = []
    for step in range(1, args.batches + 1):
        logits = plan.fn(params, batch_at(step))
        preds.append(jnp.argmax(logits, axis=-1))
    jax.block_until_ready(preds[-1])
    t_serve = time.time() - t0

    print(json.dumps(dict(
        net=spec.name, input_size=spec.input_size, batch=args.batch,
        batches=args.batches, mnf=not args.dense,
        compile_s=round(t_compile, 3),
        frames_per_s=round(args.batches * args.batch / max(t_serve, 1e-9), 2),
        engine=dataclasses.asdict(plan.engine),
        # DESIGN.md §7 invariant per cell: pool boundaries riding the
        # event-native segment max vs densify points left on the chain.
        boundaries=plan.boundaries,
        sample_preds=[int(t) for t in preds[-1][:4]])))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mnf", action="store_true",
                    help="enable the MNF fire phase (LM MLP blocks / CNN "
                         "event pipeline)")
    ap.add_argument("--mnf-threshold", type=float, default=0.0)
    ap.add_argument("--mnf-pallas", action="store_true",
                    help="route the MNF multiply phase through the Pallas "
                         "engine backend (default: pure-XLA block backend)")
    ap.add_argument("--cnn", choices=("alexnet", "vgg16", "alexnet_ds",
                                      "vgg16_ds"),
                    help="serve a CNN workload through the single-jit "
                         "event-resident pipeline instead of an LM (the _ds "
                         "variants downsample with stride-2 conv blocks — "
                         "the fused stride-2 strip path)")
    ap.add_argument("--cnn-size", type=int, default=64,
                    help="CNN input resolution (224 = paper scale)")
    ap.add_argument("--batches", type=int, default=8,
                    help="CNN mode: number of batched requests to serve")
    ap.add_argument("--dense", action="store_true",
                    help="CNN mode: serve the dense oracle path instead of "
                         "MNF events (the default)")
    ap.add_argument("--weight-sparsity", type=float, default=0.5,
                    help="CNN mode: unstructured weight pruning density")
    args = ap.parse_args()

    if args.cnn:
        if args.dense and (args.mnf or args.mnf_pallas
                           or args.mnf_threshold != 0.0):
            ap.error("--dense conflicts with --mnf/--mnf-pallas/"
                     "--mnf-threshold (CNN mode serves MNF by default)")
        serve_cnn(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # --mnf-threshold / --mnf-pallas imply --mnf: a sub-flag alone must not
    # silently benchmark the dense path.
    if args.mnf or args.mnf_pallas or args.mnf_threshold != 0.0:
        cfg = dataclasses.replace(
            cfg, mnf=dataclasses.replace(cfg.mnf, enabled=True,
                                         threshold=args.mnf_threshold,
                                         use_pallas=args.mnf_pallas))
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("serve", max_len, args.batch, "decode")
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    pre = make_prefill_step(cfg, ShapeConfig("pf", max_len, args.batch,
                                             "prefill"), mesh)
    srv = make_serve_step(cfg, shape, mesh)

    key = jax.random.PRNGKey(0)
    with mesh:
        params = jax.jit(lambda k: init_params(k, cfg)[0])(key)
        toks = jax.random.randint(key, (args.batch, max_len), 0,
                                  cfg.vocab_size, jnp.int32)
        batch = dict(tokens=toks)
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        if cfg.encoder_decoder:
            batch["audio_frames"] = jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))

        t0 = time.time()
        logits, cache = pre.fn(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        out_tokens = []
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for i in range(args.gen):
            step_batch = dict(batch, tokens=cur)
            logits, cache = srv.fn(params, cache, step_batch,
                                   jnp.asarray(args.prompt_len + i,
                                               jnp.int32))
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            cur = cur.astype(jnp.int32)
            out_tokens.append(cur)
        jax.block_until_ready(cur)
        t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(json.dumps(dict(
        arch=cfg.name, batch=args.batch, prompt_len=args.prompt_len,
        generated=args.gen,
        prefill_s=round(t_prefill, 3),
        decode_tok_per_s=round(args.gen * args.batch / t_decode, 1),
        mnf=cfg.mnf.enabled,
        engine=dataclasses.asdict(srv.engine),
        sample_tokens=[int(t) for t in gen[0][:8]])))


if __name__ == "__main__":
    main()
