"""Batched serving driver: LM prefill + decode loop, or event-resident CNN.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16

CNN mode runs a full serving replica (``repro.serving`` — DESIGN.md §10):
a FIFO request queue continuously batched into padded buckets, one
AOT-warmed compiled pipeline per bucket, weights replicated and the batch
axis sharded over the (data, model) mesh.  MNF is the default; ``--dense``
serves the oracle path instead:

  PYTHONPATH=src python -m repro.launch.serve --cnn alexnet --cnn-size 64 \
      --rate 6 --ticks 8 --cache-dir /tmp/mnf_cache

``--smoke`` serves the mini network through every bucket and **fails**
(exit 1) if any steady-state tick recompiles, or an eligible event
boundary reports fallback_decode, or padded-bucket logits drift bitwise
from the unpadded forward — the CI anti-rot gate for the serving tier.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import checked_mesh, make_serve_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params


def _cnn_spec(name: str, size: int):
    from repro.models.cnn import (ALEXNET, ALEXNET_DS, MINI, VGG16,
                                  VGG16_DS)
    return {"alexnet": ALEXNET, "vgg16": VGG16, "alexnet_ds": ALEXNET_DS,
            "vgg16_ds": VGG16_DS, "mini": MINI}[name].scaled(size)


def _mlp_spec(name: str):
    from repro.models.mlp import LENET_300_100, MLP_MINI
    return {"lenet": LENET_300_100, "mini": MLP_MINI}[name]


def serve_cnn(args) -> None:
    """Continuously-batched CNN/MLP serving through the AOT-warmed replica.

    ``--mlp`` serves an FC network through the identical bucketed tier —
    flat ``(in_features,)`` request vectors instead of images; every
    boundary is FC→FC, so its report must state zero densify points
    (DESIGN.md §12)."""
    import numpy as np

    from repro import engine, serving
    from repro.core.fire import FireConfig
    from repro.models.cnn import init_cnn_params
    from repro.models.mlp import init_mlp_params

    if args.mlp:
        spec = _mlp_spec(args.mlp)
    else:
        spec = _cnn_spec(args.cnn, args.cnn_size)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    if args.route == "adaptive":
        # Adaptive routing consults the measured crossover table (written
        # by kernel_bench --sweep); installing it is explicit — the engine
        # never reads files implicitly.
        from repro.costmodel import crossover as xover
        xover.set_active_table(xover.load_crossover_table(args.bench))
    ecfg = engine.EngineConfig(
        backend="pallas" if args.mnf_pallas else "auto",
        threshold=args.mnf_threshold, route=args.route,
        occupancy_hint=args.occupancy_hint)
    key = jax.random.PRNGKey(0)
    init = init_mlp_params if args.mlp else init_cnn_params
    params = init(key, spec, weight_sparsity=args.weight_sparsity)

    eng = serving.ServeEngine(
        spec, params,
        serving.ServeEngineConfig(buckets=buckets, mnf=not args.dense,
                                  threshold=args.mnf_threshold,
                                  cache_dir=args.cache_dir),
        mesh=make_serve_mesh(), engine_cfg=ecfg,
        fire_cfg=FireConfig(threshold=args.mnf_threshold))

    # Synthetic traffic is generated AHEAD of the serving loop: requests/s
    # must measure the pipeline, not host-side jax.random throughput.
    rng = np.random.default_rng(0)
    n_requests = args.rate * args.ticks
    req_shape = (spec.in_features,) if args.mlp else \
        (spec.input_size, spec.input_size, spec.in_ch)
    images = np.maximum(
        rng.standard_normal((n_requests,) + req_shape, dtype=np.float32),
        0.0)

    warm_recompiles = eng.recompiles
    it = iter(images)
    for _ in range(args.ticks):
        for _ in range(args.rate):
            eng.submit(next(it))
        eng.run_tick()
    stats = eng.stats()

    failures = []
    if eng.recompiles != warm_recompiles:
        failures.append(
            f"steady-state recompiles: {eng.recompiles - warm_recompiles} "
            f"ticks compiled after warmup (the jit cache-miss counter must "
            f"stay flat)")
    report = eng.boundary_report()
    if not args.dense and report["fallback_decodes"]:
        failures.append(f"eligible boundary reported fallback_decode: "
                        f"{report}")

    # An MLP boundary report with any densify point is a serving bug: every
    # FC→FC boundary is structurally eligible (DESIGN.md §12).
    if args.mlp and eng.plans[buckets[0]].boundaries.get("densify", 0):
        failures.append(f"MLP replica reports densify points: "
                        f"{eng.plans[buckets[0]].boundaries}")

    print(json.dumps(dict(
        net=spec.name,
        input_size=spec.in_features if args.mlp else spec.input_size,
        buckets=list(buckets),
        mnf=not args.dense, engine=dataclasses.asdict(eng.engine_cfg),
        boundaries=report, **stats)))
    if failures:
        print("serve smoke FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        raise SystemExit(1)


def serve_smoke(args) -> None:
    """CI gate: tiny bucketed serve loop + the tier's three invariants,
    plus the routing invariant of DESIGN.md §11: a snapshot-restored
    replica must report routes identical to the replica that compiled the
    executables (routes are trace-time static, so any drift means the
    restored executable no longer matches its report)."""
    import tempfile

    import numpy as np

    from repro import serving
    from repro.models.cnn import init_cnn_params, make_cnn_pipeline

    spec = _cnn_spec("mini", 8)
    buckets = (1, 2, 4)
    cache_dir = args.cache_dir or tempfile.mkdtemp(prefix="mnf_serve_smoke_")
    params = init_cnn_params(jax.random.PRNGKey(0), spec,
                             weight_sparsity=0.5)
    eng = serving.ServeEngine(
        spec, params, serving.ServeEngineConfig(buckets=buckets,
                                                cache_dir=cache_dir))
    warm = eng.recompiles
    rng = np.random.default_rng(0)
    images = np.maximum(rng.standard_normal((9, 8, 8, 3),
                                            dtype=np.float32), 0.0)
    arrivals = (1, 3, 0, 5)          # exercises buckets 1, 4, (idle), 4+1
    it = iter(images)
    for n in arrivals:
        for _ in range(n):
            eng.submit(next(it))
        eng.run_tick()

    failures = []
    if len(eng.completed) != 9:
        failures.append(f"served {len(eng.completed)}/9 requests")
    if [r.rid for r in eng.completed] != sorted(
            r.rid for r in eng.completed):
        failures.append("completion order is not FIFO")
    if eng.recompiles != warm:
        failures.append(f"{eng.recompiles - warm} steady-state recompiles "
                        f"(jit cache-miss counter must stay flat after "
                        f"warmup)")
    report = eng.boundary_report()
    if report["fallback_decodes"]:
        failures.append(f"eligible boundary reported fallback_decode: "
                        f"{report}")
    # Bitwise padding mask: real rows of every padded bucket == the
    # unpadded chained forward.
    ref_fn = make_cnn_pipeline(spec, donate=False)
    for n in (1, 3, 9):
        ref = np.asarray(ref_fn(params, jnp.asarray(images[:n])))
        got = np.stack([r.result for r in eng.completed[:n]])
        if not np.array_equal(ref, got):
            failures.append(f"padded-bucket logits not bitwise-equal to "
                            f"the unpadded forward at n={n}")
    # Snapshot-restart route identity: a second replica restored from the
    # first one's executable snapshots must report the exact same
    # per-boundary routes (and restore, not recompile).
    eng2 = serving.ServeEngine(
        spec, params, serving.ServeEngineConfig(buckets=buckets,
                                                cache_dir=cache_dir))
    if eng2.snapshot_hits != len(buckets):
        failures.append(f"restarted replica restored "
                        f"{eng2.snapshot_hits}/{len(buckets)} buckets from "
                        f"snapshot (restart must not recompile)")
    report2 = eng2.boundary_report()
    if report2["routes"] != report["routes"]:
        failures.append(f"snapshot-restored replica reports different "
                        f"routes: {report2['routes']} != {report['routes']}")

    # MLP tier: the FC family through the identical bucketed replica —
    # flat request vectors, every boundary FC→FC.  Zero densify points is
    # structural (DESIGN.md §12): any fallback_decode or densify count on
    # an MLP replica is a serving bug, and padded-bucket logits must stay
    # bitwise the unpadded chained forward's, same as the CNN tier.
    from repro.models.mlp import (MLP_MINI, init_mlp_params,
                                  make_mlp_pipeline)
    mspec = MLP_MINI
    mparams = init_mlp_params(jax.random.PRNGKey(0), mspec,
                              weight_sparsity=0.5)
    meng = serving.ServeEngine(
        mspec, mparams, serving.ServeEngineConfig(buckets=buckets))
    mwarm = meng.recompiles
    vecs = np.maximum(rng.standard_normal((7, mspec.in_features),
                                          dtype=np.float32), 0.0)
    it = iter(vecs)
    for n in (1, 2, 4):
        for _ in range(n):
            meng.submit(next(it))
        meng.run_tick()
    mreport = meng.boundary_report()
    if len(meng.completed) != 7:
        failures.append(f"MLP tier served {len(meng.completed)}/7 requests")
    if meng.recompiles != mwarm:
        failures.append(f"MLP tier: {meng.recompiles - mwarm} steady-state "
                        f"recompiles")
    if mreport["fallback_decodes"]:
        failures.append(f"MLP tier: eligible FC boundary reported "
                        f"fallback_decode: {mreport}")
    if mreport["boundaries"].get("densify", 0) or \
            mreport["boundaries"].get("retile", 0):
        failures.append(f"MLP tier: FC→FC chain reports densify/retile "
                        f"points: {mreport['boundaries']}")
    mref = np.asarray(make_mlp_pipeline(mspec, donate=False)(
        mparams, jnp.asarray(vecs)))
    mgot = np.stack([r.result for r in meng.completed])
    if not np.array_equal(mref, mgot):
        failures.append("MLP tier: padded-bucket logits not bitwise-equal "
                        "to the unpadded chained forward")

    print(json.dumps(dict(smoke="serve", boundaries=report,
                          mlp_boundaries=mreport, **eng.stats())))
    if failures:
        print("serve smoke FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        raise SystemExit(1)
    print("serve smoke OK: no steady-state recompiles, no fallback_decode, "
          "padding bitwise-exact, snapshot-restart routes identical, MLP "
          "tier densify-free")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mnf", action="store_true",
                    help="enable the MNF fire phase (LM MLP blocks / CNN "
                         "event pipeline)")
    ap.add_argument("--mnf-threshold", type=float, default=0.0)
    ap.add_argument("--mnf-pallas", action="store_true",
                    help="route the MNF multiply phase through the Pallas "
                         "engine backend (default: pure-XLA block backend)")
    ap.add_argument("--cnn", choices=("alexnet", "vgg16", "alexnet_ds",
                                      "vgg16_ds", "mini"),
                    help="serve a CNN workload through the bucketed "
                         "serving replica instead of an LM (the _ds "
                         "variants downsample with stride-2 conv blocks — "
                         "the fused stride-2 strip path)")
    ap.add_argument("--cnn-size", type=int, default=64,
                    help="CNN input resolution (224 = paper scale)")
    ap.add_argument("--mlp", choices=("lenet", "mini"),
                    help="serve an FC network (lenet = LeNet-300-100, the "
                         "paper's MNIST-class workload) through the same "
                         "bucketed serving replica — flat request vectors, "
                         "every FC→FC boundary event-chained, zero densify "
                         "points (DESIGN.md §12)")
    ap.add_argument("--buckets", default="1,8,32,128",
                    help="CNN mode: compiled batch bucket sizes, ascending")
    ap.add_argument("--rate", type=int, default=8,
                    help="CNN mode: synthetic request arrivals per tick")
    ap.add_argument("--ticks", type=int, default=8,
                    help="CNN mode: number of serving ticks to run")
    ap.add_argument("--cache-dir", default=None,
                    help="JAX persistent compilation cache directory — a "
                         "restarted replica re-warms its bucket "
                         "executables from disk in seconds")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny bucketed serve loop; exit 1 on any "
                         "steady-state recompile, fallback_decode, or "
                         "padding bitwise drift")
    ap.add_argument("--dense", action="store_true",
                    help="CNN mode: serve the dense oracle path instead of "
                         "MNF events (the default)")
    ap.add_argument("--weight-sparsity", type=float, default=0.5,
                    help="CNN mode: unstructured weight pruning density")
    ap.add_argument("--route", default="auto",
                    choices=("auto", "adaptive", "dense", "event", "strip",
                             "pixel", "window"),
                    help="CNN mode: per-boundary routing policy — auto "
                         "(geometry event-first), adaptive (cost-model / "
                         "crossover-table argmin at --occupancy-hint), or "
                         "a forced route (DESIGN.md §11)")
    ap.add_argument("--occupancy-hint", type=float, default=None,
                    help="CNN mode: static occupancy the adaptive router "
                         "decides at (routes are trace-time static; the "
                         "hint is the deployment's expected activation "
                         "density, default 1.0)")
    ap.add_argument("--bench", default="BENCH_engine.json",
                    help="CNN mode: BENCH file whose crossover entries "
                         "seed the adaptive routing table")
    args = ap.parse_args()

    if args.smoke:
        serve_smoke(args)
        return
    if args.cnn and args.mlp:
        ap.error("--cnn and --mlp are mutually exclusive")
    if args.cnn or args.mlp:
        if args.dense and (args.mnf or args.mnf_pallas
                           or args.mnf_threshold != 0.0):
            ap.error("--dense conflicts with --mnf/--mnf-pallas/"
                     "--mnf-threshold (CNN/MLP mode serves MNF by default)")
        serve_cnn(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # --mnf-threshold / --mnf-pallas imply --mnf: a sub-flag alone must not
    # silently benchmark the dense path.
    if args.mnf or args.mnf_pallas or args.mnf_threshold != 0.0:
        cfg = dataclasses.replace(
            cfg, mnf=dataclasses.replace(cfg.mnf, enabled=True,
                                         threshold=args.mnf_threshold,
                                         use_pallas=args.mnf_pallas))
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("serve", max_len, args.batch, "decode")
    ndev = len(jax.devices())
    mesh = checked_mesh((ndev, 1), ("data", "model"))

    pre = make_prefill_step(cfg, ShapeConfig("pf", max_len, args.batch,
                                             "prefill"), mesh)
    srv = make_serve_step(cfg, shape, mesh)

    key = jax.random.PRNGKey(0)
    with mesh:
        params = jax.jit(lambda k: init_params(k, cfg)[0])(key)
        toks = jax.random.randint(key, (args.batch, max_len), 0,
                                  cfg.vocab_size, jnp.int32)
        batch = dict(tokens=toks)
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        if cfg.encoder_decoder:
            batch["audio_frames"] = jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))

        t0 = time.time()
        logits, cache = pre.fn(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        out_tokens = []
        # Fire-gated recurrent decode (DESIGN.md §13): each decode step
        # writes the per-layer fired-event count of the state update into
        # the cache — collect it per token for the events/token report.
        track_events = (cfg.mnf.enabled and isinstance(cache, dict)
                        and isinstance(cache.get("scan"), dict)
                        and "events" in cache["scan"])
        ev_steps = []
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for i in range(args.gen):
            step_batch = dict(batch, tokens=cur)
            logits, cache = srv.fn(params, cache, step_batch,
                                   jnp.asarray(args.prompt_len + i,
                                               jnp.int32))
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            cur = cur.astype(jnp.int32)
            out_tokens.append(cur)
            if track_events:
                ev_steps.append(cache["scan"]["events"])
        jax.block_until_ready(cur)
        t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    stats = dict(
        arch=cfg.name, batch=args.batch, prompt_len=args.prompt_len,
        generated=args.gen,
        prefill_s=round(t_prefill, 3),
        decode_tok_per_s=round(args.gen * args.batch / t_decode, 1),
        mnf=cfg.mnf.enabled,
        engine=dataclasses.asdict(srv.engine),
        sample_tokens=[int(t) for t in gen[0][:8]])
    if track_events:
        evm = jnp.stack(ev_steps)                  # (gen, L) counts
        per_tok = evm.sum(axis=1)
        stats["events_per_token"] = round(float(per_tok.mean()), 2)
        stats["events_per_token_min"] = round(float(per_tok.min()), 2)
        stats["events_per_token_max"] = round(float(per_tok.max()), 2)
        stats["events_per_layer"] = [round(float(x), 2)
                                     for x in evm.mean(axis=0)]
    print(json.dumps(stats))


if __name__ == "__main__":
    main()
