"""Launch layer: meshes, sharded step factories, dry-run, roofline, drivers."""
