"""End-to-end training driver.

Wires config → mesh → sharded train step → resilient loop (checkpoint /
restart / straggler detection) → synthetic data pipeline.  On CPU use a
reduced config; on a pod pass --arch with the full config and the production
mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data import TokenStreamConfig, markov_lm_batch
from repro.launch.steps import make_train_step
from repro.optim import AdamWConfig, adamw_init, warmup_cosine
from repro.runtime import LoopConfig, ResilientLoop


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mnf_threshold is not None:
        cfg = dataclasses.replace(
            cfg, mnf=dataclasses.replace(cfg.mnf, enabled=True,
                                         threshold=args.mnf_threshold))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    ndev = len(jax.devices())
    if ndev >= 512:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    else:
        # largest (data, model) grid available
        model = 1
        while model * 2 <= min(4, ndev) and ndev % (model * 2) == 0:
            model *= 2
        from repro.launch.mesh import checked_mesh
        mesh = checked_mesh((ndev // model, model), ("data", "model"))
    opt = AdamWConfig(schedule=warmup_cosine(args.lr, args.warmup,
                                             args.steps))
    plan = make_train_step(cfg, shape, mesh, opt=opt)
    return cfg, shape, mesh, plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mnf-threshold", type=float, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg, shape, mesh, plan = build(args)
    print(f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch={cfg.name} reduced={args.reduced}")

    with mesh:
        key = jax.random.PRNGKey(0)
        from repro.models import init_params
        params = jax.jit(lambda k: init_params(k, cfg)[0],
                         out_shardings=plan.param_shardings)(key)
        opt_state = jax.jit(adamw_init,
                            out_shardings=None)(params)

        ds_cfg = TokenStreamConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq,
                                   global_batch=args.batch)

        def batch_fn(step):
            return markov_lm_batch(ds_cfg, step)

        def step_fn(state, batch):
            params, opt_state = state
            params, opt_state, metrics = plan.fn(params, opt_state, batch)
            return (params, opt_state), metrics

        loop = ResilientLoop(
            LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every),
            step_fn, batch_fn)

        t0 = time.time()
        (params, opt_state), final_step, preempted = loop.run(
            (params, opt_state))
        dt = time.time() - t0

    losses = [m["loss"] for m in loop.metrics_log]
    stragglers = sum(m["straggler"] for m in loop.metrics_log)
    print(json.dumps(dict(
        final_step=final_step, preempted=preempted,
        wall_s=round(dt, 1),
        first_loss=round(losses[0], 4) if losses else None,
        last_loss=round(sum(losses[-10:]) / max(len(losses[-10:]), 1), 4)
        if losses else None,
        stragglers_flagged=int(stragglers),
        tokens_per_s=round(len(losses) * args.batch * args.seq / dt, 1))))
    for m in loop.metrics_log[::max(1, args.log_every)]:
        print(f"  step {int(m['step']):5d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.3f} {m['step_time_s']*1e3:8.1f}ms")


if __name__ == "__main__":
    main()
