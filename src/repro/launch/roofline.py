"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are not in cost_analysis: we parse the optimized HLO text and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.  MODEL_FLOPS (6·N·D train, 2·N·D inference, with
N = active params for MoE) gives the useful-compute ratio, catching
remat/redundancy waste.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment spec).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import active_params, count_params

__all__ = ["HW", "RooflineReport", "collective_bytes_from_hlo",
           "model_flops", "analyze", "format_row"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    link_bw: float = 50e9            # bytes/s per ICI link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[dims]' occurrence."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nb = _DTYPE_BYTES.get(dt)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op, by kind.

    HLO line shape: ``%x = bf16[16,128]{1,0} all-gather(...)`` (the result
    shape precedes the op name; tuples list several shapes).  Output size is
    the standard accounting for wire bytes of AG/AR/A2A at ring-algorithm
    granularity; we report per-kind sums plus the total.
    """
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in _COLLECTIVES:
            # match "= <shape(s)> <kind>(" — avoids -start/-done duplicates
            # by only counting the op form that carries the result shape.
            marker = f" {kind}("
            if marker not in s and f" {kind}-start(" not in s:
                continue
            if f" {kind}-done(" in s:
                continue
            eq = s.find("= ")
            if eq < 0:
                continue
            rhs = s[eq + 2:]
            opname = rhs.find(kind)
            shapes_part = rhs[:opname]
            nbytes = sum(_shape_bytes(m.group(0))
                         for m in _SHAPE_RE.finditer(shapes_part))
            out[kind] += nbytes
            count[kind] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D for train, 2·N·D for inference (N active, D tokens processed)."""
    n = active_params(cfg)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch * 1            # decode: one token per sequence
    return 2.0 * n * d


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float                 # per-device GFLOP (loop-aware parse)
    hlo_gbytes: float                 # per-device HBM GB  (loop-aware parse)
    coll_gbytes: float                # per-device collective GB
    xla_raw_gflops: float             # raw cost_analysis (loop bodies ×1)
    xla_raw_gbytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_gflops: float               # global useful GFLOP (6ND / 2ND)
    useful_ratio: float               # MODEL / (HLO × chips)
    roofline_frac: float              # useful share of the binding term
    bytes_per_device: int
    coll_breakdown: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze(arch: str, cfg: ModelConfig, shape: ShapeConfig, mesh_name: str,
            chips: int, cost: dict, hlo_text: str,
            bytes_per_device: int, hw: HW = HW()) -> RooflineReport:
    from repro.launch.hlo_analysis import analyze_hlo_text
    structural = analyze_hlo_text(hlo_text)        # per-device, loop-aware
    flops = structural.flops
    bts = structural.bytes
    coll_total = structural.collective_bytes
    t_c = flops / hw.peak_flops
    t_m = bts / hw.hbm_bw
    t_x = coll_total / hw.link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / max(flops * chips, 1.0)
    # Roofline fraction: time the useful math would take at peak, over the
    # binding term's time — the score we hillclimb.
    t_useful = mf / chips / hw.peak_flops
    frac = t_useful / max(terms[bottleneck], 1e-30)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=bts / 1e9,
        coll_gbytes=coll_total / 1e9,
        xla_raw_gflops=float(cost.get("flops", 0.0)) / 1e9,
        xla_raw_gbytes=float(cost.get("bytes accessed", 0.0)) / 1e9,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, model_gflops=mf / 1e9,
        useful_ratio=useful, roofline_frac=frac,
        bytes_per_device=bytes_per_device,
        coll_breakdown=dict(structural.by_collective))


def format_row(r: RooflineReport) -> str:
    return (f"{r.arch:22s} {r.shape:12s} {r.mesh:10s} "
            f"comp={r.t_compute*1e3:9.3f}ms mem={r.t_memory*1e3:9.3f}ms "
            f"coll={r.t_collective*1e3:9.3f}ms  [{r.bottleneck:10s}] "
            f"roofline={r.roofline_frac:6.3f} useful={r.useful_ratio:6.3f} "
            f"dev_mem={r.bytes_per_device/2**30:6.2f}GiB")
