import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the first import side effect: the XLA_FLAGS line above runs before
any jax import so the host platform exposes 512 placeholder devices for the
production meshes (16×16 single-pod, 2×16×16 multi-pod).

Per cell:
  1. build the sharded step function (launch/steps.py),
  2. .lower(**ShapeDtypeStruct inputs)  — no allocation anywhere,
  3. .compile()                         — proves the GSPMD partition exists,
  4. record memory_analysis() (fits-on-device proof), cost_analysis()
     (FLOPs/bytes) and the collective schedule (HLO parse) for §Roofline.

Results stream into results/dryrun/<arch>__<shape>__<mesh>.json so the
roofline table assembles incrementally and reruns skip finished cells.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, collective_bytes_from_hlo, format_row
from repro.launch.steps import plan_cell

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# long_500k requires sub-quadratic context handling (DESIGN.md shape skips);
# whisper's decoder positions are a shape exercise only (noted).
def cell_supported(cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: full-attention arch (see DESIGN.md)"
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "decode skipped: encoder-only arch"
    return True, ""


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = RESULTS_DIR, rules=None, tag: str = "",
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, why = cell_supported(cfg, shape)
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_name, tag=tag)
    suffix = f"__{tag}" if tag else ""
    if not ok:
        rec.update(status="skipped", reason=why)
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json"),
                "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    try:
        plan = plan_cell(cfg, shape, mesh, rules=rules)
        with mesh:
            lowered = plan.fn.lower(*plan.arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        bytes_per_device = int(getattr(mem, "temp_size_in_bytes", 0) +
                               getattr(mem, "argument_size_in_bytes", 0) +
                               getattr(mem, "output_size_in_bytes", 0) -
                               getattr(mem, "alias_size_in_bytes", 0))
        rep = analyze(arch, cfg, shape, mesh_name, chips, cost, hlo,
                      bytes_per_device)
        rec.update(
            status="ok", lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=dict(
                temp=int(getattr(mem, "temp_size_in_bytes", 0)),
                args=int(getattr(mem, "argument_size_in_bytes", 0)),
                output=int(getattr(mem, "output_size_in_bytes", 0)),
                alias=int(getattr(mem, "alias_size_in_bytes", 0)),
                generated_code=int(getattr(mem,
                                           "generated_code_size_in_bytes", 0)),
            ),
            roofline=rep.to_json())
        if verbose:
            print(format_row(rep), flush=True)
    except Exception as e:  # a failed cell is a bug — record it loudly
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"FAIL {arch} {shape_name} {mesh_name}: {e}", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                cells.append((arch, shp, mp))

    n_ok = n_skip = n_fail = 0
    for arch, shp, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        path = os.path.join(args.out_dir, f"{arch}__{shp}__{mesh_name}.json")
        if args.skip_done and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    continue
        rec = run_cell(arch, shp, multi_pod=mp, out_dir=args.out_dir)
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skipped"
        n_fail += rec["status"] == "error"
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
