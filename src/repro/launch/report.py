"""Assemble the §Dry-run / §Roofline tables from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def _refresh_model_metrics(rec: dict) -> dict:
    """Recompute MODEL_FLOPS-derived fields from the config (robust to cost
    model fixes without recompiling the artifact)."""
    if rec.get("status") != "ok":
        return rec
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import HW, model_flops
    r = rec["roofline"]
    mf = model_flops(get_config(rec["arch"]), SHAPES[rec["shape"]])
    chips = r["chips"]
    flops = r["hlo_gflops"] * 1e9
    hw = HW()
    terms = dict(compute=r["t_compute"], memory=r["t_memory"],
                 collective=r["t_collective"])
    t_useful = mf / chips / hw.peak_flops
    r["model_gflops"] = mf / 1e9
    r["useful_ratio"] = mf / max(flops * chips, 1.0)
    r["roofline_frac"] = t_useful / max(terms[r["bottleneck"]], 1e-30)
    return rec


def load(tag: str = "") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        rec = json.load(open(p))
        if rec.get("tag", "") != tag:
            continue
        out.append(_refresh_model_metrics(rec))
    out.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                            if r["shape"] in SHAPE_ORDER else 9, r["mesh"]))
    return out


def roofline_markdown(tag: str = "", mesh: str = "16x16") -> str:
    rows = ["| arch | shape | comp (ms) | mem (ms) | coll (ms) | bottleneck "
            "| roofline | useful | GiB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in load(tag):
        if rec["mesh"] != mesh:
            continue
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped | — | — | — |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | "
                        f"| | |")
            continue
        r = rec["roofline"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {r['t_compute']*1e3:.1f} | {r['t_memory']*1e3:.1f} "
            f"| {r['t_collective']*1e3:.1f} | {r['bottleneck']} "
            f"| {r['roofline_frac']:.3f} | {r['useful_ratio']:.2f} "
            f"| {r['bytes_per_device']/2**30:.2f} |")
    return "\n".join(rows)


def dryrun_markdown(tag: str = "") -> str:
    rows = ["| arch | shape | mesh | status | lower (s) | compile (s) | "
            "GiB/dev | coll GB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for rec in load(tag):
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                        f"| skipped ({rec['reason'].split(':')[0]}) | | | | |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                        f"| **ERROR** | | | | |")
            continue
        r = rec["roofline"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok "
            f"| {rec['lower_s']:.1f} | {rec['compile_s']:.1f} "
            f"| {r['bytes_per_device']/2**30:.2f} | {r['coll_gbytes']:.1f} |")
    return "\n".join(rows)


def summarize(tag: str = "") -> dict:
    recs = load(tag)
    ok = [r for r in recs if r["status"] == "ok"]
    return dict(
        total=len(recs), ok=len(ok),
        skipped=sum(r["status"] == "skipped" for r in recs),
        error=sum(r["status"] == "error" for r in recs),
        over_16g=[f"{r['arch']}/{r['shape']}/{r['mesh']}" for r in ok
                  if r["roofline"]["bytes_per_device"] > 16 * 2 ** 30],
    )


if __name__ == "__main__":
    import sys
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    print(json.dumps(summarize(tag), indent=1))
    print(roofline_markdown(tag))
