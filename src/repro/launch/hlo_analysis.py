"""Loop-aware structural cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified: a
10-iteration scan of 128³ matmuls reports one matmul's FLOPs).  Every layer
stack here is a lax.scan, so raw XLA numbers undercount by ~L×.  This module
re-derives FLOPs / HBM bytes / collective bytes from ``compiled.as_text()``:

  * computations are parsed into symbol tables (every instruction line
    declares its result shape; parameters declare theirs in the signature);
  * ``while`` ops multiply their body+condition cost by the
    ``known_trip_count`` backend_config annotation XLA attaches after loop
    analysis (falling back to 1 — i.e. the XLA behaviour — if absent);
  * ``fusion`` bytes = operand + result shapes at the call site (internal
    instructions touch registers/VMEM, not HBM); fusion FLOPs recurse into
    the fused computation;
  * dynamic-slice / dynamic-update-slice / gather / scatter count only the
    bytes actually moved (result/update), not whole operands — matching
    HloCostAnalysis semantics;
  * collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) are accumulated per kind with loop multipliers
    applied — this is the §Roofline collective term.

Validated against hand-computable programs in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["Cost", "analyze_hlo_text", "parse_computations"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one array shape like f32[128,128] or pred[] or s32[2]{0}
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                        r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes_one(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype, 0)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def _shapes_bytes(text: str) -> int:
    return sum(_shape_bytes_one(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(text))


def _shape_elems(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.by_collective.items():
            self.by_collective[k] += v
        return self

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.bytes * k, self.collective_bytes * k)
        for kk, v in self.by_collective.items():
            c.by_collective[kk] = v * k
        return c


@dataclasses.dataclass
class Instr:
    name: str
    result_shapes: str          # text before the op name (shapes)
    op: str
    operands: list
    line: str
    is_root: bool = False


_OP_RE = re.compile(r"((?:[a-z0-9\-]+))\(")


def _parse_instr(line: str):
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    is_root = line.lstrip().startswith("ROOT")
    om = None
    # result shape(s) precede the op token; find the first op-looking token
    # followed by '(' after the closing of the shape spec.
    for mm in _OP_RE.finditer(rhs):
        tok = mm.group(1)
        if tok in _DTYPE_BYTES:           # dtype like f32[...] — skip
            continue
        om = mm
        break
    if om is None:
        return None
    op = om.group(1)
    shapes_part = rhs[:om.start()]
    args_start = om.end()
    depth = 1
    i = args_start
    while i < len(rhs) and depth:
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
        i += 1
    operand_text = rhs[args_start:i - 1]
    operands = _OPND_RE.findall(operand_text)
    return Instr(name=name, result_shapes=shapes_part, op=op,
                 operands=operands, line=rhs, is_root=is_root)


def parse_computations(hlo: str) -> dict:
    """name -> list[Instr]; also returns shape table name -> result text."""
    comps: dict[str, list] = {}
    shapes: dict[str, str] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):      # computation header or metadata
            hm = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)$", line)
            if hm and "{" in line:
                cur = hm.group(1)
                comps[cur] = []
                # parameter shapes from the signature: name: shape
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))",
                                      line):
                    shapes[f"{cur}::{pm.group(1)}"] = pm.group(2)
            else:
                cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins is None:
            continue
        comps[cur].append(ins)
        shapes[f"{cur}::{ins.name}"] = ins.result_shapes
    return dict(comps=comps, shapes=shapes)


_ZERO_COST_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-get-and-update-state",
    "custom-call",  # handled separately below if needed
    "bitcast-convert",
}

_MOVE_ONLY_OPS = {"copy", "reshape", "transpose", "broadcast", "concatenate",
                  "slice", "pad", "reverse", "convert", "reduce", "compare",
                  "select", "clamp", "map", "sort"}

_CHEAP_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "floor", "ceil", "sign", "cosine", "sine", "logistic", "and", "or",
    "xor", "not", "remainder", "atan2", "expm1", "log1p", "round-nearest-afz",
    "round-nearest-even", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "select", "compare", "convert", "reduce",
    "exponential-minus-one",
}


class _Analyzer:
    def __init__(self, parsed):
        self.comps = parsed["comps"]
        self.shapes = parsed["shapes"]
        self.memo: dict[str, Cost] = {}

    def operand_shape(self, comp: str, name: str) -> str:
        return self.shapes.get(f"{comp}::{name}", "")

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        # flops = 2 * result_elems * prod(contracting dims of lhs)
        res = _shape_elems(ins.result_shapes)
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        lhs_shape = self.operand_shape(comp, ins.operands[0]) if ins.operands else ""
        lm = _SHAPE_RE.search(lhs_shape)
        if not cm or not lm:
            return 2.0 * res            # fallback
        dims = [int(d) for d in lm.group(2).split(",")] if lm.group(2) else []
        k = 1
        for ci in cm.group(1).split(","):
            if ci != "" and int(ci) < len(dims):
                k *= dims[int(ci)]
        return 2.0 * res * k

    def _conv_flops(self, comp: str, ins: Instr) -> float:
        res = _shape_elems(ins.result_shapes)
        km = self.operand_shape(comp, ins.operands[1]) if len(ins.operands) > 1 else ""
        km_m = _SHAPE_RE.search(km)
        if not km_m or not km_m.group(2):
            return 2.0 * res
        kdims = [int(d) for d in km_m.group(2).split(",")]
        res_m = _SHAPE_RE.search(ins.result_shapes)
        out_feat = 1
        if res_m and res_m.group(2):
            pass
        # per output element: 2 * (kernel elems / output features)
        out_feature_guess = max(kdims[-1], 1)
        per_out = 1
        for d in kdims:
            per_out *= d
        per_out //= out_feature_guess
        return 2.0 * res * per_out

    def instr_cost(self, comp: str, ins: Instr, *, in_fusion: bool) -> Cost:
        c = Cost()
        op = ins.op
        res_bytes = _shapes_bytes(ins.result_shapes)
        res_elems = _shape_elems(ins.result_shapes)
        opnd_bytes = sum(_shapes_bytes(self.operand_shape(comp, o))
                         for o in ins.operands)

        if op in _COLLECTIVES or op.rstrip("-start") in _COLLECTIVES or \
                any(op == k + "-start" for k in _COLLECTIVES):
            base = op[:-6] if op.endswith("-start") else op
            c.collective_bytes += res_bytes
            c.by_collective[base] += res_bytes
            c.bytes += res_bytes + opnd_bytes
            return c
        if op.endswith("-done"):
            return c

        if op == "while":
            body, cond = None, None
            bm = re.search(r"body=%?([\w.\-]+)", ins.line)
            cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
            trip = 1
            tm = _TRIP_RE.search(ins.line)
            if tm:
                trip = int(tm.group(1))
            sub = Cost()
            if bm:
                sub += self.comp_cost(bm.group(1))
            if cm:
                sub += self.comp_cost(cm.group(1))
            c += sub.scaled(trip)
            return c

        if op == "fusion":
            fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
            if fm:
                inner_name = fm.group(1)
                inner = self.comp_cost(inner_name, fusion_ctx=True)
                c.flops += inner.flops
                c.collective_bytes += inner.collective_bytes
                for k, v in inner.by_collective.items():
                    c.by_collective[k] += v
                # HBM traffic = fusion boundary, slice-aware: a parameter
                # consumed only by (dynamic-)slice/gather inside the fusion
                # is charged at slice-result size, not full-buffer size
                # (matches HloCostAnalysis; critical for scan-over-layers,
                # where each iteration slices one layer from the stacked
                # params).  A root dynamic-update-slice aliases its buffer —
                # traffic is the update, not the buffer.
                c.bytes += self._fusion_boundary_bytes(comp, ins, inner_name)
            return c

        if op in ("call", "conditional", "async-start"):
            for m in _CALLED_RE.finditer(ins.line):
                names = m.group(1) or m.group(2)
                for nm in names.split(","):
                    nm = nm.strip().lstrip("%")
                    if nm in self.comps:
                        c += self.comp_cost(nm)
            c.bytes += res_bytes + opnd_bytes
            return c

        if op in ("dot", "dot-general"):
            c.flops += self._dot_flops(comp, ins)
            if not in_fusion:
                c.bytes += res_bytes + opnd_bytes
            return c
        if op == "convolution":
            c.flops += self._conv_flops(comp, ins)
            if not in_fusion:
                c.bytes += res_bytes + opnd_bytes
            return c

        if op in ("dynamic-slice", "gather"):
            c.bytes += 0 if in_fusion else 2 * res_bytes
            return c
        if op in ("dynamic-update-slice", "scatter"):
            upd = (_shapes_bytes(self.operand_shape(comp, ins.operands[1]))
                   if len(ins.operands) > 1 else res_bytes)
            c.bytes += 0 if in_fusion else 2 * upd
            c.flops += _shape_elems(self.operand_shape(comp, ins.operands[1])) \
                if op == "scatter" and len(ins.operands) > 1 else 0
            return c

        if op in _ZERO_COST_OPS:
            if op == "custom-call":
                c.bytes += 0 if in_fusion else res_bytes + opnd_bytes
            return c

        # generic elementwise / data movement
        if op in _CHEAP_FLOP_OPS:
            c.flops += res_elems
        if op == "reduce":
            c.flops += max(opnd_bytes // 4, res_elems)
        if not in_fusion:
            c.bytes += res_bytes + opnd_bytes
        return c

    def _fusion_boundary_bytes(self, comp: str, ins: Instr,
                               inner_name: str) -> float:
        inner = self.comps.get(inner_name, ())
        # parameter ordinal -> instr name (declared "… parameter(N)")
        params: dict[int, Instr] = {}
        for ii in inner:
            if ii.op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", ii.line)
                if pm:
                    params[int(pm.group(1))] = ii
        # consumers per inner instr name
        consumers: dict[str, list] = {}
        for ii in inner:
            for o in ii.operands:
                consumers.setdefault(o, []).append(ii)

        total = 0.0
        for ordi, pins in params.items():
            full = _shapes_bytes(pins.result_shapes)
            cons = consumers.get(pins.name, [])
            if cons and all(cc.op in ("dynamic-slice", "gather", "slice")
                            for cc in cons):
                total += sum(_shapes_bytes(cc.result_shapes) for cc in cons)
            elif cons and all(cc.op == "dynamic-update-slice" and
                              cc.operands and cc.operands[0] == pins.name
                              for cc in cons):
                # in-place update: read+write the update region only
                for cc in cons:
                    upd = (self.shapes.get(f"{inner_name}::{cc.operands[1]}",
                                           "") if len(cc.operands) > 1 else "")
                    total += 2 * _shapes_bytes(upd)
            else:
                total += full
        # result side
        root = next((ii for ii in inner if ii.is_root), None)
        res_bytes = _shapes_bytes(ins.result_shapes)
        if root is not None and root.op == "dynamic-update-slice":
            upd = (self.shapes.get(f"{inner_name}::{root.operands[1]}", "")
                   if len(root.operands) > 1 else "")
            res_bytes = _shapes_bytes(upd)
        total += res_bytes
        return total

    def comp_cost(self, comp: str, fusion_ctx: bool = False) -> Cost:
        key = f"{comp}::{fusion_ctx}"
        if key in self.memo:
            return self.memo[key]
        total = Cost()
        for ins in self.comps.get(comp, ()):  # missing comp -> zero
            total += self.instr_cost(comp, ins, in_fusion=fusion_ctx)
        self.memo[key] = total
        return total


def analyze_hlo_text(hlo: str, entry: str | None = None) -> Cost:
    parsed = parse_computations(hlo)
    comps = parsed["comps"]
    if entry is None:
        # The ENTRY computation is marked in the header line; our parser
        # stores it like any other — find it from the module header.
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        if m:
            entry = m.group(1)
        else:
            # fallback: computation named like main.NNN
            cands = [c for c in comps if c.startswith("main")]
            entry = cands[0] if cands else next(iter(comps))
    return _Analyzer(parsed).comp_cost(entry)
