"""Sharded step factories: train_step / prefill_step / serve_step per cell.

Each factory resolves param/cache/batch shardings from logical axis specs
under the given mesh and returns a jitted function plus the sharding trees
(the dry-run lowers these functions with ShapeDtypeStruct inputs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.engine import EngineConfig
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, OptState, adamw_init, adamw_update
from repro.parallel.sharding import (ShardingRules, data_axis_size,
                                     make_rules, make_sharder,
                                     named_sharding_tree, serve_batch_pspec,
                                     shard_map_compat)

__all__ = ["CellPlan", "CNNCellPlan", "plan_cell", "make_train_step",
           "make_prefill_step", "make_serve_step", "make_cnn_serve_step",
           "cell_engine_config"]


def cell_engine_config(cfg: ModelConfig) -> EngineConfig:
    """Resolve the MNF engine configuration a cell runs under.

    One seam for every step factory: the model-level MNFConfig maps onto an
    EngineConfig with backend/interpret pinned per device (DESIGN.md §4), so
    dry-run reports and serving logs state exactly which multiply-phase
    implementation the cell uses.
    """
    return EngineConfig.from_mnf(cfg.mnf).resolved()


@dataclasses.dataclass
class CellPlan:
    """Everything the dry-run/launcher needs for one (arch × shape × mesh)."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    rules: ShardingRules
    param_shapes: Any
    param_shardings: Any
    fn: Any                     # jitted step function
    arg_specs: tuple            # ShapeDtypeStructs to lower with
    donate: tuple = ()
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)


def _dp_spec(mesh: Mesh) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(dp if len(dp) > 1 else dp[0])


def _batch_shardings(inputs: dict, mesh: Mesh, rules: ShardingRules) -> dict:
    """Divisibility-aware batch sharding per input (batch=1 cells stay
    replicated instead of tripping pjit's divisibility check)."""
    from repro.parallel.sharding import logical_to_pspec
    out = {}
    for k, sds in inputs.items():
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        out[k] = NamedSharding(mesh, logical_to_pspec(axes, sds.shape, mesh,
                                                      rules))
    return out


def _param_shapes_and_shardings(cfg: ModelConfig, mesh: Mesh,
                                rules: ShardingRules):
    # Specs are static python data built during tracing — capture them via a
    # side channel so eval_shape only sees array outputs.
    box = {}

    def initf(k):
        p, s = tfm.init_params(k, cfg)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(initf, jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = box["specs"]
    shardings = named_sharding_tree(specs, shapes, mesh, rules)
    return shapes, specs, shardings


def make_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                    opt: AdamWConfig | None = None,
                    rules: ShardingRules | None = None,
                    accum_steps: int = 1) -> CellPlan:
    """accum_steps > 1 runs gradient accumulation: the global batch splits
    into microbatches scanned sequentially (grads averaged, one optimizer
    step) — the standard lever when a cell exceeds HBM at the target
    batch."""
    opt = opt or AdamWConfig()
    rules = rules or make_rules(mesh, fsdp=cfg.fsdp, seq_shard=cfg.seq_shard)
    sc = make_sharder(mesh, rules)

    pshapes, pspecs, pshard = _param_shapes_and_shardings(cfg, mesh, rules)
    oshard = OptState(mu=pshard, nu=pshard,
                      count=NamedSharding(mesh, P()))
    inputs = tfm.input_specs(cfg, shape)
    bshard = _batch_shardings(inputs, mesh, rules)
    assert shape.global_batch % accum_steps == 0, (shape.global_batch,
                                                   accum_steps)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(
                lambda p: tfm.lm_loss(p, batch, cfg, sc=sc))(params)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(
                    lambda p: tfm.lm_loss(p, mb, cfg, sc=sc))(params)
                g = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                 grad_acc, g)
                return (loss_acc + l, g), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        new_p, new_o, metrics = adamw_update(grads, opt_state, params, opt)
        return new_p, new_o, dict(loss=loss, **metrics)

    fn = jax.jit(train_step,
                 in_shardings=(pshard, oshard, bshard),
                 out_shardings=(pshard, oshard, None),
                 donate_argnums=(0, 1))
    oshapes = jax.eval_shape(adamw_init, pshapes)
    return CellPlan(cfg=cfg, shape=shape, mesh=mesh, rules=rules,
                    param_shapes=pshapes, param_shardings=pshard, fn=fn,
                    arg_specs=(pshapes, oshapes, inputs), donate=(0, 1),
                    engine=cell_engine_config(cfg))


def _cache_shardings(cfg: ModelConfig, bsz: int, max_len: int, mesh: Mesh,
                     rules: ShardingRules):
    cshapes = tfm.cache_specs(cfg, bsz, max_len)
    caxes = tfm.cache_axes(cfg)
    return cshapes, named_sharding_tree(caxes, cshapes, mesh, rules)


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                      rules: ShardingRules | None = None) -> CellPlan:
    rules = rules or make_rules(mesh, fsdp=cfg.fsdp, seq_shard=cfg.seq_shard)
    sc = make_sharder(mesh, rules)
    pshapes, pspecs, pshard = _param_shapes_and_shardings(cfg, mesh, rules)
    inputs = tfm.input_specs(cfg, shape)
    bshard = _batch_shardings(inputs, mesh, rules)
    _, cshard = _cache_shardings(cfg, shape.global_batch, shape.seq_len,
                                 mesh, rules)

    def prefill_step(params, batch):
        logits, cache = tfm.prefill(
            params, batch["tokens"], cfg,
            vision_embeds=batch.get("vision_embeds"),
            audio_frames=batch.get("audio_frames"),
            max_len=shape.seq_len, sc=sc)
        return logits, cache

    fn = jax.jit(prefill_step, in_shardings=(pshard, bshard),
                 out_shardings=(None, cshard))
    return CellPlan(cfg=cfg, shape=shape, mesh=mesh, rules=rules,
                    param_shapes=pshapes, param_shardings=pshard, fn=fn,
                    arg_specs=(pshapes, inputs),
                    engine=cell_engine_config(cfg))


def make_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                    rules: ShardingRules | None = None) -> CellPlan:
    """decode: one new token against a seq_len-long cache."""
    rules = rules or make_rules(mesh, fsdp=cfg.fsdp, seq_shard=cfg.seq_shard)
    sc = make_sharder(mesh, rules)
    pshapes, pspecs, pshard = _param_shapes_and_shardings(cfg, mesh, rules)
    inputs = tfm.input_specs(cfg, shape)
    bshard = _batch_shardings(inputs, mesh, rules)
    cshapes, cshard = _cache_shardings(cfg, shape.global_batch,
                                       shape.seq_len, mesh, rules)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, batch, decode_pos):
        logits, new_cache = tfm.decode_step(
            params, cache, batch["tokens"], decode_pos, cfg, sc=sc)
        return logits, new_cache

    fn = jax.jit(serve_step,
                 in_shardings=(pshard, cshard, bshard, None),
                 out_shardings=(None, cshard),
                 donate_argnums=(1,))
    return CellPlan(cfg=cfg, shape=shape, mesh=mesh, rules=rules,
                    param_shapes=pshapes, param_shardings=pshard, fn=fn,
                    arg_specs=(pshapes, cshapes, inputs, pos_spec),
                    donate=(1,), engine=cell_engine_config(cfg))


@dataclasses.dataclass
class CNNCellPlan:
    """Serving plan for a CNN workload (the paper's inference driver).

    The whole network is one compiled pipeline (models/cnn.make_cnn_pipeline,
    DESIGN.md §5.1): ``fn(params, images) -> logits`` with the image buffer
    donated — batched requests ride a single jit per (network, batch shape).
    """

    spec: Any                   # models.cnn.CNNSpec
    batch: int
    fn: Any                     # jitted whole-network pipeline
    arg_specs: tuple            # (param ShapeDtypeStructs, image SDS)
    donate: tuple = (1,)
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    #: Static chain accounting (models/cnn.chain_boundary_summary): how many
    #: pool boundaries ride the event-native segment max and how many
    #: densify points remain — serving logs report the DESIGN.md §7
    #: zero-densify invariant per cell.
    boundaries: dict = dataclasses.field(default_factory=dict)
    #: Serving-tier mesh placement (DESIGN.md §10): the (data, model) mesh
    #: the pipeline is placed on, how many ways the batch axis shards over
    #: it (1 = replicated single-device execution), and the NamedSharding
    #: the image buffer must arrive under (None off-mesh).
    mesh: Any = None
    data_shards: int = 1
    input_sharding: Any = None


def make_cnn_serve_step(spec, batch: int, *, mnf: bool = True,
                        engine_cfg: EngineConfig | None = None,
                        fire_cfg=None, donate: bool = True,
                        mesh: Mesh | None = None) -> CNNCellPlan:
    """Compile the event-resident CNN/MLP pipeline for batched serving.

    ``spec`` is a ``models.cnn.CNNSpec`` (already ``.scaled(...)`` to the
    serving resolution) or a ``models.mlp.MLPSpec`` — the FC family rides
    the same plan with a flat ``(batch, in_features)`` input buffer.  One
    jit covers conv→fire→…→FC; the MNF path keeps activations
    event-resident between conv layers (DESIGN.md §5).

    With a ``mesh``, the pipeline goes **batch-parallel**: the forward is
    wrapped in a ``shard_map`` over the mesh's data axes — weights
    replicated (in_spec ``P()``), the batch axis sharded — so each device
    runs the identical per-sample event pipeline over its batch shard
    (near-linear device scaling, and bitwise-identical logits, since the
    forward is per-sample independent).  A batch that does not divide the
    data axes (bucket 1 on a multi-device replica) stays replicated
    instead of tripping the divisibility check — same policy as
    ``parallel.sharding.serve_batch_pspec``.
    """
    from repro.core.fire import FireConfig
    from repro.models import cnn as cnn_mod
    from repro.models import mlp as mlp_mod

    fire_cfg = fire_cfg or FireConfig()
    ecfg = (engine_cfg or EngineConfig(backend="auto")).resolved()
    is_mlp = isinstance(spec, mlp_mod.MLPSpec)
    make_fwd = mlp_mod.make_mlp_forward if is_mlp \
        else cnn_mod.make_cnn_forward
    fwd = make_fwd(spec, mnf=mnf, fire_cfg=fire_cfg, engine_cfg=ecfg)
    data = data_axis_size(mesh) if mesh is not None else 1
    shards = data if (data > 1 and batch % data == 0) else 1
    in_shard = None
    if shards > 1:
        dp = _dp_spec(mesh)
        fwd = shard_map_compat(fwd, mesh, in_specs=(P(), dp), out_specs=dp)
        in_shard = NamedSharding(mesh, serve_batch_pspec(mesh, batch))
    elif mesh is not None:
        in_shard = NamedSharding(mesh, P())
    fn = jax.jit(fwd, donate_argnums=(1,) if donate else ())
    init = mlp_mod.init_mlp_params if is_mlp else cnn_mod.init_cnn_params
    pshapes = jax.eval_shape(lambda k: init(k, spec),
                             jax.ShapeDtypeStruct((2,), jnp.uint32))
    if is_mlp:
        x_spec = jax.ShapeDtypeStruct((batch, spec.in_features), jnp.float32)
        boundaries = mlp_mod.mlp_boundary_summary(
            spec, batch=batch, fire_cfg=fire_cfg,
            engine_cfg=ecfg) if mnf else {}
    else:
        x_spec = jax.ShapeDtypeStruct(
            (batch, spec.input_size, spec.input_size, spec.in_ch),
            jnp.float32)
        boundaries = cnn_mod.chain_boundary_summary(
            spec, batch=batch, fire_cfg=fire_cfg,
            engine_cfg=ecfg) if mnf else {}
    return CNNCellPlan(spec=spec, batch=batch, fn=fn,
                       arg_specs=(pshapes, x_spec),
                       donate=(1,) if donate else (), engine=ecfg,
                       boundaries=boundaries, mesh=mesh, data_shards=shards,
                       input_sharding=in_shard)


def plan_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              **kw) -> CellPlan:
    if shape.kind == "train":
        return make_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh, **kw)
    return make_serve_step(cfg, shape, mesh, **kw)
