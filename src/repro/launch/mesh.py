"""Production meshes.

Never touches jax device state at import time: meshes are built by FUNCTION
call only.  Dry-run processes must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before any jax
import* (launch/dryrun.py does this in its first two lines).

All mesh construction in the repo funnels through :func:`checked_mesh`:

  * **Capacity-checked.**  Requesting more mesh slots than the runtime has
    devices used to surface as a raw XLA/``make_mesh`` assertion deep in
    jax internals.  ``checked_mesh`` raises :class:`MeshCapacityError` —
    a named, actionable error that says how many devices exist, how many
    the shape needs, and how to get them (``XLA_FLAGS`` host-device
    forcing, or a smaller shape).  ``fallback=True`` degrades to a 1×1
    (or 1×…×1) mesh with a warning instead — what a single-device serving
    replica wants.
  * **Version-compatible.**  ``axis_types=`` only exists on newer jax;
    passing it unconditionally breaks jax 0.4.x at call time.  The helper
    feeds it only when ``jax.make_mesh`` accepts it.
"""
from __future__ import annotations

import inspect
import warnings

import jax

__all__ = ["MeshCapacityError", "checked_mesh", "make_production_mesh",
           "make_serve_mesh", "make_small_mesh"]


class MeshCapacityError(RuntimeError):
    """Requested mesh shape needs more devices than the runtime has."""


def _auto_axis_types(n: int):
    """(AxisType.Auto,) * n on jax versions that have it, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def checked_mesh(shape, axes, *, fallback: bool = False):
    """``jax.make_mesh`` with a capacity check and version-compat kwargs.

    Raises :class:`MeshCapacityError` (named, actionable) when ``shape``
    needs more devices than ``jax.devices()`` provides; with
    ``fallback=True`` it instead warns and returns the all-ones mesh over
    the same axis names (a single-device replica keeps serving).
    """
    need = 1
    for s in shape:
        need *= int(s)
    have = len(jax.devices())
    if need > have:
        msg = (f"mesh shape {tuple(shape)} over axes {tuple(axes)} needs "
               f"{need} devices but only {have} exist. Either request a "
               f"smaller mesh, or force host devices before any jax import "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count={need}).")
        if not fallback:
            raise MeshCapacityError(msg)
        warnings.warn(f"{msg} Falling back to a 1x1 mesh.", RuntimeWarning,
                      stacklevel=2)
        shape = (1,) * len(shape)
    kw = {}
    types = _auto_axis_types(len(axes))
    if types is not None and \
            "axis_types" in inspect.signature(jax.make_mesh).parameters:
        kw["axis_types"] = types
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) for two."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return checked_mesh(shape, axes)


def make_small_mesh(shape=(2, 4), axes=("data", "model")):
    """Test-scale mesh (requires a forced host device count >= prod(shape))."""
    return checked_mesh(shape, axes)


def make_serve_mesh(data: int | None = None, model: int = 1, *,
                    fallback: bool = True):
    """The serving tier's (data, model) mesh: batch axis over every device.

    ``data=None`` spans all visible devices (the replica default: weights
    replicated, batch sharded on ``data``).  An explicit shape that exceeds
    the device count warns and degrades to 1×1 (``fallback=True`` — a
    replica must come up, not crash) or raises :class:`MeshCapacityError`
    with ``fallback=False``.
    """
    if data is None:
        data = max(len(jax.devices()) // model, 1)
    return checked_mesh((data, model), ("data", "model"), fallback=fallback)
