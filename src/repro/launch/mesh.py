"""Production meshes.

Never touches jax device state at import time: meshes are built by FUNCTION
call only.  Dry-run processes must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before any jax
import* (launch/dryrun.py does this in its first two lines).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_small_mesh"]


def _auto(axes):
    return (jax.sharding.AxisType.Auto,) * len(axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) for two."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(axes))


def make_small_mesh(shape=(2, 4), axes=("data", "model")):
    """Test-scale mesh (requires a forced host device count >= prod(shape))."""
    return jax.make_mesh(shape, axes, axis_types=_auto(axes))
