"""AOT warmup + persistent compilation cache + executable snapshots.

The chained CNN pipeline pays 17–36 s of JIT per network (BENCH
``cnn_chain`` compile_us) — per *bucket shape* in the serving tier.
Three mechanisms take that off the request path, each cutting deeper:

  * **AOT warmup** — every bucket's pipeline is ``jit(...).lower(...)
    .compile()``'d at engine startup, so the first request of any bucket
    hits a finished executable, never a trace.
  * **JAX persistent compilation cache** — XLA compile outputs are
    cached under ``cache_dir``; a *restarted* replica's warmup skips the
    XLA compile (measured ~6× on AlexNet@64).  But tracing + lowering is
    pure Python work repaid every process, and at ~3–4 s per AlexNet
    bucket it dominates the re-warm.
  * **Executable snapshots** — the compiled executable itself is
    serialized per bucket (``jax.experimental.serialize_executable``)
    under ``cache_dir``; a restarted replica ``pickle.load``s finished
    executables and never traces, lowers, or compiles at all.  This is
    what makes warmed-replica TTFR an order of magnitude under the cold
    compile (BENCH ``serve_bench_summary``).

All three are wired through ``ServeEngineConfig.cache_dir`` /
``launch.serve --cache-dir``.  Snapshots are keyed by jax version,
device kind, mesh layout, network spec and engine config; a key miss or
an unpicklable payload falls back to the compile path, never fails.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import time

import jax

__all__ = ["configure_persistent_cache", "aot_compile", "snapshot_key",
           "save_executable", "load_executable"]


def configure_persistent_cache(cache_dir: str) -> None:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Thresholds drop to zero so every bucket executable is cached — serving
    warmup wants *all* compiles persisted, including the small buckets XLA
    compiles quickly.  Unknown flags (older jax) are skipped: the cache
    then simply persists less, it never breaks serving.
    """
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    for flag, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                      ("jax_persistent_cache_min_entry_size_bytes", 0)):
        try:
            jax.config.update(flag, val)
        except (AttributeError, ValueError):  # pragma: no cover - old jax
            pass
    # JAX latches cache state at the first compile of the process: if any
    # jit ran before the dir was set (param init counts), the cache object
    # initialized as "no cache" and every later lookup silently misses.
    # A reset re-initializes it from the dir just configured.
    try:
        from jax.experimental.compilation_cache import (compilation_cache as
                                                        _cc)
        _cc.reset_cache()
    except (ImportError, AttributeError):  # pragma: no cover - old jax
        pass


def aot_compile(jitted, arg_specs) -> tuple:
    """``jitted.lower(*arg_specs).compile()`` with the wall time split out.

    Returns ``(compiled, lower_s, compile_s)``.  ``compile_s`` is where the
    persistent cache bites: a warm replica's XLA compile is a disk
    deserialize.  The compiled executable is shape-strict — calling it can
    never retrace, which is what makes the steady-state recompile counter
    a meaningful invariant (a flat counter proves no tick compiled).
    """
    t0 = time.perf_counter()
    lowered = jitted.lower(*arg_specs)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return compiled, t1 - t0, t2 - t1


def snapshot_key(*parts) -> str:
    """Stable snapshot filename for an executable: every input that could
    change the compiled artifact goes into the hash — jax version, device
    kind, and whatever the caller passes (spec, bucket, engine config,
    mesh layout)."""
    dev = jax.devices()[0]
    tag = repr((jax.__version__, dev.platform, dev.device_kind) + parts)
    return hashlib.sha256(tag.encode()).hexdigest()[:24]


def save_executable(compiled, cache_dir: str, key: str) -> bool:
    """Snapshot a compiled executable under ``cache_dir`` (best-effort:
    an unserializable executable just means the next replica recompiles)."""
    from jax.experimental import serialize_executable as se
    try:
        blob = pickle.dumps(se.serialize(compiled))
    except Exception:  # pragma: no cover - backend-dependent
        return False
    path = os.path.join(cache_dir, f"exec-{key}.pkl")
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)               # atomic: a reader never sees half
    return True


def load_executable(cache_dir: str, key: str):
    """Load a snapshot, or None (missing / stale / different build — the
    caller falls back to compiling).  Only ever reads the operator's own
    ``cache_dir``."""
    from jax.experimental import serialize_executable as se
    path = os.path.join(cache_dir, f"exec-{key}.pkl")
    if not os.path.exists(path):
        return None
    try:
        with open(path, "rb") as f:
            payload, in_tree, out_tree = pickle.load(f)
        return se.deserialize_and_load(payload, in_tree, out_tree)
    except Exception:  # pragma: no cover - stale or foreign snapshot
        return None
