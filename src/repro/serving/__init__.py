"""repro.serving — the production CNN serving tier (DESIGN.md §10).

Turns the single-jit event-resident pipeline into a replica that serves
heavy traffic: a FIFO request queue continuously batched into padded
bucket shapes {1, 8, 32, 128}, batch-parallel ``shard_map`` over the
(data, model) mesh with weights replicated, and AOT warmup + JAX's
persistent compilation cache so a cold replica answers in seconds instead
of re-paying the 17–36 s chained-pipeline JIT per bucket.

    from repro import serving
    eng = serving.ServeEngine(spec, params,
                              serving.ServeEngineConfig(cache_dir=".jax"))
    eng.submit(image)
    done = eng.run_tick()          # -> completed Requests with latencies
    print(eng.stats())             # requests/s, p50/p99 per bucket
"""
from repro.serving.aot import aot_compile, configure_persistent_cache
from repro.serving.batcher import (DEFAULT_BUCKETS, ContinuousBatcher,
                                   Request, pad_bucket, smallest_bucket)
from repro.serving.server import ServeEngine, ServeEngineConfig, percentile

__all__ = [
    "DEFAULT_BUCKETS", "ContinuousBatcher", "Request", "pad_bucket",
    "smallest_bucket",
    "ServeEngine", "ServeEngineConfig", "percentile",
    "aot_compile", "configure_persistent_cache",
]
