"""Continuous batcher: FIFO request queue routed into padded batch buckets.

The serving tier compiles one pipeline per **bucket** shape (DESIGN.md §10)
— recompiling per request batch size would pay seconds of JIT on the
request path.  Requests accumulate in a FIFO queue between ticks; each tick
drains the queue head into the *smallest admissible bucket* (the smallest
compiled batch size that fits what is pending, capped at the largest
bucket), pads the short batch with all-zero rows, and hands the padded
buffer to the compiled executable.

Padding is exact, not approximate: a zero image row rides the event
pipeline as an event-free stream (ReLU fires nothing), every per-sample
row group of the block encoding is independent of its neighbours, and the
FC head's matmul reduces each batch row separately — so a real row's
logits are **bitwise independent** of what the padding rows hold
(asserted per bucket in tests/test_serving.py and in serve_bench on the
production net; DESIGN.md §10 states the cross-bucket-shape nuance).  The
batcher slices the padded rows back off before completing requests.

Fairness falls out of the head-of-queue policy: batches are always taken
from the front, so completion order is submission order (FIFO across
ticks) and no request can starve behind later arrivals that happen to fill
a larger bucket.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import numpy as np

__all__ = ["DEFAULT_BUCKETS", "Request", "ContinuousBatcher",
           "smallest_bucket", "pad_bucket"]

#: The compiled batch shapes (ROADMAP item 1): singles, small interactive
#: batches, and two throughput tiers.
DEFAULT_BUCKETS = (1, 8, 32, 128)


@dataclasses.dataclass
class Request:
    """One inference request riding the queue.

    ``submit_time`` (host clock at submission) and ``arrival_tick`` are
    stamped by the batcher; ``latency_s``/``result`` by the engine on
    completion.
    """

    rid: int
    image: Any                         # (H, W, C) array
    submit_time: float = 0.0
    arrival_tick: int = -1
    completion_tick: int = -1
    bucket: int = 0
    latency_s: float = 0.0
    result: Optional[Any] = None


def smallest_bucket(n: int, buckets: tuple) -> int:
    """Smallest compiled bucket admitting ``n`` requests (n <= max bucket)."""
    assert n >= 1, n
    for b in buckets:
        if b >= n:
            return b
    raise ValueError(f"{n} exceeds the largest bucket {buckets[-1]}")


def pad_bucket(images: list, bucket: int) -> np.ndarray:
    """Stack ``images`` into a (bucket, H, W, C) buffer, zero-padded rows.

    Zero rows are the masking: they contribute no events anywhere in the
    pipeline and their logits rows are sliced off before completion, so
    bucket padding never perturbs a real request's output bits.
    """
    n = len(images)
    assert 1 <= n <= bucket, (n, bucket)
    first = np.asarray(images[0], np.float32)
    out = np.zeros((bucket,) + first.shape, np.float32)
    for i, img in enumerate(images):
        out[i] = np.asarray(img, np.float32)
    return out


class ContinuousBatcher:
    """FIFO queue + bucket routing (the policy half of the serving tier).

    Pure host-side state machine — no jax — so every invariant the tier
    relies on (smallest admissible bucket, FIFO across ticks, no
    starvation) is testable without compiling anything.
    """

    def __init__(self, buckets: tuple = DEFAULT_BUCKETS, *,
                 max_batches_per_tick: int | None = None):
        assert buckets == tuple(sorted(set(buckets))) and len(buckets) > 0, \
            ("buckets must be sorted unique batch sizes", buckets)
        self.buckets = tuple(int(b) for b in buckets)
        self.max_batches_per_tick = max_batches_per_tick
        self._queue: deque[Request] = deque()
        self._next_rid = 0
        self.tick = 0

    # -- intake --------------------------------------------------------------

    def submit(self, image, *, submit_time: float = 0.0) -> Request:
        """Enqueue one request; returns the stamped Request."""
        req = Request(rid=self._next_rid, image=image,
                      submit_time=submit_time, arrival_tick=self.tick)
        self._next_rid += 1
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    # -- routing -------------------------------------------------------------

    def plan_tick(self, pending: int | None = None) -> list[tuple[int, int]]:
        """[(bucket, take)] decisions draining ``pending`` head-of-queue
        requests under this tick's batch budget — pure planning, no state.

        Each step takes ``min(remaining, max_bucket)`` requests from the
        queue head and routes them to the smallest admissible bucket.
        """
        pending = self.pending() if pending is None else pending
        plan = []
        budget = self.max_batches_per_tick
        while pending > 0 and (budget is None or len(plan) < budget):
            take = min(pending, self.buckets[-1])
            plan.append((smallest_bucket(take, self.buckets), take))
            pending -= take
        return plan

    def next_batch(self) -> tuple[int, list[Request]] | None:
        """Pop the next (bucket, requests) batch off the queue head, or None.

        FIFO: requests leave in arrival order, oldest first — a pending
        request is never passed over for a later arrival.
        """
        if not self._queue:
            return None
        take = min(len(self._queue), self.buckets[-1])
        bucket = smallest_bucket(take, self.buckets)
        reqs = [self._queue.popleft() for _ in range(take)]
        for r in reqs:
            r.bucket = bucket
        return bucket, reqs

    def end_tick(self) -> int:
        """Advance the tick counter (the engine calls this once per tick)."""
        self.tick += 1
        return self.tick
