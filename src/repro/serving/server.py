"""ServeEngine — the production serving tier over the event-resident CNN.

One engine = one replica: a (data, model) mesh with weights replicated and
the batch axis sharded on ``data``, one AOT-compiled pipeline per batch
bucket, and a continuous batcher routing the FIFO request queue into the
smallest admissible bucket each tick (DESIGN.md §10).

The three invariants the tier is built around, each enforced or measured:

  * **No steady-state compilation.**  Every bucket executable is built at
    startup (``serving.aot``); ``recompiles`` counts every lower+compile
    the engine ever performs, and a flat counter after warmup proves no
    tick traced or compiled anything (CI asserts this — ``serve --smoke``).
  * **Padding is bitwise-free.**  Short batches are zero-padded to the
    bucket shape; zero rows ride the pipeline as event-free streams and
    their logits are sliced off, so a real request's logits are bitwise
    the unpadded forward's (tests/test_serving.py asserts per bucket).
  * **No silent event-path degradation.**  ``boundary_report`` abstract-
    traces every bucket's pipeline under ``engine.trace_dispatch``; an
    eligible boundary reporting ``fallback_decode`` is a serving bug, not
    a slow path (CI-fatal in the smoke loop).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from repro import engine as mnf_engine
from repro.core.fire import FireConfig
from repro.launch.steps import make_cnn_serve_step
from repro.serving.aot import (aot_compile, configure_persistent_cache,
                               load_executable, save_executable,
                               snapshot_key)
from repro.serving.batcher import (DEFAULT_BUCKETS, ContinuousBatcher,
                                   Request, pad_bucket)

__all__ = ["ServeEngineConfig", "ServeEngine", "percentile"]


@dataclasses.dataclass(frozen=True)
class ServeEngineConfig:
    """Replica-level knobs of the serving tier (the CLI maps onto this).

    buckets:      compiled batch shapes, ascending (requests are padded up
                  to the smallest admissible one).
    mnf:          event-resident pipeline (False = dense oracle serving).
    backend/threshold: forwarded into the per-bucket EngineConfig.
    cache_dir:    warm-start directory (None = off): holds both the JAX
                  persistent compilation cache and per-bucket executable
                  snapshots, so a restarted replica restores finished
                  executables from disk without tracing or compiling.
    aot_warmup:   compile every bucket at startup (False defers each bucket
                  to its first request — only for tests/latency studies).
    max_batches_per_tick: tick batch budget (None = drain the queue).
    """

    buckets: tuple = DEFAULT_BUCKETS
    mnf: bool = True
    backend: str = "auto"
    threshold: float = 0.0
    cache_dir: str | None = None
    aot_warmup: bool = True
    max_batches_per_tick: int | None = None


def percentile(values: list, q: float) -> float:
    """p-th percentile of a latency list (0 for an empty window)."""
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


class ServeEngine:
    """One serving replica: sharded, continuously batched, AOT-warmed."""

    def __init__(self, spec, params, cfg: ServeEngineConfig | None = None, *,
                 mesh=None, engine_cfg=None, fire_cfg=None):
        self.cfg = cfg or ServeEngineConfig()
        self.spec = spec
        if self.cfg.cache_dir:
            configure_persistent_cache(self.cfg.cache_dir)
        if mesh is None:
            from repro.launch.mesh import make_serve_mesh
            mesh = make_serve_mesh()
        self.mesh = mesh
        ecfg = (engine_cfg or mnf_engine.EngineConfig(
            backend=self.cfg.backend,
            threshold=self.cfg.threshold)).resolved()
        fire_cfg = fire_cfg or FireConfig(threshold=self.cfg.threshold)
        self.fire_cfg = fire_cfg
        # donate=False: logits cannot alias the image buffer, so donation
        # buys nothing here and XLA warns per bucket; the padded buffer is
        # engine-owned and reused across ticks anyway.
        self.plans = {
            b: make_cnn_serve_step(spec, b, mnf=self.cfg.mnf,
                                   engine_cfg=ecfg, fire_cfg=fire_cfg,
                                   mesh=mesh, donate=False)
            for b in self.cfg.buckets}
        self.engine_cfg = ecfg
        self.batcher = ContinuousBatcher(
            self.cfg.buckets,
            max_batches_per_tick=self.cfg.max_batches_per_tick)
        # Params are placed once, replicated over the mesh (weights
        # replicated, batch sharded — ROADMAP item 1's layout).
        self.params = self._replicate(params)
        self._exec: dict[int, Any] = {}
        #: Every lower+compile this engine ever ran.  Flat after warmup ==
        #: no steady-state tick compiled anything (the CI smoke invariant).
        self.recompiles = 0
        #: Buckets whose executable was restored from a cache_dir snapshot
        #: (no trace, no lower, no compile — the restarted-replica path).
        self.snapshot_hits = 0
        self.warmup_s: dict[int, dict] = {}
        self.completed: list[Request] = []
        self.ttfr_s: float | None = None   # time to first response
        self._born = time.perf_counter()
        self._serve_window = 0.0
        if self.cfg.aot_warmup:
            self.warm()

    # -- placement -----------------------------------------------------------

    def _replicate(self, params):
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = NamedSharding(self.mesh, P())
        return jax.tree.map(lambda p: jax.device_put(p, sh), params)

    def _place(self, bucket: int, x: np.ndarray):
        sh = self.plans[bucket].input_sharding
        return jax.device_put(x, sh) if sh is not None else jax.numpy.asarray(x)

    # -- compilation ---------------------------------------------------------

    def _snapshot_key(self, bucket: int) -> str:
        return snapshot_key(self.spec, bucket, self.cfg.mnf,
                            self.engine_cfg, self.fire_cfg,
                            tuple(self.mesh.axis_names),
                            tuple(self.mesh.devices.shape))

    def _compiled(self, bucket: int):
        """The bucket's AOT executable.

        Resolution order: in-memory → ``cache_dir`` executable snapshot
        (restored without any trace/lower/compile — the restarted-replica
        fast path) → lower+compile (counted in ``recompiles``, snapshotted
        for the next replica)."""
        if bucket not in self._exec:
            plan = self.plans[bucket]
            key = self._snapshot_key(bucket)
            if self.cfg.cache_dir:
                t0 = time.perf_counter()
                restored = load_executable(self.cfg.cache_dir, key)
                if restored is not None:
                    self._exec[bucket] = restored
                    self.snapshot_hits += 1
                    self.warmup_s[bucket] = dict(
                        load_s=round(time.perf_counter() - t0, 4))
                    return restored
            self.recompiles += 1
            compiled, lower_s, compile_s = aot_compile(plan.fn,
                                                       plan.arg_specs)
            self._exec[bucket] = compiled
            self.warmup_s[bucket] = dict(lower_s=round(lower_s, 4),
                                         compile_s=round(compile_s, 4))
            if self.cfg.cache_dir:
                save_executable(compiled, self.cfg.cache_dir, key)
        return self._exec[bucket]

    def warm(self) -> dict:
        """AOT-compile every bucket (startup warmup; persistent-cache hits
        make a restarted replica's warmup a disk read).  Returns per-bucket
        lower/compile seconds."""
        for b in self.cfg.buckets:
            self._compiled(b)
        return self.warmup_s

    def boundary_report(self, bucket: int | None = None) -> dict:
        """Abstract-trace one bucket's pipeline: chained/pool/fallback
        counts plus the per-boundary routing decisions (no numeric work —
        ``jax.eval_shape`` under the dispatch tracer).
        ``fallback_decodes`` must be 0 on an eligible network, and because
        routes are trace-time static (DESIGN.md §11) ``routes`` states
        exactly what each compiled boundary does — a snapshot-restored
        executable must report the same list it was compiled with (the
        serve smoke checks restart drift)."""
        from repro.models.cnn import make_cnn_forward
        from repro.models.mlp import MLPSpec, make_mlp_forward
        bucket = self.cfg.buckets[0] if bucket is None else bucket
        plan = self.plans[bucket]
        make_fwd = make_mlp_forward if isinstance(self.spec, MLPSpec) \
            else make_cnn_forward
        fwd = make_fwd(self.spec, mnf=self.cfg.mnf,
                       engine_cfg=self.engine_cfg)
        with mnf_engine.trace_dispatch() as recs:
            jax.eval_shape(fwd, plan.arg_specs[0], plan.arg_specs[1])
        routes = [dict(op=r.get("op"), route=r.get("route"),
                       occupancy=r.get("occupancy"),
                       source=r.get("route_source"),
                       shape_class=r.get("shape_class"))
                  for r in recs if r.get("route") is not None]
        route_counts: dict[str, int] = {}
        for r in routes:
            route_counts[r["route"]] = route_counts.get(r["route"], 0) + 1
        return dict(
            bucket=bucket,
            chained=sum(1 for r in recs if r.get("chained")),
            pool_events=sum(1 for r in recs if r.get("pool_events")),
            fallback_decodes=sum(
                1 for r in recs if r.get("fallback_decode")),
            routed_dense=sum(1 for r in recs if r.get("routed_dense")),
            routes=routes, route_counts=route_counts,
            boundaries=plan.boundaries)

    # -- request path --------------------------------------------------------

    def submit(self, image) -> Request:
        """Enqueue one request (a (H, W, C) image)."""
        return self.batcher.submit(image, submit_time=time.perf_counter())

    def run_tick(self) -> list[Request]:
        """Drain this tick's queue through the compiled buckets.

        Routing, padding, execution, unpadding; completions carry
        per-request latency (submit → logits ready).  Returns the
        requests completed this tick, in FIFO order.
        """
        t_tick0 = time.perf_counter()
        done: list[Request] = []
        budget = self.batcher.max_batches_per_tick
        batches = 0
        while budget is None or batches < budget:
            batch = self.batcher.next_batch()
            if batch is None:
                break
            bucket, reqs = batch
            batches += 1
            x = pad_bucket([r.image for r in reqs], bucket)
            y = self._compiled(bucket)(self.params, self._place(bucket, x))
            y = jax.block_until_ready(y)
            now = time.perf_counter()
            logits = np.asarray(y)[:len(reqs)]      # mask padded rows off
            for i, r in enumerate(reqs):
                r.result = logits[i]
                r.latency_s = now - r.submit_time
                r.completion_tick = self.batcher.tick
            if self.ttfr_s is None:
                self.ttfr_s = now - self._born
            done.extend(reqs)
        self.batcher.end_tick()
        self._serve_window += time.perf_counter() - t_tick0
        self.completed.extend(done)
        return done

    # -- reporting -----------------------------------------------------------

    def stats(self) -> dict:
        """requests/s + p50/p99 latency, overall and per bucket."""
        lats = [r.latency_s for r in self.completed]
        per_bucket = {}
        for b in self.cfg.buckets:
            bl = [r.latency_s for r in self.completed if r.bucket == b]
            per_bucket[b] = dict(
                requests=len(bl),
                p50_ms=round(percentile(bl, 50) * 1e3, 3),
                p99_ms=round(percentile(bl, 99) * 1e3, 3))
        return dict(
            requests=len(lats),
            requests_s=round(len(lats) / max(self._serve_window, 1e-9), 2),
            p50_ms=round(percentile(lats, 50) * 1e3, 3),
            p99_ms=round(percentile(lats, 99) * 1e3, 3),
            per_bucket=per_bucket,
            recompiles=self.recompiles,
            snapshot_hits=self.snapshot_hits,
            warmup_s=self.warmup_s,
            ttfr_s=round(self.ttfr_s, 4) if self.ttfr_s is not None
            else None,
            devices=len(self.mesh.devices.flat),
            data_shards={b: p.data_shards for b, p in self.plans.items()})
