"""Int8 affine quantization (Jacob et al., CVPR'18) — paper §5.2.3 step 2.

The MNF MAC cluster accumulates in 32-bit and quantizes the accumulated sum
to 8-bit before firing it to the next layer.  We reproduce that numerically:
weights/activations are int8 (simulated in fp32 carriers on CPU), partial
sums are fp32/int32, and the fire phase re-quantizes.

At LM scale (the assigned-architecture cells) we compute in bf16 — see
DESIGN.md §8 item 2.  On the event path this module is first-class: with
``EngineConfig(int8_events=True)`` fire emits int8 event values carrying a
symmetric ``QParams`` on the stream and consumers dequantize at tile load
(DESIGN.md §12).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["QParams", "calibrate", "dequantize_accumulator", "quantize",
           "dequantize", "fake_quant", "requantize_accumulator"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QParams:
    """Affine quantization parameters: real = scale * (q - zero_point)."""

    scale: jax.Array        # ()
    zero_point: jax.Array   # () int32

    @staticmethod
    def symmetric(scale) -> "QParams":
        return QParams(scale=jnp.asarray(scale, jnp.float32),
                       zero_point=jnp.zeros((), jnp.int32))


def calibrate(x: jax.Array, *, symmetric: bool = True,
              bits: int = 8) -> QParams:
    """Min/max calibration of quantization parameters for tensor ``x``."""
    qmax = 2 ** (bits - 1) - 1
    if symmetric:
        amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
        return QParams.symmetric(amax / qmax)
    lo, hi = jnp.min(x), jnp.max(x)
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, 1e-8)
    scale = (hi - lo) / (2 ** bits - 1)
    zp = jnp.round(-lo / scale).astype(jnp.int32) - 2 ** (bits - 1)
    return QParams(scale=scale.astype(jnp.float32), zero_point=zp)


def quantize(x: jax.Array, qp: QParams, *, bits: int = 8) -> jax.Array:
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = jnp.round(x / qp.scale) + qp.zero_point
    return jnp.clip(q, qmin, qmax).astype(jnp.int8 if bits == 8 else jnp.int32)


def dequantize(q: jax.Array, qp: QParams) -> jax.Array:
    return (q.astype(jnp.float32) - qp.zero_point) * qp.scale


def fake_quant(x: jax.Array, qp: QParams, *, bits: int = 8) -> jax.Array:
    """Quantize-dequantize round trip (straight-through value)."""
    return dequantize(quantize(x, qp, bits=bits), qp)


def dequantize_accumulator(acc: jax.Array, in_qp: QParams,
                           w_qp: QParams) -> jax.Array:
    """Real value of an accumulator of int8×int8 products.

    acc is an int32 (or fp32 carrier) accumulator of products whose input
    and weight scales are ``in_qp`` / ``w_qp``; its real value is
    acc * in_scale * w_scale (zero points are handled by the MAC itself).
    """
    return acc.astype(jnp.float32) * (in_qp.scale * w_qp.scale)


def requantize_accumulator(acc: jax.Array, in_qp: QParams, w_qp: QParams,
                           out_qp: QParams, *, bits: int = 8) -> jax.Array:
    """Paper §5.2.3: 32-bit accumulated sum -> 8-bit output activation.

    Dequantize the accumulated sum to its real value, then quantize into
    ``out_qp`` scale — the boundary requantization the int8 event path
    applies at every fire (DESIGN.md §12; the engine dequantizes at tile
    load, so its accumulators carry unit scales).
    """
    return quantize(dequantize_accumulator(acc, in_qp, w_qp), out_qp,
                    bits=bits)
