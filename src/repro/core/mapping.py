"""PE mapping technique — paper §5.3 (Eq. 1, Eq. 2, Fig. 7).

Sizes the PE grid for a layer given per-PE SRAM capacities.  Used by the
cost model (cycle/energy accounting needs the PE count) and exported for the
sharding planner's sanity checks (tiles-per-device arithmetic).

Paper defaults (Table 3): 11 PEs, 27 multipliers/PE, weight SRAM 691.2 KB,
accumulate SRAM 67.5 KB, 8-bit weights, 32-bit partial sums.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["PECapacity", "PAPER_PE", "conv_pes", "fc_pes", "noc_grid",
           "LayerMapping", "plan_conv_layer", "plan_fc_layer"]


@dataclasses.dataclass(frozen=True)
class PECapacity:
    """Per-PE storage limits, in element counts (N neurons, W weights)."""

    neurons: int   # accumulate SRAM capacity / 4B psum  (paper: N)
    weights: int   # weight SRAM capacity / 1B weight    (paper: W)

    @staticmethod
    def from_table3() -> "PECapacity":
        # Table 3: weight SRAM 691.2 KB @ 8-bit weights; accumulate SRAM
        # 67.5 KB @ 32-bit partial sums.
        return PECapacity(neurons=int(67.5 * 1024 // 4),
                          weights=int(691.2 * 1024))


PAPER_PE = PECapacity.from_table3()


def conv_pes(out_w: int, out_h: int, k: int, c_out: int, c_in: int,
             cap: PECapacity = PAPER_PE, *, paper_verbatim: bool = False) -> int:
    """Eq. 1: C_PEs = max(w·h/N, k·k·c/W)  (ceil).

    The paper's Eq. 1 counts weights as k·k·c with c = #filters (its worked
    example has c_in = 1); ``paper_verbatim=True`` reproduces that exactly.
    The default generalizes to k·k·c_in·c_out weights and w·h·c_out output
    neurons, which matches the paper's own Fig. 7 example (two 28×28 OFMs,
    N=800 ⇒ 2 PEs).
    """
    if paper_verbatim:
        neurons_needed = out_w * out_h
        weights_needed = k * k * c_out
    else:
        neurons_needed = out_w * out_h * c_out
        weights_needed = k * k * c_in * c_out
    return max(math.ceil(neurons_needed / cap.neurons),
               math.ceil(weights_needed / cap.weights), 1)


def fc_pes(m: int, n: int, cap: PECapacity = PAPER_PE) -> int:
    """Eq. 2: F_PEs = max(n/N, m·n/W) (ceil).

    Paper example: 1568×128 FC with N=800, W=9000 ⇒ max(1, 23) = 23 PEs.
    """
    return max(math.ceil(n / cap.neurons), math.ceil(m * n / cap.weights), 1)


def noc_grid(pes: int) -> tuple[int, int]:
    """PEs arranged in a ⌈√PEs⌉ × ⌈√PEs⌉ NoC grid (paper §5.3)."""
    side = math.ceil(math.sqrt(pes))
    return side, side


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    pes: int
    grid: tuple[int, int]
    neurons_per_pe: int
    weights_per_pe: int
    # Events must be multicast to every PE holding a slice of the layer
    # (paper: NoC multicast); fan-out feeds the cost model's NoC term.
    event_fanout: int


def plan_conv_layer(out_w: int, out_h: int, k: int, c_out: int, c_in: int,
                    cap: PECapacity = PAPER_PE) -> LayerMapping:
    pes = conv_pes(out_w, out_h, k, c_out, c_in, cap)
    return LayerMapping(
        pes=pes, grid=noc_grid(pes),
        neurons_per_pe=math.ceil(out_w * out_h * c_out / pes),
        weights_per_pe=math.ceil(k * k * c_in * c_out / pes),
        event_fanout=pes)


def plan_fc_layer(m: int, n: int, cap: PECapacity = PAPER_PE) -> LayerMapping:
    pes = fc_pes(m, n, cap)
    return LayerMapping(
        pes=pes, grid=noc_grid(pes),
        neurons_per_pe=math.ceil(n / pes),
        weights_per_pe=math.ceil(m * n / pes),
        event_fanout=pes)
