"""Fire phase (paper §4.2) — threshold, activate, and emit events.

After the multiply phase accumulates into output neurons, the fire module
compares each output with a threshold; supra-threshold outputs become input
events for the next layer, sub-threshold outputs are discarded.  With
threshold = 0 this is exactly ReLU + sparsity-preserving propagation, so the
event-driven network is numerically identical to the dense one — the key
correctness invariant of the whole system (property-tested).

This module is the pure-jnp implementation; ``kernels/fire_compact`` is the
fused Pallas version (threshold + per-block occupancy in one VMEM pass).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core import quantize as qz

__all__ = ["FireConfig", "fire", "fire_stats", "fire_to_block_events"]


@dataclasses.dataclass(frozen=True)
class FireConfig:
    """Configuration of the fire module.

    threshold:   fire iff activation > threshold (paper: ReLU threshold,
                 typically 0).  ``magnitude=True`` fires on |a| > threshold —
                 the LM generalization for non-ReLU nonlinearities.
    magnitude:   see above.
    signed:      explicit signed-event mode: fire on |a| > threshold and emit
                 the *signed* value (a negative supra-threshold delta is an
                 event, not a drop).  Same gating rule as ``magnitude`` —
                 the separate flag exists because downstream consumers must
                 know the stream can carry negatives: the pool's segment max
                 (identity 0) is only bitwise for ReLU-family streams, so it
                 rejects signed streams by name (engine.pool_ineligible_reason),
                 while the recurrent decode path *requires* signed fire
                 (per-token state deltas are two-sided — DESIGN.md §13).
    quantize_to_int8: reproduce the paper's accumulate(fp32/int32) -> int8
                 requantization before firing.
    """

    threshold: float = 0.0
    magnitude: bool = False
    signed: bool = False
    quantize_to_int8: bool = False


def fire(acc: jax.Array, cfg: FireConfig = FireConfig(),
         out_qp: qz.QParams | None = None) -> jax.Array:
    """Apply the fire decision to an accumulator tensor.

    Returns the *dense* fired tensor (zeros where not fired); event extraction
    is a separate step (``fire_to_block_events`` /
    ``events.encode_scalar_events``) so callers can choose granularity.
    """
    if cfg.magnitude or cfg.signed:
        live = jnp.abs(acc) > cfg.threshold
        fired = jnp.where(live, acc, 0)
    else:
        fired = jnp.where(acc > cfg.threshold, acc, 0)  # ReLU at threshold 0
    if cfg.quantize_to_int8:
        qp = out_qp if out_qp is not None else qz.calibrate(fired)
        fired = qz.fake_quant(fired, qp)
    return fired


def fire_stats(acc: jax.Array, cfg: FireConfig = FireConfig()):
    """(fired tensor, #events fired, density) — cost-model instrumentation."""
    fired = fire(acc, cfg)
    n = ev.count_nonzero_events(fired)
    density = n / acc.size
    return fired, n, density


def fire_to_block_events(acc: jax.Array, *, blk_m: int, blk_k: int,
                         cfg: FireConfig = FireConfig(),
                         capacity: int | None = None) -> tuple[jax.Array, ev.BlockEvents]:
    """Fire and re-encode as block events for the next layer's multiply phase.

    acc: (M, K_next) accumulator laid out as next layer's input.
    Returns (dense fired tensor, BlockEvents).
    """
    fired = fire(acc, cfg)
    bev = ev.encode_block_events(fired, blk_m=blk_m, blk_k=blk_k,
                                 capacity=capacity, threshold=0.0)
    return fired, bev
