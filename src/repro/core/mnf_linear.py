"""Event-driven fully-connected layer — paper Algorithm 2 + fire phase.

Three interchangeable execution paths (all numerically identical for
threshold-0 ReLU networks; property-tested):

  * ``dense_linear``        — baseline jnp matmul (the oracle).
  * ``scalar_event_linear`` — faithful Algorithm 2: for each input event
    (value, neuron address) read the weight row at the direct address and
    accumulate into every output neuron.  Executed with lax.fori_loop over a
    padded event list; this is the semantic reference for the cost model.
  * ``block_event_linear``  — the TPU-native path: compacted K-block events ×
    weight row-blocks (pure-jnp here; ``kernels/event_matmul`` is the Pallas
    version with scalar-prefetch weight addressing).

The multiply phase computes acc[n] += W[addr, n] * value per event, i.e. the
input-driven (scatter) view of y = x @ W; the fire phase thresholds and emits
next-layer events.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.fire import FireConfig, fire

__all__ = ["dense_linear", "scalar_event_linear", "block_event_linear",
           "block_event_linear_from_events", "mnf_linear"]


def dense_linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Oracle: y = x @ W (+ b).  x: (..., K), w: (K, N)."""
    y = jnp.einsum("...k,kn->...n", x, w)
    if b is not None:
        y = y + b
    return y


def scalar_event_linear(x: jax.Array, w: jax.Array,
                        b: jax.Array | None = None) -> jax.Array:
    """Algorithm 2, verbatim semantics, for a single input vector x: (K,).

    Each non-zero input neuron fires one event carrying (value, addr); the
    multiply module reads weight row ``addr`` (the direct start_weight
    address) and accumulates value * W[addr, :] into all N output neurons.
    """
    assert x.ndim == 1, "scalar-event path is per-activation-vector"
    k, n = w.shape
    evs = ev.encode_scalar_events(x)                      # capacity = K
    acc0 = jnp.zeros((n,), jnp.promote_types(x.dtype, w.dtype))

    def body(i, acc):
        # Process event i iff live; padded slots have value 0 so the
        # accumulate is a no-op either way (paper: idle PE on no event).
        value = evs.values[i]
        addr = evs.indices[i]
        return acc + value * w[addr, :]

    acc = jax.lax.fori_loop(0, evs.capacity, body, acc0)
    if b is not None:
        acc = acc + b
    return acc


def block_event_linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
                       *, blk_m: int = 8, blk_k: int = 128,
                       capacity: int | None = None,
                       threshold: float = 0.0) -> jax.Array:
    """TPU-native multiply phase: compacted K-block events × weight blocks.

    x: (M, K) activations, w: (K, N).  Lossless when capacity covers all live
    blocks and threshold == 0 matches the upstream fire threshold.
    Pure-jnp twin of kernels/event_matmul (same event encoding).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    xp = ev.pad_to_block_multiple(x, blk_m, 0)
    xp = ev.pad_to_block_multiple(xp, blk_k, 1)
    bev = ev.encode_block_events(xp, blk_m=blk_m, blk_k=blk_k,
                                 capacity=capacity, threshold=threshold)
    y = block_event_linear_from_events(bev, w)[:m]
    if b is not None:
        y = y + b
    return y


def block_event_linear_from_events(bev: ev.BlockEvents, w: jax.Array,
                                   qparams=None) -> jax.Array:
    """Multiply phase on *pre-encoded* block events (pure-jnp twin of
    kernels/event_matmul.event_matmul_from_events; the engine's chained-layer
    path rides this so consecutive layers skip the decode→re-encode
    round-trip).  Returns (G * blk_m, N); callers slice off row padding.

    With ``qparams`` the event values are int8 codes: each tile is
    dequantized at load — before the slot mask, so padding slots stay
    exact f32 zeros whatever the zero point — and the contraction runs in
    f32, matching the f32 path fed the fake-quant twin bit for bit
    (DESIGN.md §12).
    """
    g, e, bm, bk = bev.values.shape
    n = w.shape[1]
    wp = ev.pad_to_block_multiple(w, bk, 0)
    assert wp.shape[0] == bev.num_k_blocks * bk, (w.shape, bev.num_k_blocks, bk)
    wb = wp.reshape(bev.num_k_blocks, bk, n)
    # Gather the weight tile named by each event's direct block address and
    # contract: acc[g, bm, n] = sum_e vals[g, e, bm, bk] @ W[idx[g, e], bk, n].
    wtiles = wb[bev.block_idx]                            # (G, E, bk, N)
    slot_live = jnp.arange(e, dtype=jnp.int32)[None, :] < bev.counts[:, None]
    values = bev.values
    if qparams is not None:
        from repro.core.quantize import dequantize
        values = dequantize(values, qparams)
    vals = jnp.where(slot_live[:, :, None, None], values, 0)
    acc = jnp.einsum("gemk,gekn->gmn", vals, wtiles)
    return acc.reshape(g * bm, n)


def mnf_linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
               *, fire_cfg: FireConfig = FireConfig(),
               blk_m: int = 8, blk_k: int = 128,
               capacity: int | None = None) -> jax.Array:
    """Full MNF FC layer: engine multiply phase + fire phase.

    Deprecation shim — new code should call ``repro.engine.linear`` +
    ``repro.engine.fire`` with one :class:`~repro.engine.EngineConfig`.
    """
    from repro import engine
    cfg = engine.EngineConfig(backend="block", blk_m=blk_m, blk_k=blk_k,
                              capacity=capacity)
    acc = engine.linear(x, w, b, cfg)
    return fire(acc, fire_cfg)
