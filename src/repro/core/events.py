"""Event encoding — the paper's §4 compressed-data-storage scheme, TPU-adapted.

The paper encodes every non-zero activation as an *event* carrying the value
plus direct addresses (start_weight_addr, start_neuron_addr, ...) so that a PE
can fetch exactly the weights it needs with O(1) address arithmetic instead of
CSR/CSC/COO pointer chasing.

On TPU the profitable event granularity is a VMEM tile, not a scalar (see
DESIGN.md §2).  This module provides both:

  * scalar events  — faithful Algorithm-1/2 semantics, used by the CNN
    reference path and the cost model (event counting);
  * block events   — `(values[B_blk, E, blk], block_idx[B_blk, E], count)`
    compacted K-blocks, the encoding consumed by the `event_matmul` Pallas
    kernel (block_idx is the direct weight-tile address).

All functions are pure jnp / jax.lax and jit-safe (static shapes: event lists
are padded to a static capacity, with an explicit count — the TPU analogue of
the paper's end-of-data event).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "ScalarEvents",
    "BlockEvents",
    "encode_scalar_events",
    "count_nonzero_events",
    "block_occupancy",
    "encode_block_events",
    "decode_block_events",
    "gather_row_groups",
    "pad_to_block_multiple",
]


# ---------------------------------------------------------------------------
# Scalar events (paper-faithful; Algorithm 1 / 2 inputs)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScalarEvents:
    """Padded list of scalar events for one feature map / activation vector.

    values:  (capacity,)   event activation values (0 in padding slots)
    indices: (capacity,)   flat position of the activation (0 in padding)
    count:   ()            number of live events (<= capacity)
    """

    values: jax.Array
    indices: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return self.values.shape[0]


def encode_scalar_events(x: jax.Array, capacity: int | None = None,
                         threshold: float = 0.0) -> ScalarEvents:
    """Compact the non-zero (|x| > threshold) entries of ``x`` into events.

    This is the fire-module output format: each event is (value, address).
    ``capacity`` defaults to x.size (lossless).  Events are emitted in
    ascending address order — matching the paper's raster-order event stream.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    if capacity is None:
        capacity = n
    live = jnp.abs(flat) > threshold
    count = jnp.sum(live, dtype=jnp.int32)
    # Stable compaction: sort (1 - live) keeps live entries first, in order.
    order = jnp.argsort(jnp.logical_not(live), stable=True)
    idx = order[:capacity].astype(jnp.int32)
    vals = flat[idx]
    slot_live = jnp.arange(capacity, dtype=jnp.int32) < count
    vals = jnp.where(slot_live, vals, 0)
    idx = jnp.where(slot_live, idx, 0)
    return ScalarEvents(values=vals, indices=idx, count=count)


def count_nonzero_events(x: jax.Array, threshold: float = 0.0) -> jax.Array:
    """Number of scalar events a tensor would fire (cost-model instrumentation)."""
    return jnp.sum(jnp.abs(x) > threshold, dtype=jnp.int64.dtype
                   if jax.config.read("jax_enable_x64") else jnp.int32)


# ---------------------------------------------------------------------------
# Block events (TPU-native; consumed by kernels/event_matmul)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockEvents:
    """Compacted K-block events for a batch-tiled activation matrix.

    For an activation matrix a[(M), (K)] tiled into K//blk blocks per row
    group:

    values:    (G, E, blk_m, blk_k)  the live activation tiles (padding = 0)
    block_idx: (G, E)                direct weight-tile address of each event
                                     (padding repeats the last live index so a
                                     consuming kernel's DMA is a no-op)
    counts:    (G,)                  number of live events per row group
    num_k_blocks: static int         K // blk_k
    """

    values: jax.Array
    block_idx: jax.Array
    counts: jax.Array
    num_k_blocks: int = dataclasses.field(metadata=dict(static=True))

    @property
    def capacity(self) -> int:
        return self.block_idx.shape[-1]


def pad_to_block_multiple(x: jax.Array, block: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to a multiple of ``block``."""
    size = x.shape[axis]
    rem = (-size) % block
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def block_occupancy(x: jax.Array, blk_k: int, threshold: float = 0.0) -> jax.Array:
    """Per-K-block liveness: any |x| > threshold inside the block.

    x: (..., K) -> bool (..., K // blk_k).  K must be a multiple of blk_k.
    """
    *lead, k = x.shape
    assert k % blk_k == 0, f"K={k} not a multiple of blk_k={blk_k}"
    xb = x.reshape(*lead, k // blk_k, blk_k)
    return jnp.any(jnp.abs(xb) > threshold, axis=-1)


def encode_block_events(a: jax.Array, *, blk_m: int, blk_k: int,
                        capacity: int | None = None,
                        threshold: float = 0.0) -> BlockEvents:
    """Encode activation matrix a (M, K) into block events.

    Rows are grouped into G = M // blk_m row groups.  A K-block is an event
    for a group iff any element in the (blk_m, blk_k) tile exceeds the
    threshold.  Live tiles are compacted (in ascending K-block order — the
    paper's raster event order) to a static ``capacity`` (default: all
    blocks, lossless).
    """
    m, k = a.shape
    assert m % blk_m == 0 and k % blk_k == 0, (m, k, blk_m, blk_k)
    g, nkb = m // blk_m, k // blk_k
    if capacity is None:
        capacity = nkb
    capacity = min(capacity, nkb)
    tiles = a.reshape(g, blk_m, nkb, blk_k).transpose(0, 2, 1, 3)  # (G, nkb, bm, bk)
    live = jnp.any(jnp.abs(tiles) > threshold, axis=(-1, -2))      # (G, nkb)
    counts = jnp.sum(live, axis=-1, dtype=jnp.int32)               # (G,)
    order = jnp.argsort(jnp.logical_not(live), axis=-1, stable=True)  # live first
    idx = order[:, :capacity].astype(jnp.int32)                    # (G, E)
    slot = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    slot_live = slot < counts[:, None]
    # Padding index repeats the last live index (DMA no-op downstream);
    # all-empty groups point at block 0 with zero values.
    last_live = jnp.maximum(counts - 1, 0)
    gathered_last = jnp.take_along_axis(idx, last_live[:, None], axis=1)
    idx = jnp.where(slot_live, idx, gathered_last)
    vals = jnp.take_along_axis(tiles, idx[:, :, None, None], axis=1)  # (G,E,bm,bk)
    vals = jnp.where(slot_live[:, :, None, None], vals, 0)
    return BlockEvents(values=vals, block_idx=idx, counts=counts,
                       num_k_blocks=nkb)


def gather_row_groups(bev: BlockEvents, idx: jax.Array,
                      live: jax.Array) -> BlockEvents:
    """Re-index row groups of ``bev`` — the event-domain image of a row gather.

    idx:  (G',) int32   source row-group index per output group
    live: (G',) bool    False marks groups with no source (e.g. a conv tap
                        reading outside the padded feature map); their counts
                        are zeroed so consumers treat them as event-free.

    This is what lets a conv tap consume the *fired feature-map events* of
    the previous layer directly: a shifted spatial slice of a pixel-granular
    (blk_m == 1) encoding is exactly a gather of its row groups — no dense
    map is ever materialized (DESIGN.md §5).
    """
    counts = jnp.where(live, bev.counts[idx], 0)
    return BlockEvents(values=bev.values[idx], block_idx=bev.block_idx[idx],
                       counts=counts, num_k_blocks=bev.num_k_blocks)


def decode_block_events(ev: BlockEvents, *, blk_m: int, blk_k: int,
                        m: int, k: int) -> jax.Array:
    """Inverse of :func:`encode_block_events` (up to thresholded-away values).

    Scatter the event tiles back into a dense (M, K) matrix.  Property-tested:
    decode(encode(x)) == x whenever threshold == 0.
    """
    g, e = ev.block_idx.shape
    nkb = ev.num_k_blocks
    assert m == g * blk_m and k == nkb * blk_k
    dense = jnp.zeros((g, nkb, blk_m, blk_k), ev.values.dtype)
    slot_live = jnp.arange(e, dtype=jnp.int32)[None, :] < ev.counts[:, None]
    vals = jnp.where(slot_live[:, :, None, None], ev.values, 0)
    garr = jnp.arange(g, dtype=jnp.int32)[:, None].repeat(e, axis=1)
    dense = dense.at[garr.reshape(-1), ev.block_idx.reshape(-1)].add(
        vals.reshape(g * e, blk_m, blk_k))
    return dense.transpose(0, 2, 1, 3).reshape(m, k)
