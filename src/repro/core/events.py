"""Event encoding — the paper's §4 compressed-data-storage scheme, TPU-adapted.

The paper encodes every non-zero activation as an *event* carrying the value
plus direct addresses (start_weight_addr, start_neuron_addr, ...) so that a PE
can fetch exactly the weights it needs with O(1) address arithmetic instead of
CSR/CSC/COO pointer chasing.

On TPU the profitable event granularity is a VMEM tile, not a scalar (see
DESIGN.md §2).  This module provides both:

  * scalar events  — faithful Algorithm-1/2 semantics, used by the CNN
    reference path and the cost model (event counting);
  * block events   — `(values[B_blk, E, blk], block_idx[B_blk, E], count)`
    compacted K-blocks, the encoding consumed by the `event_matmul` Pallas
    kernel (block_idx is the direct weight-tile address).

All functions are pure jnp / jax.lax and jit-safe (static shapes: event lists
are padded to a static capacity, with an explicit count — the TPU analogue of
the paper's end-of-data event).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "STRIP_CO_MIN",
    "STRIP_STRIDES",
    "STRIP_W",
    "ScalarEvents",
    "BlockEvents",
    "encode_scalar_events",
    "count_nonzero_events",
    "block_occupancy",
    "encode_block_events",
    "decode_block_events",
    "gather_row_groups",
    "gather_row_strips",
    "live_block_mask",
    "pad_to_block_multiple",
    "pool_window_map",
    "retile_block_events",
    "retile_fc_addr_offsets",
    "retile_ineligible_reason",
    "scalar_event_rows",
    "strip_eligible",
    "strip_ineligible_reason",
    "strip_parts",
    "strip_shift_live",
    "strip_subtap_counts",
    "strip_tap_map",
]

#: Pixels per row strip of the strip-aligned conv encoding (DESIGN.md §6).
#: Matches the TPU sublane count so a strip event is one (8, blk_k) tile.
STRIP_W = 8


# ---------------------------------------------------------------------------
# Scalar events (paper-faithful; Algorithm 1 / 2 inputs)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScalarEvents:
    """Padded list of scalar events for one feature map / activation vector.

    values:  (capacity,)   event activation values (0 in padding slots)
    indices: (capacity,)   flat position of the activation (0 in padding)
    count:   ()            number of live events (<= capacity)
    """

    values: jax.Array
    indices: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return self.values.shape[0]


def encode_scalar_events(x: jax.Array, capacity: int | None = None,
                         threshold: float = 0.0) -> ScalarEvents:
    """Compact the non-zero (|x| > threshold) entries of ``x`` into events.

    This is the fire-module output format: each event is (value, address).
    ``capacity`` defaults to x.size (lossless).  Events are emitted in
    ascending address order — matching the paper's raster-order event stream.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    if capacity is None:
        capacity = n
    live = jnp.abs(flat) > threshold
    count = jnp.sum(live, dtype=jnp.int32)
    # Stable compaction: sort (1 - live) keeps live entries first, in order.
    order = jnp.argsort(jnp.logical_not(live), stable=True)
    idx = order[:capacity].astype(jnp.int32)
    vals = flat[idx]
    slot_live = jnp.arange(capacity, dtype=jnp.int32) < count
    vals = jnp.where(slot_live, vals, 0)
    idx = jnp.where(slot_live, idx, 0)
    return ScalarEvents(values=vals, indices=idx, count=count)


def count_nonzero_events(x: jax.Array, threshold: float = 0.0) -> jax.Array:
    """Number of scalar events a tensor would fire (cost-model instrumentation)."""
    return jnp.sum(jnp.abs(x) > threshold, dtype=jnp.int64.dtype
                   if jax.config.read("jax_enable_x64") else jnp.int32)


# ---------------------------------------------------------------------------
# Block events (TPU-native; consumed by kernels/event_matmul)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockEvents:
    """Compacted K-block events for a batch-tiled activation matrix.

    For an activation matrix a[(M), (K)] tiled into K//blk blocks per row
    group:

    values:    (G, E, blk_m, blk_k)  the live activation tiles (padding = 0)
    block_idx: (G, E)                direct weight-tile address of each event
                                     (padding repeats the last live index so a
                                     consuming kernel's DMA is a no-op)
    counts:    (G,)                  number of live events per row group
    num_k_blocks: static int         K // blk_k
    """

    values: jax.Array
    block_idx: jax.Array
    counts: jax.Array
    num_k_blocks: int = dataclasses.field(metadata=dict(static=True))

    @property
    def capacity(self) -> int:
        return self.block_idx.shape[-1]


def pad_to_block_multiple(x: jax.Array, block: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to a multiple of ``block``."""
    size = x.shape[axis]
    rem = (-size) % block
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def block_occupancy(x: jax.Array, blk_k: int, threshold: float = 0.0) -> jax.Array:
    """Per-K-block liveness: any |x| > threshold inside the block.

    x: (..., K) -> bool (..., K // blk_k).  K must be a multiple of blk_k.
    """
    *lead, k = x.shape
    assert k % blk_k == 0, f"K={k} not a multiple of blk_k={blk_k}"
    xb = x.reshape(*lead, k // blk_k, blk_k)
    return jnp.any(jnp.abs(xb) > threshold, axis=-1)


def encode_block_events(a: jax.Array, *, blk_m: int, blk_k: int,
                        capacity: int | None = None,
                        threshold: float = 0.0) -> BlockEvents:
    """Encode activation matrix a (M, K) into block events.

    Rows are grouped into G = M // blk_m row groups.  A K-block is an event
    for a group iff any element in the (blk_m, blk_k) tile exceeds the
    threshold.  Live tiles are compacted (in ascending K-block order — the
    paper's raster event order) to a static ``capacity`` (default: all
    blocks, lossless).
    """
    m, k = a.shape
    assert m % blk_m == 0 and k % blk_k == 0, (m, k, blk_m, blk_k)
    g, nkb = m // blk_m, k // blk_k
    if capacity is None:
        capacity = nkb
    capacity = min(capacity, nkb)
    tiles = a.reshape(g, blk_m, nkb, blk_k).transpose(0, 2, 1, 3)  # (G, nkb, bm, bk)
    live = jnp.any(jnp.abs(tiles) > threshold, axis=(-1, -2))      # (G, nkb)
    counts = jnp.sum(live, axis=-1, dtype=jnp.int32)               # (G,)
    order = jnp.argsort(jnp.logical_not(live), axis=-1, stable=True)  # live first
    idx = order[:, :capacity].astype(jnp.int32)                    # (G, E)
    slot = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    slot_live = slot < counts[:, None]
    # Padding index repeats the last live index (DMA no-op downstream);
    # all-empty groups point at block 0 with zero values.
    last_live = jnp.maximum(counts - 1, 0)
    gathered_last = jnp.take_along_axis(idx, last_live[:, None], axis=1)
    idx = jnp.where(slot_live, idx, gathered_last)
    vals = jnp.take_along_axis(tiles, idx[:, :, None, None], axis=1)  # (G,E,bm,bk)
    vals = jnp.where(slot_live[:, :, None, None], vals, 0)
    return BlockEvents(values=vals, block_idx=idx, counts=counts,
                       num_k_blocks=nkb)


def gather_row_groups(bev: BlockEvents, idx: jax.Array,
                      live: jax.Array) -> BlockEvents:
    """Re-index row groups of ``bev`` — the event-domain image of a row gather.

    idx:  (G',) int32   source row-group index per output group
    live: (G',) bool    False marks groups with no source (e.g. a conv tap
                        reading outside the padded feature map); their counts
                        are zeroed so consumers treat them as event-free.

    This is what lets a conv tap consume the *fired feature-map events* of
    the previous layer directly: a shifted spatial slice of a pixel-granular
    (blk_m == 1) encoding is exactly a gather of its row groups — no dense
    map is ever materialized (DESIGN.md §5).
    """
    counts = jnp.where(live, bev.counts[idx], 0)
    return BlockEvents(values=bev.values[idx], block_idx=bev.block_idx[idx],
                       counts=counts, num_k_blocks=bev.num_k_blocks)


def gather_row_strips(bev: BlockEvents, idx: jax.Array, live: jax.Array,
                      shift: int, row_stride: int = 1) -> BlockEvents:
    """Tap-shifted strip gather — the strip analogue of :func:`gather_row_groups`.

    Gathers row-strip groups (``idx``/``live`` exactly as in
    ``gather_row_groups``) and then remaps rows *within* each (blk_m, blk_k)
    tile by the static affine map: output row i takes source row
    ``row_stride * i + shift``, rows whose source falls outside [0, blk_m)
    are zero.  A conv tap at stride 1 whose x-offset is not a multiple of
    STRIP_W straddles two adjacent strips; at stride 2 an output strip reads
    every other input pixel, so the 8 sources of one tap spread over up to
    three strips as *interleaved half-strips* (4 same-parity pixels each).
    One ``gather_row_strips`` per (tap, straddle part) realizes the strided
    slice in the event domain (DESIGN.md §6).

    The row remap is a pure gather + zero mask — no FP arithmetic — so
    gathered values are bit-identical to the source rows.
    """
    g = gather_row_groups(bev, idx, live)
    bm = g.values.shape[2]
    d = int(shift)
    rs = int(row_stride)
    if rs == 1 and d == 0:
        return g
    rows = [rs * i + d for i in range(bm)]
    if not any(0 <= r < bm for r in rows):
        return dataclasses.replace(g, values=jnp.zeros_like(g.values),
                                   counts=jnp.zeros_like(g.counts))
    if rs == 1:      # contiguous shift: slice + zero-pad
        if d > 0:    # out rows [0, bm-d) <- src rows [d, bm)
            vals = jnp.pad(g.values[:, :, d:, :],
                           ((0, 0), (0, 0), (0, d), (0, 0)))
        else:        # out rows [-d, bm) <- src rows [0, bm+d)
            vals = jnp.pad(g.values[:, :, :bm + d, :],
                           ((0, 0), (0, 0), (-d, 0), (0, 0)))
        return dataclasses.replace(g, values=vals)
    take = jnp.asarray([min(max(r, 0), bm - 1) for r in rows], jnp.int32)
    ok = jnp.asarray([0 <= r < bm for r in rows], bool)
    vals = jnp.where(ok[None, None, :, None], g.values[:, :, take, :], 0)
    return dataclasses.replace(g, values=vals)


def live_block_mask(bev: BlockEvents) -> jax.Array:
    """Per-K-block liveness of an event set, (G, num_k_blocks) bool.

    Scatter of the compacted slots back onto the block grid.  Padding slots
    repeat the *last live* block index (the DMA-no-op convention), so they
    are masked out before the scatter — a dead block stays dead even when a
    padding slot points at its neighbour.  This is the skip mask the
    event-gated recurrent step kernels consult per state row-block
    (DESIGN.md §13): ``decode_block_events(bev) != 0`` implies the mask is
    live at that block, never the reverse.
    """
    g, e = bev.block_idx.shape
    mask = jnp.zeros((g, bev.num_k_blocks), jnp.int32)
    if g == 0 or bev.num_k_blocks == 0:
        return mask > 0
    slot_live = jnp.arange(e, dtype=jnp.int32)[None, :] < bev.counts[:, None]
    garr = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[:, None], (g, e))
    mask = mask.at[garr.reshape(-1), bev.block_idx.reshape(-1)].add(
        slot_live.reshape(-1).astype(jnp.int32))
    return mask > 0


def scalar_event_rows(bev: BlockEvents) -> jax.Array:
    """Per-row scalar-event (non-zero activation) counts, (G * blk_m,) f32.

    Derived from the compacted event values alone — no dense twin needed —
    because the block encoding is lossless at threshold 0: every non-zero
    activation sits in exactly one live tile (twin-free instrumentation).
    """
    g, e, bm, bk = bev.values.shape
    slot_live = jnp.arange(e, dtype=jnp.int32)[None, :] < bev.counts[:, None]
    nz = (bev.values != 0) & slot_live[:, :, None, None]
    return jnp.sum(nz, axis=(1, 3), dtype=jnp.float32).reshape(g * bm)


#: Output-channel granule for the strip path.  The bit-exactness contract
#: (strip == per-tap, bitwise) relies on the backend lowering the
#: (8, bk) @ (bk, n) and (1, bk) @ (bk, n) dots with the same per-element
#: K-reduction; XLA picks M-dependent strategies when n has a ragged lane
#: remainder (observed divergence at n = 2 and n = 9, while n = 8, 12, 16
#: hold), so strips require whole sublane groups of output channels.
#: Real conv nets (AlexNet/VGG co in {64, 96, ..., 512}) always qualify.
STRIP_CO_MIN = 8


#: Strides the strip plan covers: output pixel x maps affinely to input
#: pixel stride*x, so each tap gathers at most ``strip_parts(stride)``
#: straddle parts (two adjacent-strip halves at stride 1; up to three
#: interleaved half-strips — 4 same-parity pixels each — at stride 2; up
#: to five quarter-strips — 2 same-residue pixels each — at stride 4, the
#: AlexNet conv1 case).  The plan math is stride-generic; this tuple is
#: the *validated* set (each member carries a bitwise strip == per-tap
#: test suite), not a structural limit.
STRIP_STRIDES = (1, 2, 4)


def strip_parts(stride: int) -> int:
    """Worst-case straddle parts per tap at ``stride``.

    Output row i of a strip reads input pixel ``stride*i + s`` (s the tap
    x-offset), so the 8 sources span ``7*stride + 1`` pixels and touch at
    most ``(7*stride + STRIP_W - 1)//STRIP_W + 1`` input strips.  Equals
    ``stride + 1`` for every stride in STRIP_STRIDES.
    """
    return ((STRIP_W - 1) * stride + STRIP_W - 1) // STRIP_W + 1


def strip_shift_live(shift: int, stride: int) -> bool:
    """True iff the affine row map ``out row i <- src row stride*i + shift``
    sources at least one row in [0, STRIP_W).  Depends only on (shift,
    stride) — never on the output strip — which is what makes dead
    straddle parts *columns* of the plan, droppable at plan time."""
    return any(0 <= stride * i + shift < STRIP_W for i in range(STRIP_W))


def strip_subtap_counts(k: int, padding: int, stride: int) -> tuple[int, int]:
    """(compacted, worst-case) subtap column counts of a strip conv plan.

    ``worst = strip_parts(stride) * k * k`` is the uncompacted grid the
    pre-compaction kernels launched; ``compacted`` keeps only parts whose
    affine row map sources a row (``strip_shift_live``).  Pure arithmetic
    twin of :func:`strip_tap_map`'s column enumeration — engine traces and
    benches report both without building a plan.
    """
    parts = strip_parts(stride)
    live = 0
    for dx in range(k):
        r = (dx - padding) % STRIP_W
        live += sum(strip_shift_live(r - j * STRIP_W, stride)
                    for j in range(parts))
    return live * k, parts * k * k


def strip_ineligible_reason(width: int, k: int, stride: int, padding: int,
                            co: int | None = None) -> str | None:
    """Why a conv layer cannot consume a strip-aligned stream (None = it can).

    Strip tiling (blk_m == STRIP_W) needs every tap's strided slice to be
    an affine row remap of at most ``strip_parts(stride)`` straddle parts:
    stride in STRIP_STRIDES (output pixel x maps to input pixel
    stride*x + dx - p, so the 8 sources of one output strip interleave
    with step ``stride``), input and output widths tiling into whole
    strips, padding at most k // 2 (so output strips never outnumber the
    input strips the straddle plan pairs them with), and tap x-offsets
    within one strip of the origin.  When the output-channel count ``co``
    is known it must be a multiple of STRIP_CO_MIN (see its note) so
    strip == per-tap stays bitwise.

    Every message is derived from STRIP_STRIDES / STRIP_W / STRIP_CO_MIN —
    never a hardcoded stride set — so extending STRIP_STRIDES can't ship a
    stale error message (``test_strip_ineligible_reason_message_table``
    pins the rendered strings against the same constants).
    """
    if stride not in STRIP_STRIDES:
        return (f"stride {stride} not in {set(STRIP_STRIDES)} (strip plans "
                f"gather up to (7*stride + 7)//8 + 1 interleaved straddle "
                f"parts per tap; only these strides are validated bitwise)")
    out_w = (width + 2 * padding - k) // stride + 1
    if width <= 0 or width % STRIP_W:
        return f"input width {width} not a multiple of STRIP_W={STRIP_W}"
    if out_w <= 0 or out_w % STRIP_W:
        return (f"output width {out_w} ((W + 2p - k)//stride + 1) not a "
                f"multiple of STRIP_W={STRIP_W}")
    if padding > k // 2:
        return (f"padding {padding} > k//2 = {k // 2}: the output map "
                f"outgrows the input and a tap shift can index outside the "
                f"planned straddle parts (strip plans pair each output "
                f"strip with its aligned input strips)")
    if padding > STRIP_W or k - 1 - padding > STRIP_W:
        return (f"tap x-offsets [-{padding}, {k - 1 - padding}] leave the "
                f"adjacent-strip window (|dx - p| <= {STRIP_W})")
    if co is not None and (co < STRIP_CO_MIN or co % STRIP_CO_MIN):
        return (f"output channels {co} not a multiple of "
                f"STRIP_CO_MIN={STRIP_CO_MIN} (bitwise contract needs an "
                f"M-invariant dot lowering — ragged lane remainders break it)")
    return None


def strip_eligible(width: int, k: int, stride: int, padding: int,
                   co: int | None = None) -> bool:
    """True iff a k x k / stride / padding conv over maps of width ``width``
    (and, when given, ``co`` output channels) can consume a strip-aligned
    (blk_m == STRIP_W) event stream."""
    return strip_ineligible_reason(width, k, stride, padding, co) is None


def strip_tap_map(logical_shape: tuple, k: int, padding: int,
                  stride: int = 1):
    """Static *compacted* subtap gather plan for the fused strip conv
    (DESIGN.md §6).

    For each output strip and each live subtap (tap (dy, dx) split into
    its straddle parts, dead parts dropped — see below), the plan names
    the source strip group and the in-tile affine row map that realize
    the tap's strided slice:

      src   (G_out, T) int32  source strip group (clamped when dead)
      live  (G_out, T) bool   False = no source (zero-padding border)
      shift (T,)       int32  signed row offset d: out row i <- src row
                              stride*i + d
      tap   (T,)       int32  flat filter index dy*k + dx of the subtap

    Output row i of strip (b, oy, sx) reads input pixel
    ``8*stride*sx + stride*i + s`` for tap x-offset s = dx - p: the 8
    sources span ``7*stride + 1`` pixels, i.e. up to
    ``strip_parts(stride)`` input strips, part j contributing the rows
    its affine map ``out row i <- src row stride*i + d`` (d = s%8 - 8j)
    lands inside [0, 8).  At stride 1 that is the familiar two
    adjacent-strip halves; at stride 2, up to three interleaved
    half-strips (4 same-parity pixels each); at stride 4, up to five
    quarter-strips (2 same-residue pixels each — AlexNet conv1).

    **Dead-subtap compaction**: a part whose (d, stride) sources no row
    is dead for *every* output strip (``strip_shift_live`` depends on the
    shift alone), so its column is dropped from the plan instead of
    carried as an always-idle grid step — r == 0 taps lose their second
    half at stride 1, r < 2 taps their third part at stride 2, r < 4
    taps their fifth part at stride 4.  T is therefore the *compacted*
    count ``strip_subtap_counts(k, padding, stride)[0]`` <= worst-case
    ``strip_parts(stride)*k*k``, and consumers size their inner grid by
    the plan they are handed.  Dropping a dead column only removes
    additions of exact zeros from fixed reduction slots, so the
    compacted plan stays bit-identical to the uncompacted one (and to
    the per-tap oracle).

    Subtaps are ordered tap-major (dy, dx ascending — the per-tap
    oracle's loop order), surviving straddle parts left-to-right, so a
    consumer accumulating in plan order reproduces the per-tap reduction
    tree bit-for-bit.  Everything here is shape-derived — plain numpy,
    evaluated at trace time.
    """
    import numpy as np

    b, h, w, _ = logical_shape
    assert stride in STRIP_STRIDES, (stride, "strip_ineligible_reason gates")
    assert w % STRIP_W == 0, (logical_shape, "strip encoding needs W % 8 == 0")
    assert padding <= k // 2, (k, padding, "strip plans pair each output "
                               "strip with its aligned input strips; "
                               "strip_ineligible_reason gates this")
    oh = (h + 2 * padding - k) // stride + 1
    ow = (w + 2 * padding - k) // stride + 1
    assert ow > 0 and ow % STRIP_W == 0, (logical_shape, k, padding, stride)
    nsx_in = w // STRIP_W
    nsx_out = ow // STRIP_W
    g_out = b * oh * nsx_out
    gidx = np.arange(g_out, dtype=np.int64)
    sx = gidx % nsx_out
    oy = (gidx // nsx_out) % oh
    bb = gidx // (nsx_out * oh)
    parts = strip_parts(stride)
    t_n, t_worst = strip_subtap_counts(k, padding, stride)
    src = np.zeros((g_out, t_n), np.int32)
    live = np.zeros((g_out, t_n), bool)
    shift = np.zeros((t_n,), np.int32)
    tap = np.zeros((t_n,), np.int32)
    t = 0
    for dy in range(k):
        for dx in range(k):
            iy = oy * stride + dy - padding
            s = dx - padding                       # tap x-offset
            base = stride * sx + (s // STRIP_W)    # first straddled strip
            r = s % STRIP_W                        # in-strip row offset
            for j in range(parts):
                d = r - j * STRIP_W
                if not strip_shift_live(d, stride):
                    continue                       # dead part: column dropped
                tx = base + j
                ok = (iy >= 0) & (iy < h) & (tx >= 0) & (tx < nsx_in)
                src[:, t] = ((bb * h + np.clip(iy, 0, h - 1)) * nsx_in
                             + np.clip(tx, 0, nsx_in - 1)).astype(np.int32)
                live[:, t] = ok
                shift[t] = d
                tap[t] = dy * k + dx
                t += 1
    assert t == t_n <= t_worst, (t, t_n, t_worst)
    return src, live, shift, tap


def pool_window_map(logical_shape: tuple, k: int, stride: int, blk_m: int):
    """Static window gather plan for the event-native max-pool (DESIGN.md §7).

    Maps each output pixel of a VALID k×k / ``stride`` max-pool over a
    (B, H, W, C) feature map onto the event row groups of the input stream:
    for output pixel p = (b, oy, ox) and window tap t = (dy, dx), the source
    input pixel is q = (b, oy·stride + dy, ox·stride + dx), and the plan
    names where q lives in the encoding:

      src  (P_out, T) int32  row group holding q (q // blk_m — pixel groups
                             at blk_m == 1, 8-pixel raster strips at
                             blk_m == STRIP_W; both tile raster order, which
                             is what makes the decomposition uniform)
      row  (P_out, T) int32  q's row within the group's (blk_m, blk_k) tile
      live (P_out, T) bool   False = no source pixel.  Always True for
                             VALID pooling (the window never leaves the
                             map); carried so a SAME-padded variant reuses
                             the plan shape.

    T = k·k window taps, ordered (dy, dx) ascending — the raster order the
    dense ``reduce_window`` walks.  Max is order-invariant, so consumers
    need no ordering contract; the order only keeps plans deterministic.
    Everything here is shape-derived — plain numpy, evaluated at trace time.
    """
    import numpy as np

    b, h, w, _ = logical_shape
    assert k >= 1 and stride >= 1, (k, stride)
    assert h >= k and w >= k, (logical_shape, k, "VALID window exceeds map")
    if blk_m == STRIP_W:
        assert w % STRIP_W == 0, (logical_shape,
                                  "strip encoding needs W % 8 == 0")
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    p_out = b * oh * ow
    pidx = np.arange(p_out, dtype=np.int64)
    ox = pidx % ow
    oy = (pidx // ow) % oh
    bb = pidx // (ow * oh)
    t_n = k * k
    src = np.zeros((p_out, t_n), np.int32)
    row = np.zeros((p_out, t_n), np.int32)
    live = np.zeros((p_out, t_n), bool)
    t = 0
    for dy in range(k):
        for dx in range(k):
            iy = oy * stride + dy
            ix = ox * stride + dx
            q = (bb * h + iy) * w + ix          # raster flat pixel index
            src[:, t] = (q // blk_m).astype(np.int32)
            row[:, t] = (q % blk_m).astype(np.int32)
            live[:, t] = (iy < h) & (ix < w)    # always true for VALID
            t += 1
    return src, row, live


def pool_window_ineligible_reason(logical_shape: tuple, k: int, stride: int,
                                  blk_m: int) -> str | None:
    """Why the *window-major* strip pool cannot consume this stream
    (None = it can; the per-event segment max remains the general path).

    The window-major grid walks output strips — 8 consecutive pooled pixels
    of one output row — so it needs a strip-aligned input stream
    (blk_m == STRIP_W, W % 8 == 0) and a pooled width that tiles into whole
    strips (OW % 8 == 0: every grid step's 8 output pixels are real).
    """
    if blk_m != STRIP_W:
        return f"stream not strip-aligned (blk_m={blk_m} != STRIP_W)"
    b, h, w, _ = logical_shape
    if w <= 0 or w % STRIP_W:
        return f"input width {w} not a multiple of STRIP_W={STRIP_W}"
    if h < k or w < k:
        return f"VALID {k}x{k} window exceeds the {h}x{w} map"
    ow = (w - k) // stride + 1
    if ow <= 0 or ow % STRIP_W:
        return (f"pooled width {ow} ((W - k)//stride + 1) not a multiple "
                f"of STRIP_W={STRIP_W}")
    return None


def pool_strip_map(logical_shape: tuple, k: int, stride: int):
    """Window-major gather plan for the strip event pool (DESIGN.md §7).

    Where :func:`pool_window_map` walks output *pixels* (grid P_out · k²·E),
    this plan walks output *strips* — 8 consecutive pooled pixels of one
    output row — so the consumer's grid shrinks 8-fold to
    (B·OH·(OW/8), T, E).  Output row i of strip (b, oy, sx) pools input
    pixel ix = 8·stride·sx + stride·i + dx at window tap (dy, dx); the 8
    strided sources span up to ``parts = (7·stride + k - 1)//8 + 1`` input
    strips, each contributing an interleaved part realized by the same
    affine row remap as the fused conv plan (out row i <- src row
    stride·i + d; rows outside [0, 8) are exact zeros — the max identity):

      src   (G_out, T) int32  source input strip group (clamped when dead)
      live  (G_out, T) bool   False = part sources no row (masked to 0)
      shift (T,)       int32  signed row offset d = dx - 8·j of part j
      tap   (T,)       int32  flat window index dy·k + dx of the subtap

    T = k·k·parts subtaps, tap-major then parts left-to-right — the same
    ordering discipline as ``strip_tap_map`` (max needs no order contract;
    determinism keeps plans comparable).  Requires a strip-eligible
    geometry (:func:`pool_window_ineligible_reason`); everything here is
    shape-derived — plain numpy, evaluated at trace time.
    """
    import numpy as np

    b, h, w, _ = logical_shape
    reason = pool_window_ineligible_reason(logical_shape, k, stride, STRIP_W)
    assert reason is None, (logical_shape, k, stride, reason)
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    nsx_in = w // STRIP_W
    nsx_out = ow // STRIP_W
    g_out = b * oh * nsx_out
    parts = ((STRIP_W - 1) * stride + k - 1) // STRIP_W + 1
    t_n = k * k * parts
    gidx = np.arange(g_out, dtype=np.int64)
    sx = gidx % nsx_out
    oy = (gidx // nsx_out) % oh
    bb = gidx // (nsx_out * oh)
    src = np.zeros((g_out, t_n), np.int32)
    live = np.zeros((g_out, t_n), bool)
    shift = np.zeros((t_n,), np.int32)
    tap = np.zeros((t_n,), np.int32)
    t = 0
    for dy in range(k):
        for dx in range(k):
            iy = oy * stride + dy              # always in-map (VALID)
            for j in range(parts):
                tx = stride * sx + dx // STRIP_W + j
                d = dx % STRIP_W - j * STRIP_W
                # Part j is live iff its affine map sources at least one of
                # the strip's 8 rows; a live row's input pixel is a real
                # VALID window read, so tx is automatically in-map.
                ok = any(0 <= stride * i + d < STRIP_W
                         for i in range(STRIP_W))
                src[:, t] = ((bb * h + iy) * nsx_in
                             + np.clip(tx, 0, nsx_in - 1)).astype(np.int32)
                live[:, t] = ok & (tx >= 0) & (tx < nsx_in)
                shift[t] = d
                tap[t] = dy * k + dx
                t += 1
    return src, live, shift, tap


def retile_ineligible_reason(logical_shape: tuple | None, blk_m: int,
                             blk_k: int) -> str | None:
    """Why a conv stream cannot re-tile to the FC view (None = it can).

    The conv→FC re-tile maps a (B·H·W, C)-tiled stream onto the flattened
    (B, H·W·C) view by address arithmetic alone (DESIGN.md §12): FC K-block
    ``pix·nkb + j`` is conv tile ``j`` of raster pixel ``pix``, which only
    works when every conv K-block lands intact inside the flattened row.
    That needs a conv stream (NHWC logical shape), a channel depth that
    tiles into whole K-blocks (C % blk_k == 0 — otherwise the conv
    encoding's K-padding columns would interleave into the middle of the
    FC row), and pixel- or strip-granular rows (blk_m in {1, STRIP_W} —
    the two granularities fire emits; a strip splits into 8 per-pixel
    events before re-tiling).

    Messages are derived from STRIP_W and the offending shape — never
    hardcoded — and are pinned verbatim by
    ``test_retile_ineligible_reason_message_table``.
    """
    if logical_shape is None or len(logical_shape) != 4:
        return ("stream has no NHWC logical shape (not a conv stream; "
                "nothing to re-tile)")
    c = logical_shape[-1]
    if c % blk_k:
        return (f"channel depth {c} not a multiple of blk_k={blk_k} (the "
                f"conv encoding's K-padding columns would interleave into "
                f"the flattened FC row)")
    if blk_m not in (1, STRIP_W):
        return (f"row granularity blk_m={blk_m} is neither pixel (1) nor "
                f"strip (STRIP_W={STRIP_W})")
    return None


def retile_fc_addr_offsets(logical_shape: tuple, num_k_blocks: int,
                           capacity: int):
    """Static address plan for the conv→FC re-tile (DESIGN.md §12).

    For a pixel-granular (blk_m == 1) conv stream over (B, H, W, C) with
    ``num_k_blocks`` K-blocks per pixel and ``capacity`` event slots per
    row group, the flattened (B, H·W·C) view puts conv tile ``j`` of
    raster pixel ``pix`` at FC K-block ``pix·num_k_blocks + j``.  Slots of
    one batch row are laid out pixel-major (all slots of pixel 0, then
    pixel 1, ...), so the per-slot address offset is a pure function of
    the slot position:

      off (H·W·capacity,) int32   off[s] = (s // capacity) · num_k_blocks

    The re-tiled address of slot s is ``off[s] + block_idx[pix, slot]`` —
    a static offset add, no decode.  Everything here is shape-derived —
    plain numpy, evaluated at trace time (the ``strip_tap_map`` idiom).
    """
    import numpy as np

    _, h, w, _ = logical_shape
    slots = h * w * capacity
    off = (np.arange(slots, dtype=np.int64) // capacity) * num_k_blocks
    return off.astype(np.int32)


def retile_block_events(bev: BlockEvents, logical_shape: tuple,
                        blk_m: int) -> BlockEvents:
    """Re-tile a (B·H·W, C) conv block stream to the (B, H·W·C) FC view.

    Exactness contract (pinned by tests/test_retile.py): for a stream
    produced by ``encode_block_events`` at threshold 0 (every live tile
    holds a non-zero and block addresses are unique per group),

        retile_block_events(bev, (B, H, W, C), blk_m)
          == encode_block_events(decoded.reshape(B, H*W*C), blk_m=1,
                                 blk_k=bk, capacity=H*W*E, threshold=0.0)

    array for array (values, block_idx, counts) — where ``decoded`` is the
    dense (B·H·W, C) twin and E the input capacity.  With the lossless
    default capacity (E == num_k_blocks) the re-tiled capacity H·W·E is
    exactly the FC view's block count, i.e. the lossless default again.

    The pipeline is the encode pipeline run over pre-compacted slots:
    strip tiles first split into 8 per-pixel events (a pure transpose —
    rows move, values don't), per-slot FC addresses come from the static
    :func:`retile_fc_addr_offsets` plan, live slots (in-count and holding
    a non-zero) compact live-first by stable argsort — pixel-major slot
    order with ascending per-group addresses means ascending FC addresses,
    encode's raster event order — and padding repeats the last live
    address with zeroed values, exactly as encode pads.  Values move by
    gather only (any dtype, int8 included); no FP arithmetic touches them.
    """
    b, h, w, c = logical_shape
    g, e, bm, bk = bev.values.shape
    reason = retile_ineligible_reason(logical_shape, blk_m, bk)
    assert reason is None, reason
    assert bm == blk_m and g * blk_m == b * h * w, (bev.values.shape,
                                                   logical_shape, blk_m)
    nkb = bev.num_k_blocks
    vals, idx, counts = bev.values, bev.block_idx, bev.counts
    if blk_m != 1:          # split strips into per-pixel events: rows move,
        vals = vals.transpose(0, 2, 1, 3).reshape(g * bm, e, 1, bk)
        idx = jnp.repeat(idx, bm, axis=0)          # values don't.
        counts = jnp.repeat(counts, bm)
    slots = h * w * e
    off = jnp.asarray(retile_fc_addr_offsets(logical_shape, nkb, e))
    addr = idx.reshape(b, slots) + off[None, :]
    in_count = (jnp.arange(e, dtype=jnp.int32)[None, :]
                < counts[:, None]).reshape(b, slots)
    live = in_count & jnp.any(vals.reshape(b, slots, bk) != 0, axis=-1)
    order = jnp.argsort(jnp.logical_not(live), axis=-1, stable=True)
    addr = jnp.take_along_axis(addr, order, axis=1)
    live = jnp.take_along_axis(live, order, axis=1)
    vals = jnp.take_along_axis(vals.reshape(b, slots, 1, bk),
                               order[:, :, None, None], axis=1)
    counts_fc = jnp.sum(live, axis=-1, dtype=jnp.int32)
    last_live = jnp.maximum(counts_fc - 1, 0)
    gathered_last = jnp.take_along_axis(addr, last_live[:, None], axis=1)
    addr = jnp.where(live, addr, gathered_last).astype(jnp.int32)
    vals = jnp.where(live[:, :, None, None], vals, 0)
    return BlockEvents(values=vals, block_idx=addr, counts=counts_fc,
                       num_k_blocks=h * w * nkb)


def decode_block_events(ev: BlockEvents, *, blk_m: int, blk_k: int,
                        m: int, k: int) -> jax.Array:
    """Inverse of :func:`encode_block_events` (up to thresholded-away values).

    Scatter the event tiles back into a dense (M, K) matrix.  Property-tested:
    decode(encode(x)) == x whenever threshold == 0.
    """
    g, e = ev.block_idx.shape
    nkb = ev.num_k_blocks
    assert m == g * blk_m and k == nkb * blk_k
    dense = jnp.zeros((g, nkb, blk_m, blk_k), ev.values.dtype)
    slot_live = jnp.arange(e, dtype=jnp.int32)[None, :] < ev.counts[:, None]
    vals = jnp.where(slot_live[:, :, None, None], ev.values, 0)
    garr = jnp.arange(g, dtype=jnp.int32)[:, None].repeat(e, axis=1)
    dense = dense.at[garr.reshape(-1), ev.block_idx.reshape(-1)].add(
        vals.reshape(g * e, blk_m, blk_k))
    return dense.transpose(0, 2, 1, 3).reshape(m, k)
