"""Event-driven convolution — paper Algorithm 1 + fire phase.

Paths (numerically identical; property-tested against the lax.conv oracle):

  * ``dense_conv2d``        — oracle (lax.conv_general_dilated), NHWC/HWIO.
  * ``scalar_event_conv2d`` — faithful Algorithm 1: each non-zero input pixel
    fires an event carrying (value, channel id, start_weight_addr,
    start_neuron_addr, x_jump, y_jump); the PE walks the filter over the
    event's receptive outputs, decrementing the weight address by ``stride``
    and incrementing the neuron address — direct address arithmetic, no
    CSR/COO decode.  Used for semantics tests + event accounting.
  * ``tap_event_conv2d``    — TPU-native: convolution as k·k shifted
    channel-matmuls, each executed with the block-event multiply phase
    (compacted activation tiles × weight tiles).  This is how the MNF
    dataflow rides the MXU.

Event parameter derivation (paper §4.1.1): for input pixel (iy, ix), stride s,
padding p, k×k filter and OY×OX output map, the touched outputs are
oy ∈ [max(0, ceil((iy+p-k+1)/s)), min(OY-1, floor((iy+p)/s))] (same for ox);
``start_weight`` is the flat filter index at the *first* touched output (the
largest filter offset), and each step of the walk decrements it by ``stride``
exactly as in Algorithm 1.  (The paper's worked example uses an accumulator
row pitch of 4 on a 2×2 OFM; we use the mathematically consistent pitch = OX.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.fire import FireConfig, fire
from repro.core.mnf_linear import block_event_linear

__all__ = ["dense_conv2d", "conv_out_size", "event_params_for_pixel",
           "scalar_event_conv2d", "tap_event_conv2d", "mnf_conv2d"]


def conv_out_size(in_size: int, k: int, stride: int, padding: int) -> int:
    return (in_size + 2 * padding - k) // stride + 1


def dense_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                 padding: int = 0, b: jax.Array | None = None) -> jax.Array:
    """Oracle conv.  x: (B, H, W, CI), w: (KH, KW, CI, CO)."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        y = y + b
    return y


def event_params_for_pixel(iy, ix, *, k: int, stride: int, padding: int,
                           oy_size: int, ox_size: int):
    """Paper §4.1.1 event fields for one input pixel (traced-value safe).

    Returns (start_weight, start_neuron, x_jump, y_jump, oy0, ox0, dy0, dx0).
    jumps are the paper's step counts (number of moves, inclusive walk is
    jump+1 positions); an all-clipped pixel yields negative jumps (no work).
    """
    iy = jnp.asarray(iy, jnp.int32)
    ix = jnp.asarray(ix, jnp.int32)
    oy0 = jnp.maximum(0, -(-(iy + padding - k + 1) // stride))
    oy1 = jnp.minimum(oy_size - 1, (iy + padding) // stride)
    ox0 = jnp.maximum(0, -(-(ix + padding - k + 1) // stride))
    ox1 = jnp.minimum(ox_size - 1, (ix + padding) // stride)
    y_jump = oy1 - oy0
    x_jump = ox1 - ox0
    dy0 = iy + padding - oy0 * stride    # largest filter row offset touched
    dx0 = ix + padding - ox0 * stride
    start_weight = dy0 * k + dx0
    start_neuron = oy0 * ox_size + ox0
    return start_weight, start_neuron, x_jump, y_jump, oy0, ox0, dy0, dx0


def scalar_event_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                        padding: int = 0) -> jax.Array:
    """Faithful Algorithm 1, single image.  x: (H, W, CI), w: (KH, KW, CI, CO).

    fori_loop over the padded event list; the inner filter walk is a fixed
    k×k loop with liveness masks (TPU/jit needs static bounds; clipped walk
    positions are masked, mirroring the jump-bounded walk of the paper).
    """
    h, wd, ci = x.shape
    kh, kw, ci2, co = w.shape
    assert kh == kw and ci == ci2, "square filters, matching channels"
    k, s, p = kh, stride, padding
    oy_size = conv_out_size(h, k, s, p)
    ox_size = conv_out_size(wd, k, s, p)

    evs = ev.encode_scalar_events(x)          # flat over (H, W, CI)
    acc0 = jnp.zeros((oy_size * ox_size, co),
                     jnp.promote_types(x.dtype, w.dtype))
    wflat = w.reshape(k * k, ci, co)

    def body(i, acc):
        value = evs.values[i]
        flat = evs.indices[i]
        ch = flat % ci
        ixx = (flat // ci) % wd
        iyy = flat // (ci * wd)
        (start_w, start_n, x_jump, y_jump, oy0, ox0, dy0, dx0) = \
            event_params_for_pixel(iyy, ixx, k=k, stride=s, padding=p,
                                   oy_size=oy_size, ox_size=ox_size)

        def walk_y(yy, acc):
            # Algorithm 1 row re-bases: weight -= nc_filter*(y+1)*stride,
            # neuron += nc_output*(y+1), expressed directly per row here.
            w_row = start_w - k * yy * s
            n_row = start_n + ox_size * yy

            def walk_x(xx, acc):
                waddr = w_row - xx * s          # weight_addr -= stride
                naddr = n_row + xx              # neuron_addr += 1
                live = (yy <= y_jump) & (xx <= x_jump)
                contrib = jnp.where(live, value, 0) * wflat[waddr % (k * k), ch]
                return acc.at[naddr % (oy_size * ox_size)].add(contrib)

            return jax.lax.fori_loop(0, k, walk_x, acc)

        return jax.lax.fori_loop(0, k, walk_y, acc)

    acc = jax.lax.fori_loop(0, evs.capacity, body, acc0)
    return acc.reshape(oy_size, ox_size, co)


def tap_event_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                     padding: int = 0, blk_m: int = 8, blk_k: int = 8,
                     capacity: int | None = None,
                     threshold: float = 0.0,
                     matmul=None) -> jax.Array:
    """TPU-native event conv: Σ_{dy,dx} shift(x) @ W[dy,dx] via block events.

    x: (B, H, W, CI), w: (K, K, CI, CO).  Each tap's (B·OY·OX, CI) activation
    matrix goes through the block-event multiply phase; spatial+channel
    sparsity both shrink the event list.

    ``matmul(a, w_tap)`` overrides the per-tap multiply (the engine's pallas
    conv backend injects the event_matmul kernel here; default is the
    pure-jnp block-event path).
    """
    bsz, h, wd, ci = x.shape
    k = w.shape[0]
    s, p = stride, padding
    oy, ox = conv_out_size(h, k, s, p), conv_out_size(wd, k, s, p)
    if matmul is None:
        matmul = partial(block_event_linear, blk_m=blk_m, blk_k=blk_k,
                         capacity=capacity, threshold=threshold)
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
    acc = jnp.zeros((bsz * oy * ox, w.shape[-1]),
                    jnp.promote_types(x.dtype, w.dtype))
    for dy in range(k):
        for dx in range(k):
            xs = jax.lax.slice(xp, (0, dy, dx, 0),
                               (bsz, dy + (oy - 1) * s + 1,
                                dx + (ox - 1) * s + 1, ci),
                               (1, s, s, 1))          # (B, OY, OX, CI)
            a = xs.reshape(bsz * oy * ox, ci)
            acc = acc + matmul(a, w[dy, dx])
    return acc.reshape(bsz, oy, ox, -1)


def mnf_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
               padding: int = 0, fire_cfg: FireConfig = FireConfig(),
               blk_m: int = 8, blk_k: int = 8) -> jax.Array:
    """Full MNF conv layer: engine multiply phase + fire phase.

    Deprecation shim — new code should call ``repro.engine.conv2d`` with an
    :class:`~repro.engine.EngineConfig`.
    """
    from repro import engine
    cfg = engine.EngineConfig(backend="block", blk_m=blk_m, blk_k=blk_k)
    acc = engine.conv2d(x, w, cfg=cfg, stride=stride, padding=padding)
    return fire(acc, fire_cfg)
