"""repro.core — the paper's contribution: Multiply-and-Fire event-driven
sparse computation (event encoding, multiply phase, fire phase, PE mapping).
"""
from repro.core.events import (BlockEvents, ScalarEvents, block_occupancy,
                               count_nonzero_events, decode_block_events,
                               encode_block_events, encode_scalar_events,
                               pad_to_block_multiple)
from repro.core.fire import FireConfig, fire, fire_stats, fire_to_block_events
from repro.core.mapping import (PAPER_PE, LayerMapping, PECapacity, conv_pes,
                                fc_pes, noc_grid, plan_conv_layer,
                                plan_fc_layer)
from repro.core.mnf_conv import (conv_out_size, dense_conv2d, mnf_conv2d,
                                 scalar_event_conv2d, tap_event_conv2d)
from repro.core.mnf_linear import (block_event_linear,
                                   block_event_linear_from_events,
                                   dense_linear, mnf_linear,
                                   scalar_event_linear)
from repro.core.quantize import (QParams, calibrate, dequantize, fake_quant,
                                 quantize, requantize_accumulator)

__all__ = [
    "BlockEvents", "ScalarEvents", "block_occupancy", "count_nonzero_events",
    "decode_block_events", "encode_block_events", "encode_scalar_events",
    "pad_to_block_multiple",
    "FireConfig", "fire", "fire_stats", "fire_to_block_events",
    "PAPER_PE", "LayerMapping", "PECapacity", "conv_pes", "fc_pes",
    "noc_grid", "plan_conv_layer", "plan_fc_layer",
    "conv_out_size", "dense_conv2d", "mnf_conv2d", "scalar_event_conv2d",
    "tap_event_conv2d",
    "block_event_linear", "block_event_linear_from_events", "dense_linear",
    "mnf_linear", "scalar_event_linear",
    "QParams", "calibrate", "dequantize", "fake_quant", "quantize",
    "requantize_accumulator",
]
