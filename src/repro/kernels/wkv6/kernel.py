"""Pallas TPU kernel for the RWKV6 WKV recurrence, chunked.

Grid (B, T // C) with the chunk dimension innermost-sequential: the (D, D)
WKV state lives in a VMEM scratch accumulator across the whole sequence of
one batch row — it is never round-tripped to HBM between chunks (the
accumulate-SRAM discipline of the MNF PE, applied to a recurrent state).
Inside a chunk the exact per-token recurrence runs in a fori_loop; all math
in f32.

HBM traffic: r/k/v/w are streamed chunk-by-chunk (double-buffered by Mosaic),
o is streamed out, the state is written once at the end.  That makes the
kernel memory-roofline-optimal for decode/long-context shapes where
T·D ≫ D².
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6_kernel", "wkv6_pallas"]


def wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                o_ref, sfin_ref, s_acc, *, chunk: int):
    t = pl.program_id(1)
    num_t = pl.num_programs(1)

    @pl.when(t == 0)
    def _init():
        s_acc[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[...].astype(jnp.float32)             # (1, D)

    def step(i, _):
        rt = r_ref[0, i, :].astype(jnp.float32)[None, :]   # (1, D)
        kt = k_ref[0, i, :].astype(jnp.float32)[None, :]
        vt = v_ref[0, i, :].astype(jnp.float32)[None, :]
        wt = w_ref[0, i, :].astype(jnp.float32)[None, :]
        s = s_acc[...]                                     # (D, D)
        att = jnp.sum(rt * u * kt)                         # scalar
        o = att * vt + jnp.dot(rt, s,
                               preferred_element_type=jnp.float32)  # (1, D)
        o_ref[0, i, :] = o[0].astype(o_ref.dtype)
        s_acc[...] = wt.T * s + kt.T * vt                  # diag(w)S + k v^T
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(t == num_t - 1)
    def _flush():
        sfin_ref[0] = s_acc[...].astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, s0: jax.Array, *, chunk: int = 64,
                interpret: bool = False):
    """r,k,v,w: (B, T, D); u: (D,); s0: (B, D, D) -> (o, s_final)."""
    b, t, d = r.shape
    assert t % chunk == 0, (t, chunk)
    grid = (b, t // chunk)
    u2 = u.reshape(1, d)

    rkvw_spec = pl.BlockSpec((1, chunk, d), lambda bi, ti: (bi, ti, 0))
    state_spec = pl.BlockSpec((1, d, d), lambda bi, ti: (bi, 0, 0))
    o, sfin = pl.pallas_call(
        functools.partial(wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[rkvw_spec, rkvw_spec, rkvw_spec, rkvw_spec,
                  pl.BlockSpec((1, d), lambda bi, ti: (0, 0)),
                  state_spec],
        out_specs=[rkvw_spec, state_spec],
        out_shape=[jax.ShapeDtypeStruct((b, t, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, d, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
        name="wkv6_chunked",
    )(r, k, v, w, u2, s0)
    return o, sfin
