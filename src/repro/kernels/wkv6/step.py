"""Single-token WKV6 decode step — dense oracle + event-gated variants.

The decode recurrence per flattened row g = (batch, head):

    o  = (Σ_d r_d u_d k_d) v + r S          (att bonus + state readout)
    S' = diag(w) S + k v^T                  (decay + rank-1 increment)

The state *increment* is driven entirely by the key vector k: a channel d
with k_d == 0 contributes nothing to S' beyond the decay, and nothing to
the att bonus.  The event-gated step (DESIGN.md §13) therefore consumes a
signed-fired EventStream of k — dead K-blocks of the state update are
skipped per ``live_block_mask`` — while the decay applies to every block
(it is input-independent and cannot be gated).

``wkv6_step_ref`` is the dense oracle (models/ssm.wkv6_step delegates to
it); ``wkv6_step_events_ref`` is the jnp twin consuming compacted events;
``wkv6_step_events_pallas`` is the kernel.  Reductions in all three use the
same formulation (elementwise product + jnp.sum over the contracted axis)
so the threshold-0 contract — gated step float-equal to the dense step —
holds bit for bit on both backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import events as ev

__all__ = ["wkv6_step_ref", "wkv6_step_events_ref",
           "wkv6_step_events_pallas", "drive_from_events"]


def wkv6_step_ref(r, k, v, w, u, s):
    """Dense single-token step, rows flattened.  r,k,v,w,u: (G, D);
    s: (G, D, D).  All math f32.  Returns (o (G, D), s_new (G, D, D))."""
    f32 = jnp.float32
    r, k, v, w, u = (x.astype(f32) for x in (r, k, v, w, u))
    s = s.astype(f32)
    att = jnp.sum(r * u * k, axis=-1)                        # (G,)
    o = att[:, None] * v + jnp.sum(r[:, :, None] * s, axis=1)
    s_new = w[..., None] * s + k[..., None] * v[:, None, :]
    return o, s_new


def drive_from_events(bev: ev.BlockEvents, *, blk_k: int, m: int,
                      k: int) -> jax.Array:
    """Reassemble the fired (M, K) drive from compacted blk_m == 1 events.

    This is event *consumption*, not a stream decode: the block backends
    below run the same step math the dense oracle runs, just on the drive
    carried by the events (zeros where no event fired) — the jnp image of
    what the Pallas kernel's VMEM scatter does.
    """
    g = bev.block_idx.shape[0]
    full = ev.decode_block_events(bev, blk_m=1, blk_k=blk_k, m=g,
                                  k=bev.num_k_blocks * blk_k)
    return full[:m, :k]


def wkv6_step_events_ref(bev: ev.BlockEvents, r, v, w, u, s, *, blk_k: int):
    """jnp twin of the event-gated step: same math as ``wkv6_step_ref`` on
    the event-carried key drive."""
    k_used = drive_from_events(bev, blk_k=blk_k, m=r.shape[0], k=r.shape[1])
    return wkv6_step_ref(r, k_used, v, w, u, s)


def wkv6_step_kernel(idx_ref, counts_ref, live_ref,       # scalar prefetch
                     vals_ref, r_ref, v_ref, w_ref, u_ref, s_ref,
                     o_ref, snew_ref, kbuf, *, blk_k: int, nkb: int, d: int):
    """One grid step per row g.  The fired key drive is scattered from the
    compacted event slots into a VMEM scratch row (stores guarded by
    ``e < count`` — padding slots repeat the last live index and would
    clobber it); the output reductions run over exactly the logical D
    channels (single tree, matching the dense step's bits); the state
    update walks K-blocks and skips dead ones via the precomputed live
    mask — the decay still applies everywhere."""
    g = pl.program_id(0)
    e_cap = vals_ref.shape[1]
    kbuf[...] = jnp.zeros_like(kbuf)
    cnt = counts_ref[g]

    def slot(e, _):
        j = idx_ref[g, e]

        @pl.when(e < cnt)
        def _store():
            kbuf[0, pl.ds(j * blk_k, blk_k)] = vals_ref[0, e, 0, :]
        return 0

    jax.lax.fori_loop(0, e_cap, slot, 0)

    f32 = jnp.float32
    r = r_ref[...].astype(f32)                               # (1, Dp)
    v = v_ref[...].astype(f32)
    w = w_ref[...].astype(f32)
    u = u_ref[...].astype(f32)
    kk = kbuf[...]                                           # (1, Dp)
    s = s_ref[0].astype(f32)                                 # (Dp, Dp)

    # Output: reduce over the logical D channels only (static slices) so
    # the reduction tree matches the dense step even when Dp > D.
    rd, ud, kd, vd = r[:, :d], u[:, :d], kk[:, :d], v[:, :d]
    att = jnp.sum(rd * ud * kd, axis=-1, keepdims=True)      # (1, 1)
    o = att * vd + jnp.sum(rd[0][:, None] * s[:d, :d], axis=0,
                           keepdims=True)                    # (1, D)
    o_ref[...] = jnp.pad(o, ((0, 0), (0, r.shape[1] - d))).astype(o_ref.dtype)

    # State: per-block decay always; the rank-1 increment only where the
    # block carries events (elementwise — padding rows/cols are zeros and
    # get sliced off by the wrapper).
    for j in range(nkb):
        sl = slice(j * blk_k, (j + 1) * blk_k)
        dec = w[0, sl][:, None] * s[sl, :]                   # (blk_k, Dp)

        @pl.when(live_ref[g, j] > 0)
        def _upd(sl=sl, dec=dec):
            snew_ref[0, sl, :] = (dec + kbuf[0, sl][:, None] * v).astype(
                snew_ref.dtype)

        @pl.when(live_ref[g, j] == 0)
        def _decay(sl=sl, dec=dec):
            snew_ref[0, sl, :] = dec.astype(snew_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("blk_k", "interpret"))
def _wkv6_step_events_call(values, block_idx, counts, live, r, v, w, u, s,
                           *, blk_k: int, interpret: bool):
    g, dp = r.shape
    nkb = dp // blk_k
    d = int(s.shape[-1])  # logical D rides in via the unpadded state width
    row = pl.BlockSpec((1, dp), lambda gi, idx, cnt, lv: (gi, 0))
    sp = jnp.pad(s.astype(jnp.float32),
                 ((0, 0), (0, dp - d), (0, dp - d)))
    state = pl.BlockSpec((1, dp, dp), lambda gi, idx, cnt, lv: (gi, 0, 0))
    e_cap = values.shape[1]
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, e_cap, 1, blk_k),
                               lambda gi, idx, cnt, lv: (gi, 0, 0, 0)),
                  row, row, row, row, state],
        out_specs=[row, state],
        scratch_shapes=[pltpu.VMEM((1, dp), jnp.float32)],
    )
    o, snew = pl.pallas_call(
        functools.partial(wkv6_step_kernel, blk_k=blk_k, nkb=nkb, d=d),
        grid_spec=spec,
        out_shape=[jax.ShapeDtypeStruct((g, dp), jnp.float32),
                   jax.ShapeDtypeStruct((g, dp, dp), jnp.float32)],
        interpret=interpret,
        name="wkv6_step_events",
    )(block_idx, counts, live, values, r, v, w, u, sp)
    return o[:, :d], snew[:, :d, :d]


def wkv6_step_events_pallas(bev: ev.BlockEvents, r, v, w, u, s, *,
                            blk_k: int, interpret: bool = False):
    """Event-gated decode step kernel.  bev: blk_m == 1 events of the fired
    key drive (G, D); r,v,w,u: (G, D); s: (G, D, D).  Returns (o, s_new),
    float-equal to ``wkv6_step_ref`` on the same drive."""
    g, d = r.shape
    nkb = bev.num_k_blocks
    dp = nkb * blk_k
    assert dp >= d and g == bev.block_idx.shape[0], (r.shape, nkb, blk_k)
    pad = lambda x: jnp.pad(x.astype(jnp.float32), ((0, 0), (0, dp - d)))
    live = ev.live_block_mask(bev).astype(jnp.int32)
    return _wkv6_step_events_call(
        bev.values, bev.block_idx, bev.counts, live,
        pad(r), pad(v), pad(w), pad(u), s.astype(jnp.float32),
        blk_k=blk_k, interpret=interpret)
