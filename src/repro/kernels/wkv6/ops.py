"""Jit'd wrapper for the WKV6 kernel: multi-head batching + chunk padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.wkv6.kernel import wkv6_kernel

__all__ = ["wkv6"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, s0: jax.Array | None = None, *, chunk: int = 64,
         interpret: bool = False):
    """Multi-head WKV6.  r,k,v,w: (B, H, T, D); u: (H, D); s0: (B, H, D, D).

    Returns (o (B, H, T, D) f32, s_final (B, H, D, D) f32).  (B, H) flattens
    into the batch grid dimension; each head's bonus ``u`` row is selected by
    the BlockSpec index map (bh mod H).
    """
    b, h, t, d = r.shape
    pad = (-t) % chunk
    if pad:
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r, k, v = zpad(r), zpad(k), zpad(v)
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)),
                    constant_values=1.0)  # identity decay in padding
    tp = t + pad
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)

    flat = lambda x: x.reshape(b * h, tp, d)
    rkvw_spec = pl.BlockSpec((1, chunk, d), lambda bh, ti: (bh, ti, 0))
    state_spec = pl.BlockSpec((1, d, d), lambda bh, ti: (bh, 0, 0))
    u_spec = pl.BlockSpec((1, d), lambda bh, ti: (bh % h, 0))

    o, sfin = pl.pallas_call(
        functools.partial(wkv6_kernel, chunk=chunk),
        grid=(b * h, tp // chunk),
        in_specs=[rkvw_spec, rkvw_spec, rkvw_spec, rkvw_spec, u_spec,
                  state_spec],
        out_specs=[rkvw_spec, state_spec],
        out_shape=[jax.ShapeDtypeStruct((b * h, tp, d), jnp.float32),
                   jax.ShapeDtypeStruct((b * h, d, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
        name="wkv6_chunked",
    )(flat(r), flat(k), flat(v), flat(w), u, s0.reshape(b * h, d, d))
    return (o.reshape(b, h, tp, d)[:, :, :t],
            sfin.reshape(b, h, d, d))
