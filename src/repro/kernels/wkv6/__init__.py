from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref
from repro.kernels.wkv6.step import (drive_from_events, wkv6_step_events_ref,
                                     wkv6_step_events_pallas, wkv6_step_ref)

__all__ = ["wkv6", "wkv6_ref", "wkv6_step_ref", "wkv6_step_events_ref",
           "wkv6_step_events_pallas", "drive_from_events"]
