"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence.

Per head with head dim D (keys) × D (values):

    o_t     = r_t^T (diag(u) k_t v_t^T + S_t)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T,      S_0 given (default 0)

with data-dependent per-channel decay w_t ∈ (0, 1).  This is the exact
sequential recurrence (lax.scan); the Pallas kernel must match it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv6_ref"]


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, s0: jax.Array | None = None):
    """r,k,v,w: (B, T, D); u: (D,); s0: (B, D, D) or None.

    Returns (o (B, T, D), s_final (B, D, D)).  f32 math.
    """
    b, t, d = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, d, d), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                       # (B, D) each
        # o_t[j] = sum_d r_d (u_d k_d v_j + S[d, j])
        att = jnp.einsum("bd,bd->b", rt, u[None, :] * kt)       # scalar/b
        o = att[:, None] * vt + jnp.einsum("bd,bdj->bj", rt, s)
        s = wt[:, :, None] * s + kt[:, :, None] * vt[:, None, :]
        return s, o

    xs = (r.astype(jnp.float32).swapaxes(0, 1),
          k.astype(jnp.float32).swapaxes(0, 1),
          v.astype(jnp.float32).swapaxes(0, 1),
          w.astype(jnp.float32).swapaxes(0, 1))
    s_final, o = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return o.swapaxes(0, 1), s_final
