"""Jit'd wrapper: pad to tile multiples, run the fused selective scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.kernel import mamba_scan_pallas

__all__ = ["mamba_scan"]


@functools.partial(jax.jit, static_argnames=("d_blk", "chunk", "interpret"))
def mamba_scan(da: jax.Array, dbx: jax.Array, c: jax.Array,
               h0: jax.Array | None = None, *, d_blk: int = 128,
               chunk: int = 64, interpret: bool = False):
    """da, dbx: (B, T, D, N); c: (B, T, N) -> (y (B, T, D), h_fin (B, D, N)).

    Padding: T pads with da=1, dbx=0 (state passes through unchanged — same
    identity-decay convention as the wkv6 wrapper); D pads with zeros.
    """
    b, t, d, n = da.shape
    d_blk = min(d_blk, d)
    chunk = min(chunk, t)
    pt, pd = (-t) % chunk, (-d) % d_blk
    if pt or pd:
        da = jnp.pad(da, ((0, 0), (0, pt), (0, pd), (0, 0)),
                     constant_values=1.0)
        dbx = jnp.pad(dbx, ((0, 0), (0, pt), (0, pd), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pt), (0, 0)))
    if h0 is None:
        h0 = jnp.zeros((b, d + pd, n), jnp.float32)
    elif pd:
        h0 = jnp.pad(h0, ((0, 0), (0, pd), (0, 0)))
    y, hfin = mamba_scan_pallas(da, dbx, c, h0, d_blk=d_blk, chunk=chunk,
                                interpret=interpret)
    return y[:, :t, :d], hfin[:, :d]
