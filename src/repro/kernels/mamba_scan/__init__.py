from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref

__all__ = ["mamba_scan", "mamba_scan_ref"]
