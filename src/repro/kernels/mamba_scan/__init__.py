from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.mamba_scan.step import (mamba_step_events_ref,
                                           mamba_step_events_pallas,
                                           mamba_step_ref)

__all__ = ["mamba_scan", "mamba_scan_ref", "mamba_step_ref",
           "mamba_step_events_ref", "mamba_step_events_pallas"]
