"""Pallas TPU kernel for the Mamba1 selective scan, chunked + fused.

The XLA path of the selective scan is memory-bound: the (B, T, D, N) state
stream round-trips HBM at every elementwise step (measured on
hymba/train_4k: the scan dominates the memory roofline term — EXPERIMENTS.md
§Perf H1).  This kernel keeps the (D_blk, N) state in a VMEM scratch across
the whole sequence and streams da/dbx/c chunk-by-chunk, so HBM traffic
collapses to the input/output streams — the same accumulate-SRAM discipline
as the event_matmul and wkv6 kernels.

Grid: (B, D // D_blk, T // C), chunk innermost-sequential; channels are
independent in Mamba so the D_blk dimension parallelizes freely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba_scan_kernel", "mamba_scan_pallas"]


def mamba_scan_kernel(da_ref, dbx_ref, c_ref, h0_ref,
                      y_ref, hfin_ref, h_acc, *, chunk: int):
    t = pl.program_id(2)
    num_t = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        h_acc[...] = h0_ref[0].astype(jnp.float32)

    def step(i, _):
        da_t = da_ref[0, i].astype(jnp.float32)      # (D_blk, N)
        dbx_t = dbx_ref[0, i].astype(jnp.float32)
        c_t = c_ref[0, i].astype(jnp.float32)        # (1, N)
        h = da_t * h_acc[...] + dbx_t
        h_acc[...] = h
        y_ref[0, i] = jnp.sum(h * c_t, axis=-1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(t == num_t - 1)
    def _flush():
        hfin_ref[0] = h_acc[...].astype(hfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_blk", "chunk", "interpret"))
def mamba_scan_pallas(da: jax.Array, dbx: jax.Array, c: jax.Array,
                      h0: jax.Array, *, d_blk: int = 128, chunk: int = 64,
                      interpret: bool = False):
    """da, dbx: (B, T, D, N); c: (B, T, N); h0: (B, D, N).

    Returns (y (B, T, D) f32, h_final (B, D, N) f32).  D % d_blk == 0 and
    T % chunk == 0 (callers pad; see ops.py).
    """
    b, t, d, n = da.shape
    assert d % d_blk == 0 and t % chunk == 0, (d, d_blk, t, chunk)
    grid = (b, d // d_blk, t // chunk)

    stream = pl.BlockSpec((1, chunk, d_blk, n),
                          lambda bi, di, ti: (bi, ti, di, 0))
    cspec = pl.BlockSpec((1, chunk, n), lambda bi, di, ti: (bi, ti, 0))
    state = pl.BlockSpec((1, d_blk, n), lambda bi, di, ti: (bi, di, 0))
    yspec = pl.BlockSpec((1, chunk, d_blk), lambda bi, di, ti: (bi, ti, di))

    y, hfin = pl.pallas_call(
        functools.partial(mamba_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[stream, stream, cspec, state],
        out_specs=[yspec, state],
        out_shape=[jax.ShapeDtypeStruct((b, t, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, d, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d_blk, n), jnp.float32)],
        interpret=interpret,
        name="mamba_selective_scan",
    )(da, dbx, c, h0)
    return y, hfin
