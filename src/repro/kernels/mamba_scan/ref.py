"""Pure-jnp oracle for the Mamba1 selective-scan recurrence.

    h_t = da_t ⊙ h_{t-1} + dbx_t          (h: (di, n))
    y_t = Σ_n h_t[:, n] · c_t[n] + d ⊙ x_t

with da = exp(dt·A), dbx = (dt·x) ⊗ B — all precomputed by the caller (the
kernel consumes the same precomputed streams, so the oracle is the exact
sequential recurrence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["mamba_scan_ref"]


def mamba_scan_ref(da: jax.Array, dbx: jax.Array, c: jax.Array,
                   h0: jax.Array | None = None):
    """da, dbx: (B, T, D, N); c: (B, T, N); h0: (B, D, N) or None.

    Returns (y (B, T, D) f32, h_final (B, D, N) f32).
    """
    b, t, d, n = da.shape
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)

    def step(h, xs):
        da_t, dbx_t, c_t = xs
        h = da_t * h + dbx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (da.astype(jnp.float32).swapaxes(0, 1),
          dbx.astype(jnp.float32).swapaxes(0, 1),
          c.astype(jnp.float32).swapaxes(0, 1))
    h_fin, y = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return y.swapaxes(0, 1), h_fin
