"""Single-token Mamba decode step — dense oracle + event-gated variants.

The selective-scan recurrence per batch row, one token:

    h' = h ⊙ dA + g ⊗ B          (decay + rank-1 increment, g = Δt · x)
    y  = (h' ⊙ C) · 1_N          (state readout)

The state *increment* is driven entirely by the gate vector g = Δt·silu(x):
a channel d with g_d == 0 contributes nothing to h' beyond the decay.  The
event-gated step (DESIGN.md §13) therefore consumes a signed-fired
EventStream of g — dead channel-blocks of the state update skip the
increment via ``live_block_mask`` — while the decay dA applies to every
block (it is input-independent and cannot be gated).

``mamba_step_ref`` is the dense oracle (models/ssm.mamba_step delegates to
it); ``mamba_step_events_ref`` is the jnp twin consuming compacted events;
``mamba_step_events_pallas`` is the kernel.  All three use the same
elementwise + jnp.sum formulation so the threshold-0 contract — gated step
float-equal to the dense step — holds bit for bit on the block backend
(the Pallas contract is within-backend; see kernels/wkv6/step.py and
DESIGN.md §13).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import events as ev
from repro.kernels.wkv6.step import drive_from_events

__all__ = ["mamba_step_ref", "mamba_step_events_ref",
           "mamba_step_events_pallas"]


def mamba_step_ref(gdrive, da, bmat, cmat, h):
    """Dense single-token step.  gdrive: (B, DI) — the Δt·x increment gate;
    da: (B, DI, N) decay; bmat, cmat: (B, N); h: (B, DI, N).  All math f32.
    Returns (y (B, DI), h_new (B, DI, N))."""
    f32 = jnp.float32
    gdrive, bmat, cmat = (x.astype(f32) for x in (gdrive, bmat, cmat))
    da = da.astype(f32)
    h = h.astype(f32)
    dbx = gdrive[..., None] * bmat[:, None, :]
    h_new = h * da + dbx
    y = jnp.sum(h_new * cmat[:, None, :], axis=-1)
    return y, h_new


def mamba_step_events_ref(bev: ev.BlockEvents, da, bmat, cmat, h, *,
                          blk_k: int):
    """jnp twin of the event-gated step: same math as ``mamba_step_ref`` on
    the event-carried increment gate."""
    g = drive_from_events(bev, blk_k=blk_k, m=da.shape[0], k=da.shape[1])
    return mamba_step_ref(g, da, bmat, cmat, h)


def mamba_step_kernel(idx_ref, counts_ref, live_ref,      # scalar prefetch
                      vals_ref, da_ref, b_ref, c_ref, h_ref,
                      y_ref, hnew_ref, gbuf, *, blk_k: int, nkb: int):
    """One grid step per batch row.  The fired gate is scattered from the
    compacted event slots into a VMEM scratch row (stores guarded by
    ``e < count``); the state update walks DI-blocks and skips the rank-1
    increment on dead ones via the precomputed live mask — the decay (and
    the readout over the surviving state) still runs everywhere."""
    b = pl.program_id(0)
    e_cap = vals_ref.shape[1]
    gbuf[...] = jnp.zeros_like(gbuf)
    cnt = counts_ref[b]

    def slot(e, _):
        j = idx_ref[b, e]

        @pl.when(e < cnt)
        def _store():
            gbuf[0, pl.ds(j * blk_k, blk_k)] = vals_ref[0, e, 0, :]
        return 0

    jax.lax.fori_loop(0, e_cap, slot, 0)

    f32 = jnp.float32
    da = da_ref[0].astype(f32)                           # (Dp, N)
    bm = b_ref[...].astype(f32)                          # (1, N)
    cm = c_ref[...].astype(f32)                          # (1, N)
    h = h_ref[0].astype(f32)                             # (Dp, N)

    for j in range(nkb):
        sl = slice(j * blk_k, (j + 1) * blk_k)
        dec = h[sl] * da[sl]                             # (blk_k, N)

        @pl.when(live_ref[b, j] > 0)
        def _upd(sl=sl, dec=dec):
            hn = dec + gbuf[0, sl][:, None] * bm
            hnew_ref[0, sl, :] = hn.astype(hnew_ref.dtype)
            y_ref[0, sl] = jnp.sum(hn * cm, axis=-1).astype(y_ref.dtype)

        @pl.when(live_ref[b, j] == 0)
        def _decay(sl=sl, dec=dec):
            hnew_ref[0, sl, :] = dec.astype(hnew_ref.dtype)
            y_ref[0, sl] = jnp.sum(dec * cm, axis=-1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_k", "interpret"))
def _mamba_step_events_call(values, block_idx, counts, live, da, bmat, cmat,
                            h, *, blk_k: int, interpret: bool):
    b, dp, n = da.shape
    nkb = dp // blk_k
    row = pl.BlockSpec((1, dp), lambda bi, idx, cnt, lv: (bi, 0))
    nrow = pl.BlockSpec((1, n), lambda bi, idx, cnt, lv: (bi, 0))
    mat = pl.BlockSpec((1, dp, n), lambda bi, idx, cnt, lv: (bi, 0, 0))
    e_cap = values.shape[1]
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, e_cap, 1, blk_k),
                               lambda bi, idx, cnt, lv: (bi, 0, 0, 0)),
                  mat, nrow, nrow, mat],
        out_specs=[row, mat],
        scratch_shapes=[pltpu.VMEM((1, dp), jnp.float32)],
    )
    y, hnew = pl.pallas_call(
        functools.partial(mamba_step_kernel, blk_k=blk_k, nkb=nkb),
        grid_spec=spec,
        out_shape=[jax.ShapeDtypeStruct((b, dp), jnp.float32),
                   jax.ShapeDtypeStruct((b, dp, n), jnp.float32)],
        interpret=interpret,
        name="mamba_step_events",
    )(block_idx, counts, live, values, da, bmat, cmat, h)
    return y, hnew


def mamba_step_events_pallas(bev: ev.BlockEvents, da, bmat, cmat, h, *,
                             blk_k: int, interpret: bool = False):
    """Event-gated decode step kernel.  bev: blk_m == 1 events of the fired
    gate g = Δt·x (B, DI); da: (B, DI, N); bmat, cmat: (B, N);
    h: (B, DI, N).  Returns (y, h_new)."""
    b, di, n = da.shape
    nkb = bev.num_k_blocks
    dp = nkb * blk_k
    assert dp >= di and b == bev.block_idx.shape[0], (da.shape, nkb, blk_k)
    padm = lambda x: jnp.pad(x.astype(jnp.float32),
                             ((0, 0), (0, dp - di), (0, 0)))
    live = ev.live_block_mask(bev).astype(jnp.int32)
    y, hnew = _mamba_step_events_call(
        bev.values, bev.block_idx, bev.counts, live,
        padm(da), bmat.astype(jnp.float32), cmat.astype(jnp.float32),
        padm(h), blk_k=blk_k, interpret=interpret)
    return y[:, :di], hnew[:, :di, :]
