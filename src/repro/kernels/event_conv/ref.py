"""Pure-jnp twin of the fused-tap strip conv kernel.

Walks the same static subtap plan (``core.events.strip_tap_map``) in the
same order, realizing each subtap as a ``gather_row_strips`` (exact row
moves) + the block-event contraction ``block_event_linear_from_events`` —
the engine registry's "block" backend of ``conv2d_events_strip``.

Bit-exactness contract (tested in tests/test_conv_strips.py): because the
plan visits taps in the per-tap oracle's (dy, dx) order, straddle halves
contribute exact zeros to rows they don't source, and strip-live-but-
pixel-dead event slots contribute exact zeros to the contraction, this twin
is bit-identical to the pixel-granular per-tap path — strips only shrink
the event grid, they never reorder the arithmetic (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.mnf_conv import conv_out_size
from repro.core.mnf_linear import block_event_linear_from_events

__all__ = ["fused_event_conv2d_ref"]


def fused_event_conv2d_ref(stream, w: jax.Array, *, stride: int = 1,
                           padding: int = 0) -> jax.Array:
    """Strip-tiled fused-tap conv, pure jnp.  Returns (B*OY*OX, CO)."""
    b, h, wd, ci = stream.logical_shape
    k, _, ci2, co = w.shape
    assert ci == ci2, (stream.logical_shape, w.shape)
    assert stream.blk_m == ev.STRIP_W, stream.blk_m
    src, live, shift, tap = ev.strip_tap_map((b, h, wd, ci), k, padding,
                                             stride)
    oy = conv_out_size(h, k, stride, padding)
    ox = conv_out_size(wd, k, stride, padding)
    wtap = w.reshape(k * k, ci, co)
    acc = jnp.zeros((b * oy * ox, co),
                    jnp.promote_types(stream.events.values.dtype, w.dtype))
    for t in range(src.shape[1]):
        gat = ev.gather_row_strips(stream.events, jnp.asarray(src[:, t]),
                                   jnp.asarray(live[:, t]), int(shift[t]),
                                   row_stride=stride)
        acc = acc + block_event_linear_from_events(gat, wtap[int(tap[t])],
                                                   qparams=stream.qparams)
    return acc
