"""Public wrappers around the fused-tap strip conv kernel.

``fused_event_conv2d`` consumes a strip-aligned conv ``EventStream``
(blk_m == STRIP_W, NHWC ``logical_shape``) and computes the whole conv layer
in **one** Pallas launch — the engine registry's "pallas" backend of
``conv2d_events_strip``.  ``fused_conv_plan`` exposes the static launch
accounting (grid size, launches, event-grid reduction vs the per-tap path)
that the benchmarks and BENCH_engine.json report.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.mnf_conv import conv_out_size
from repro.kernels.event_conv.kernel import (event_conv_int8_pallas,
                                             event_conv_pallas)

__all__ = ["fused_event_conv2d", "fused_conv_plan"]


def _stacked_weights(w: jax.Array, bk: int, nkb: int,
                     blk_n: int) -> jax.Array:
    """(K, K, CI, CO) -> (k*k*nkb*bk, N): per-tap weight slabs, K-block rows.

    Block row ``tap * nkb + kb`` of the result is W[dy, dx] rows
    [kb*bk, (kb+1)*bk) — the tile the kernel's index map addresses from an
    event's direct K-block address.
    """
    k, k2, ci, co = w.shape
    assert k == k2, w.shape
    wf = w.reshape(k * k, ci, co)
    wf = ev.pad_to_block_multiple(wf, bk, 1)
    assert wf.shape[1] == nkb * bk, (wf.shape, nkb, bk)
    ws = wf.reshape(k * k * nkb * bk, co)
    return ev.pad_to_block_multiple(ws, blk_n, 1)


def fused_event_conv2d(stream, w: jax.Array, *, stride: int = 1,
                       padding: int = 0, blk_n: int = 128,
                       interpret: bool = False,
                       remap: str = "matmul") -> jax.Array:
    """Strip-tiled fused-tap conv, one Pallas launch.  Returns (B*OY*OX, CO).

    ``stream`` must be strip-aligned (blk_m == STRIP_W) and the layer
    strip-eligible (stride in STRIP_STRIDES — see
    ``core.events.strip_eligible``; the engine API enforces this before
    dispatching here).  Streams carrying int8 event values (``qparams``
    set) dispatch to the dequantize-at-load kernel variant (DESIGN.md §12).
    """
    b, h, wd, ci = stream.logical_shape
    k, _, ci2, co = w.shape
    assert ci == ci2, (stream.logical_shape, w.shape)
    assert stream.blk_m == ev.STRIP_W, stream.blk_m
    bev = stream.events
    bk = stream.blk_k
    nkb = bev.num_k_blocks
    src, live, shift, tap = ev.strip_tap_map((b, h, wd, ci), k, padding,
                                             stride)
    src_j = jnp.asarray(src)
    cnt = jnp.where(jnp.asarray(live), bev.counts[src_j], 0)
    ws = _stacked_weights(w, bk, nkb, blk_n)
    if stream.qparams is not None:
        y = event_conv_int8_pallas(
            bev.values, bev.block_idx, jnp.asarray(tap), jnp.asarray(shift),
            src_j, cnt.astype(jnp.int32), stream.qparams.scale,
            stream.qparams.zero_point, ws, nkb=nkb, blk_n=blk_n,
            row_stride=stride, interpret=interpret, remap=remap)
    else:
        y = event_conv_pallas(bev.values, bev.block_idx, jnp.asarray(tap),
                              jnp.asarray(shift), src_j, cnt.astype(jnp.int32),
                              ws, nkb=nkb, blk_n=blk_n, row_stride=stride,
                              interpret=interpret, remap=remap)
    oy = conv_out_size(h, k, stride, padding)
    ox = conv_out_size(wd, k, stride, padding)
    return y.reshape(-1, y.shape[-1])[:b * oy * ox, :co]


def fused_conv_plan(logical_shape: tuple, k: int, padding: int,
                    nkb: int, capacity: int | None = None,
                    stride: int = 1) -> dict:
    """Static launch accounting for one strip conv layer vs the per-tap path.

    event_grid counts (row groups x event slots) of the stream each path
    consumes — the gather grid the per-tap path inflates k*k-fold and the
    strip encoding shrinks STRIP_W-fold.  ``subtaps`` is the compacted
    inner-grid length the kernel actually launches (dead straddle parts
    dropped at plan time); ``subtaps_worst`` the uncompacted
    ``strip_parts(stride)*k*k`` it would have launched, ``compaction``
    their ratio (1.0 = nothing to drop).
    """
    b, h, wd, _ = logical_shape
    e = nkb if capacity is None else min(capacity, nkb)
    oh = conv_out_size(h, k, stride, padding)
    ow = conv_out_size(wd, k, stride, padding)
    g_pix = b * h * wd
    g_strip = g_pix // ev.STRIP_W
    g_out = b * oh * (ow // ev.STRIP_W)
    subtaps, subtaps_worst = ev.strip_subtap_counts(k, padding, stride)
    return dict(
        launches_fused=1, launches_per_tap=k * k,
        grid_fused=(g_out, subtaps, e),
        subtaps=subtaps, subtaps_worst=subtaps_worst,
        compaction=subtaps / subtaps_worst,
        event_grid_strip=g_strip * e, event_grid_pixel=g_pix * e,
        grid_reduction=float(g_pix) / float(g_strip),
        gathered_groups_per_tap=k * k * b * oh * ow,
        gathered_groups_fused=0)
