"""Fused-tap Pallas TPU kernel for strip-tiled event convolution.

One launch computes an entire conv layer from a strip-aligned event stream
(DESIGN.md §6).  The per-tap path re-dispatches ``event_matmul`` k*k times
per layer and materializes a gathered event grid per tap; this kernel keeps
the k*k tap loop *inside* the launch as two grid dimensions (subtap, event)
and never materializes a gather at all — scalar-prefetched plan arrays
(``src``/``cnt``/``shift``/``tap`` from ``core.events.strip_tap_map``) drive
the indirection through BlockSpec index maps:

  a_vals (G_in, E, bm, bk)   strip event tiles, consumed in place — the
                             a-tile DMA'd for grid step (g, ., t, e) is
                             ``a_vals[src[g, t], e]``.
  ws     (k*k*nkb*bk, N)     tap-stacked weights; the w-tile is block row
                             ``tap[t] * nkb + a_idx[src[g, t], e]`` — the
                             event's direct weight address offset into its
                             tap's slab.

Grid (G_out, N/bn, T, E), T the **compacted** subtap count of the plan
(``strip_subtap_counts(k, p, stride)[0]``): each tap splits into its
``strip_parts(stride)`` strip-straddle parts — two adjacent-strip halves at
stride 1, up to three interleaved half-strips at stride 2, up to five
quarter-strips at stride 4 — and parts whose affine map sources no row are
*dropped from the plan* rather than idled over, so the inner grid axis
shrinks from ``strip_parts(stride)*k*k`` toward ``k*k``.  E innermost.
Per subtap a scratch ``tap_acc`` accumulates events exactly like the
per-tap ``event_matmul`` kernel does, then flushes into the layer
accumulator — reproducing the per-tap oracle's reduction tree bit-for-bit
(the straddle part that does not source a given output row contributes
exact zeros).  The in-tile affine row remap of a straddling tap (out row
i <- src row stride*i + d) is applied as a 0/1 selection matmul
(``sel @ a``), which moves rows exactly (no rounding) and rides the MXU;
``remap="select"`` swaps in an 8-step vselect ladder (broadcast row m,
select where stride*i + d == m) — same exact row moves on the VPU, kept
for the Mosaic lowering cost comparison recorded in DESIGN.md §6.

``@pl.when(e < cnt[g, t])`` idles the unit on padded event slots and on
border subtaps (zero-padding reads outside the map) — the paper's
low-power idle, now covering the whole tap loop of a layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["event_conv_kernel", "event_conv_pallas",
           "event_conv_int8_kernel", "event_conv_int8_pallas"]


def _shift_rows(a, d, *, row_stride: int, remap: str):
    """Exact affine row remap: out row i <- src row row_stride*i + d
    (strided straddle parts pick their interleaved partial strip).
    Rows the map doesn't source come out exact f32 zeros."""
    bm = a.shape[0]
    if remap == "select":
        # vselect ladder: bm row-broadcasts + masked selects (VPU).
        want = (jax.lax.broadcasted_iota(jnp.int32, (bm, a.shape[1]), 0)
                * row_stride + d)
        shifted = jnp.zeros(a.shape, jnp.float32)
        for m in range(bm):
            row = jax.lax.broadcast_in_dim(a[m].astype(jnp.float32),
                                           a.shape, (1,))
            shifted = jnp.where(want == m, row, shifted)
        return shifted
    # 0/1 selection matmul: one (bm, bm) @ (bm, bk) MXU op.
    i = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 1)
    sel = (j == i * row_stride + d).astype(a.dtype)
    return jnp.dot(sel, a, preferred_element_type=jnp.float32)


def event_conv_kernel(tap_ref, shift_ref, src_ref, cnt_ref, a_idx_ref,
                      # ^ scalar-prefetch refs (plan + event addresses)
                      a_vals_ref, w_ref,       # VMEM inputs
                      out_ref,                 # VMEM output
                      acc_ref, tap_acc_ref,    # VMEM scratch (bm, bn) f32
                      *, row_stride: int = 1, remap: str = "matmul"):
    g = pl.program_id(0)
    t = pl.program_id(2)
    e = pl.program_id(3)
    num_t = pl.num_programs(2)
    num_e = pl.num_programs(3)

    @pl.when((t == 0) & (e == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(e == 0)
    def _tap_init():
        tap_acc_ref[...] = jnp.zeros_like(tap_acc_ref)

    @pl.when(e < cnt_ref[g, t])
    def _mac():
        a = a_vals_ref[0, 0]                     # (bm, bk) source strip tile
        shifted = _shift_rows(a, shift_ref[t], row_stride=row_stride,
                              remap=remap)
        tap_acc_ref[...] += jnp.dot(shifted, w_ref[...],
                                    preferred_element_type=jnp.float32)

    @pl.when(e == num_e - 1)
    def _tap_flush():
        # Matches the per-tap oracle's outer `acc = acc + tap_result`;
        # dead subtaps flush exact zeros (bitwise no-op).
        acc_ref[...] += tap_acc_ref[...]

    @pl.when((t == num_t - 1) & (e == num_e - 1))
    def _writeback():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def event_conv_int8_kernel(tap_ref, shift_ref, src_ref, cnt_ref, a_idx_ref,
                           scale_ref, zp_ref,
                           # ^ scalar-prefetch refs (plan + QParams)
                           a_vals_ref, w_ref,       # VMEM inputs
                           out_ref,                 # VMEM output
                           acc_ref, tap_acc_ref,    # VMEM scratch (bm, bn)
                           *, row_stride: int = 1, remap: str = "matmul"):
    """Int8-value lowering of :func:`event_conv_kernel` (DESIGN.md §12).

    Strip tiles arrive as int8 codes; the kernel dequantizes at tile load
    — ``(q - zp) * scale`` in f32, the exact floats ``quantize.dequantize``
    produces — *before* the affine row remap, so unsourced rows stay exact
    f32 zeros whatever the zero point, and the selection matmul / vselect
    ladder then runs on the same floats the f32 kernel sees when fed the
    fake-quant twin.  TPU int8 min tiles are (32, 128); upcasting at load
    keeps the sub-tile remap structure intact instead of forcing int8 MXU
    alignment.
    """
    g = pl.program_id(0)
    t = pl.program_id(2)
    e = pl.program_id(3)
    num_t = pl.num_programs(2)
    num_e = pl.num_programs(3)

    @pl.when((t == 0) & (e == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(e == 0)
    def _tap_init():
        tap_acc_ref[...] = jnp.zeros_like(tap_acc_ref)

    @pl.when(e < cnt_ref[g, t])
    def _mac():
        a = a_vals_ref[0, 0].astype(jnp.float32)   # (bm, bk) int8 codes
        a = (a - zp_ref[0].astype(jnp.float32)) * scale_ref[0]
        shifted = _shift_rows(a, shift_ref[t], row_stride=row_stride,
                              remap=remap)
        tap_acc_ref[...] += jnp.dot(shifted, w_ref[...],
                                    preferred_element_type=jnp.float32)

    @pl.when(e == num_e - 1)
    def _tap_flush():
        acc_ref[...] += tap_acc_ref[...]

    @pl.when((t == num_t - 1) & (e == num_e - 1))
    def _writeback():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nkb", "blk_n", "row_stride",
                                             "interpret", "out_dtype",
                                             "remap"))
def event_conv_pallas(a_vals: jax.Array, a_idx: jax.Array, tap: jax.Array,
                      shift: jax.Array, src: jax.Array, cnt: jax.Array,
                      ws: jax.Array, *, nkb: int, blk_n: int = 128,
                      row_stride: int = 1, interpret: bool = False,
                      out_dtype=jnp.float32, remap: str = "matmul") -> jax.Array:
    """One fused launch: y[g] = sum_t sum_e remap_t(a[src[g,t], e]) @ W_tile.

    a_vals/a_idx: strip-encoded events (G_in, E, bm, bk) / (G_in, E).
    tap/shift: (T,) subtap plan, T the plan's **compacted** subtap count
    (dead straddle parts already dropped — the grid axis is sized by the
    plan handed in, not the worst case); src/cnt: (G_out, T) source strip
    + live event count per (output strip, subtap).  ws: tap-stacked
    weights (k*k*nkb*bk, N), N a multiple of blk_n.  ``row_stride`` is
    the conv stride: out row i reads src row row_stride*i + shift[t].
    ``remap`` picks the in-tile row-remap lowering ("matmul" | "select" —
    bit-identical; see the kernel docstring).  Returns (G_out, bm, N).
    """
    g_in, e, bm, bk = a_vals.shape
    g_out, t_n = src.shape
    rows, n = ws.shape
    assert remap in ("matmul", "select"), remap
    assert rows % (nkb * bk) == 0, (ws.shape, nkb, bk)  # k*k weight slabs
    assert t_n <= (rows // (nkb * bk)) * \
        (((bm - 1) * row_stride + bm - 1) // bm + 1), \
        (t_n, ws.shape, nkb, bk, row_stride)
    assert n % blk_n == 0, (n, blk_n)

    grid = (g_out, n // blk_n, t_n, e)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk),
                         lambda gi, ni, ti, ei, tp, sh, sr, ct, ai:
                         (sr[gi, ti], ei, 0, 0)),
            pl.BlockSpec((bk, blk_n),
                         lambda gi, ni, ti, ei, tp, sh, sr, ct, ai:
                         (tp[ti] * nkb + ai[sr[gi, ti], ei], ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, blk_n),
                               lambda gi, ni, ti, ei, tp, sh, sr, ct, ai:
                               (gi, 0, ni)),
        scratch_shapes=[pltpu.VMEM((bm, blk_n), jnp.float32),
                        pltpu.VMEM((bm, blk_n), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(event_conv_kernel, row_stride=row_stride,
                          remap=remap),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g_out, bm, n), out_dtype),
        interpret=interpret,
        name="mnf_event_conv_fused",
    )(tap, shift, src, cnt, a_idx, a_vals, ws)
    return out


@functools.partial(jax.jit, static_argnames=("nkb", "blk_n", "row_stride",
                                             "interpret", "out_dtype",
                                             "remap"))
def event_conv_int8_pallas(a_vals: jax.Array, a_idx: jax.Array,
                           tap: jax.Array, shift: jax.Array, src: jax.Array,
                           cnt: jax.Array, scale: jax.Array,
                           zero_point: jax.Array, ws: jax.Array, *, nkb: int,
                           blk_n: int = 128, row_stride: int = 1,
                           interpret: bool = False, out_dtype=jnp.float32,
                           remap: str = "matmul") -> jax.Array:
    """Fused strip conv on int8 event payloads (DESIGN.md §12).

    Same launch/plan structure as :func:`event_conv_pallas`; ``a_vals`` are
    int8 codes and ``scale``/``zero_point`` the stream's QParams, riding
    the scalar prefetch next to the plan arrays.  Returns (G_out, bm, N)
    in f32 accumulation, bit-identical to the f32 kernel fed the
    fake-quant twin.
    """
    g_in, e, bm, bk = a_vals.shape
    g_out, t_n = src.shape
    rows, n = ws.shape
    assert remap in ("matmul", "select"), remap
    assert a_vals.dtype == jnp.int8, a_vals.dtype
    assert rows % (nkb * bk) == 0, (ws.shape, nkb, bk)
    assert n % blk_n == 0, (n, blk_n)

    grid = (g_out, n // blk_n, t_n, e)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk),
                         lambda gi, ni, ti, ei, tp, sh, sr, ct, ai, sc, zp:
                         (sr[gi, ti], ei, 0, 0)),
            pl.BlockSpec((bk, blk_n),
                         lambda gi, ni, ti, ei, tp, sh, sr, ct, ai, sc, zp:
                         (tp[ti] * nkb + ai[sr[gi, ti], ei], ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, blk_n),
                               lambda gi, ni, ti, ei, tp, sh, sr, ct, ai,
                               sc, zp: (gi, 0, ni)),
        scratch_shapes=[pltpu.VMEM((bm, blk_n), jnp.float32),
                        pltpu.VMEM((bm, blk_n), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(event_conv_int8_kernel, row_stride=row_stride,
                          remap=remap),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g_out, bm, n), out_dtype),
        interpret=interpret,
        name="mnf_event_conv_fused_int8",
    )(tap, shift, src, cnt, a_idx,
      scale.reshape(1).astype(jnp.float32),
      zero_point.reshape(1).astype(jnp.int32), a_vals, ws)
    return out
