from repro.kernels.event_conv.kernel import (event_conv_kernel,
                                             event_conv_pallas)
from repro.kernels.event_conv.ops import fused_conv_plan, fused_event_conv2d
from repro.kernels.event_conv.ref import fused_event_conv2d_ref

__all__ = ["event_conv_kernel", "event_conv_pallas", "fused_conv_plan",
           "fused_event_conv2d", "fused_event_conv2d_ref"]
