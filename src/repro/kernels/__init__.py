"""Pallas TPU kernels for MNF's compute hot-spots.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper), ref.py (pure-jnp oracle).  Kernels are validated in
interpret=True mode on CPU; the model stack reaches them through the
``repro.engine`` backend registry (they register as the "pallas" backend of
each op), so dry-run/roofline lower the pure-XLA path (truthful
cost_analysis — see DESIGN.md §2 and §4).
"""
from repro.kernels.event_conv import (fused_conv_plan, fused_event_conv2d,
                                      fused_event_conv2d_ref)
from repro.kernels.event_matmul import (event_matmul, event_matmul_cfg,
                                        event_matmul_from_events,
                                        event_matmul_int8,
                                        event_matmul_int8_ref,
                                        event_matmul_ref)
from repro.kernels.fire_compact import (fire_and_encode, fire_and_encode_cfg,
                                        fire_compact, fire_compact_ref)
from repro.kernels.mamba_scan import (mamba_scan, mamba_scan_ref,
                                      mamba_step_events_pallas,
                                      mamba_step_events_ref, mamba_step_ref)
from repro.kernels.wkv6 import (wkv6, wkv6_ref, wkv6_step_events_pallas,
                                wkv6_step_events_ref, wkv6_step_ref)

__all__ = ["event_matmul", "event_matmul_cfg", "event_matmul_from_events",
           "event_matmul_int8", "event_matmul_int8_ref", "event_matmul_ref",
           "fused_conv_plan", "fused_event_conv2d", "fused_event_conv2d_ref",
           "fire_and_encode", "fire_and_encode_cfg", "fire_compact",
           "fire_compact_ref",
           "mamba_scan", "mamba_scan_ref", "wkv6", "wkv6_ref",
           "wkv6_step_ref", "wkv6_step_events_ref", "wkv6_step_events_pallas",
           "mamba_step_ref", "mamba_step_events_ref",
           "mamba_step_events_pallas"]
