"""Jit'd public wrapper around the event_matmul Pallas kernel.

``event_matmul(a, w)`` = encode block events (repro.core.events) + Pallas
multiply phase.  On CPU use ``interpret=True`` (kernel body executed in
Python); on TPU the compiled kernel runs with MXU-aligned tiles.

This module is the "pallas" backend of the engine registry
(``repro.engine``): ``event_matmul_cfg`` translates an EngineConfig into the
kernel's knobs, and ``event_matmul_from_events`` is the chained-layer entry
point that consumes a fired EventStream's BlockEvents with no re-encode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.kernels.event_matmul.kernel import (event_matmul_int8_pallas,
                                               event_matmul_pallas)

__all__ = ["event_matmul", "event_matmul_from_events", "event_matmul_cfg",
           "event_matmul_int8"]


def event_matmul_from_events(bev: ev.BlockEvents, w: jax.Array, *,
                             blk_n: int = 128, interpret: bool = False,
                             out_dtype=jnp.float32, qparams=None) -> jax.Array:
    """Multiply phase on pre-encoded events.  Returns (G*bm, N).

    With ``qparams`` (a ``core.quantize.QParams``) the event values are
    int8 codes: the int8 kernel dequantizes each tile at load and
    accumulates in f32 (DESIGN.md §12).
    """
    g, e, bm, bk = bev.values.shape
    if qparams is not None:
        y = event_matmul_int8_pallas(bev.values, bev.block_idx, bev.counts,
                                     qparams.scale, qparams.zero_point, w,
                                     blk_n=blk_n, interpret=interpret,
                                     out_dtype=out_dtype)
    else:
        y = event_matmul_pallas(bev.values, bev.block_idx, bev.counts, w,
                                blk_n=blk_n, interpret=interpret,
                                out_dtype=out_dtype)
    return y.reshape(g * bm, w.shape[1])


@functools.partial(jax.jit, static_argnames=(
    "blk_m", "blk_k", "blk_n", "capacity", "interpret"))
def event_matmul_int8(q: jax.Array, w: jax.Array, qparams, *, blk_m: int = 8,
                      blk_k: int = 128, blk_n: int = 128,
                      capacity: int | None = None,
                      interpret: bool = False) -> jax.Array:
    """y = dequant(q) @ W on int8 codes q: (M, K) — encode + int8 kernel.

    The dense entry of the int8 lowering (benches, tests): encodes the
    codes at threshold 0 (a tile is live iff it holds a non-zero code —
    the same liveness the fake-quant twin's encode sees) and runs the
    dequantize-at-load kernel.  Matches ``ref.event_matmul_int8_ref``
    bit-for-bit up to f32 accumulation order.
    """
    m, k = q.shape
    k2, n = w.shape
    assert k == k2, (q.shape, w.shape)
    assert q.dtype == jnp.int8, q.dtype
    qp2 = ev.pad_to_block_multiple(q, blk_m, 0)
    qp2 = ev.pad_to_block_multiple(qp2, blk_k, 1)
    wp = ev.pad_to_block_multiple(w, blk_k, 0)
    wp = ev.pad_to_block_multiple(wp, blk_n, 1)
    bev = ev.encode_block_events(qp2, blk_m=blk_m, blk_k=blk_k,
                                 capacity=capacity, threshold=0.0)
    y = event_matmul_from_events(bev, wp, blk_n=blk_n, interpret=interpret,
                                 qparams=qparams)
    return y[:m, :n]


@functools.partial(jax.jit, static_argnames=(
    "blk_m", "blk_k", "blk_n", "capacity", "threshold", "interpret"))
def event_matmul(a: jax.Array, w: jax.Array, *, blk_m: int = 8,
                 blk_k: int = 128, blk_n: int = 128,
                 capacity: int | None = None, threshold: float = 0.0,
                 interpret: bool = False) -> jax.Array:
    """y = a @ W with the MNF block-event dataflow.  a: (M, K), w: (K, N).

    Lossless (== dense matmul) when threshold == 0 and capacity covers all
    live blocks; with threshold > 0 it drops event-free tiles exactly like
    the oracle ``ref.event_matmul_ref``.
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    ap = ev.pad_to_block_multiple(a, blk_m, 0)
    ap = ev.pad_to_block_multiple(ap, blk_k, 1)
    wp = ev.pad_to_block_multiple(w, blk_k, 0)
    wp = ev.pad_to_block_multiple(wp, blk_n, 1)
    bev = ev.encode_block_events(ap, blk_m=blk_m, blk_k=blk_k,
                                 capacity=capacity, threshold=threshold)
    y = event_matmul_from_events(bev, wp, blk_n=blk_n, interpret=interpret)
    return y[:m, :n]


def event_matmul_cfg(a: jax.Array, w: jax.Array, cfg) -> jax.Array:
    """EngineConfig adapter (the engine registry's "pallas" matmul backend).

    ``cfg`` is a ``repro.engine.EngineConfig``; tile sizes are clamped to the
    operand so small CPU test shapes don't pad to full MXU tiles.
    """
    c = cfg.for_width(*a.shape)
    return event_matmul(a, w, blk_m=c.blk_m, blk_k=c.blk_k, blk_n=c.blk_n,
                        capacity=c.capacity, threshold=c.threshold,
                        interpret=c.resolve_interpret())
