"""Pallas TPU kernel for the MNF multiply phase (block-event sparse matmul).

Computes y = a @ W where ``a`` is supplied as *block events* — the paper's
event encoding adapted to TPU tiling (DESIGN.md §2):

  a_vals    (G, E, bm, bk)  compacted live activation tiles
  a_idx     (G, E) int32    direct weight-tile address per event (the paper's
                            start_weight_address); padding slots repeat the
                            last live address so their DMA is elided by
                            Mosaic's revisit-skip.
  counts    (G,)  int32     live event count per row group (the paper's
                            end-of-data event).
  w         (K, N)          dense weights, tiled (bk, bn).

Grid (G, N/bn, E), E innermost so the accumulator tile (= the paper's
accumulate SRAM) stays resident in VMEM while events stream through; the
weight tile named by each event is scalar-prefetch-indexed
(PrefetchScalarGridSpec), so only event-addressed weight tiles are DMA'd from
HBM — the TPU image of "memory accesses occur only when a PE detects an
event".  ``@pl.when(e < count)`` idles the MXU on padded slots (the paper's
low-power idle on no events).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["event_matmul_kernel", "event_matmul_pallas",
           "event_matmul_int8_kernel", "event_matmul_int8_pallas"]


def event_matmul_kernel(a_idx_ref, counts_ref,   # scalar-prefetch refs
                        a_vals_ref, w_ref,       # VMEM inputs
                        out_ref,                 # VMEM output
                        acc_ref):                # VMEM scratch (bm, bn) f32
    g = pl.program_id(0)
    e = pl.program_id(2)
    num_e = pl.num_programs(2)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(e < counts_ref[g])
    def _mac():
        # Multiply phase: one dense MXU burst per event tile.
        a = a_vals_ref[0, 0]                     # (bm, bk)
        w = w_ref[...]                           # (bk, bn)
        acc_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)

    @pl.when(e == num_e - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def event_matmul_int8_kernel(a_idx_ref, counts_ref, scale_ref, zp_ref,
                             # ^ scalar-prefetch refs (addresses + QParams)
                             a_vals_ref, w_ref,       # VMEM inputs
                             out_ref,                 # VMEM output
                             acc_ref):                # VMEM scratch f32
    """Int8-value lowering of :func:`event_matmul_kernel` (DESIGN.md §12).

    Event tiles arrive as int8 codes; the kernel dequantizes at tile load
    — ``(q - zp) * scale`` in f32, the exact floats ``quantize.dequantize``
    produces — and accumulates in f32, so the result is bit-identical to
    the f32 kernel fed the fake-quant twin.  scale/zp ride the scalar
    prefetch next to the event addresses (one QParams per stream —
    dynamic per-layer calibration).
    """
    g = pl.program_id(0)
    e = pl.program_id(2)
    num_e = pl.num_programs(2)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(e < counts_ref[g])
    def _mac():
        a = a_vals_ref[0, 0].astype(jnp.float32)          # (bm, bk) codes
        a = (a - zp_ref[0].astype(jnp.float32)) * scale_ref[0]
        acc_ref[...] += jnp.dot(a, w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(e == num_e - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret",
                                             "out_dtype"))
def event_matmul_int8_pallas(a_vals: jax.Array, a_idx: jax.Array,
                             counts: jax.Array, scale: jax.Array,
                             zero_point: jax.Array, w: jax.Array, *,
                             blk_n: int = 128, interpret: bool = False,
                             out_dtype=jnp.float32) -> jax.Array:
    """y[g, bm, n] = sum_e dequant(a_vals[g, e]) @ W[a_idx[g, e]].

    ``a_vals`` are int8 codes; ``scale``/``zero_point`` the stream's
    QParams (scalars — reshaped to (1,) scalar-prefetch operands).
    """
    g, e, bm, bk = a_vals.shape
    k, n = w.shape
    assert k % bk == 0 and n % blk_n == 0, (k, n, bk, blk_n)

    grid = (g, n // blk_n, e)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk),
                         lambda gi, ni, ei, idx, cnt, sc, zp: (gi, ei, 0, 0)),
            pl.BlockSpec((bk, blk_n),
                         lambda gi, ni, ei, idx, cnt, sc, zp:
                         (idx[gi, ei], ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, blk_n),
                               lambda gi, ni, ei, idx, cnt, sc, zp:
                               (gi, 0, ni)),
        scratch_shapes=[pltpu.VMEM((bm, blk_n), jnp.float32)],
    )
    out = pl.pallas_call(
        event_matmul_int8_kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, bm, n), out_dtype),
        interpret=interpret,
        name="mnf_event_matmul_int8",
    )(a_idx, counts, scale.reshape(1).astype(jnp.float32),
      zero_point.reshape(1).astype(jnp.int32), a_vals, w)
    return out


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret", "out_dtype"))
def event_matmul_pallas(a_vals: jax.Array, a_idx: jax.Array,
                        counts: jax.Array, w: jax.Array, *,
                        blk_n: int = 128, interpret: bool = False,
                        out_dtype=jnp.float32) -> jax.Array:
    """y[g, bm, n] = sum_e a_vals[g, e] @ W[a_idx[g, e]] (live events only)."""
    g, e, bm, bk = a_vals.shape
    k, n = w.shape
    assert k % bk == 0 and n % blk_n == 0, (k, n, bk, blk_n)

    grid = (g, n // blk_n, e)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk),
                         lambda gi, ni, ei, idx, cnt: (gi, ei, 0, 0)),
            pl.BlockSpec((bk, blk_n),
                         lambda gi, ni, ei, idx, cnt: (idx[gi, ei], ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, blk_n),
                               lambda gi, ni, ei, idx, cnt: (gi, 0, ni)),
        scratch_shapes=[pltpu.VMEM((bm, blk_n), jnp.float32)],
    )
    # The W BlockSpec addresses tile-rows: block (bk, blk_n) at block index
    # (a_idx[g, e], ni) == elements [a_idx*bk : (a_idx+1)*bk, ni*blk_n : ...].
    out = pl.pallas_call(
        event_matmul_kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, bm, n), out_dtype),
        interpret=interpret,
        name="mnf_event_matmul",
    )(a_idx, counts, a_vals, w)
    return out
