"""Pure-jnp oracle for the event_matmul kernel.

Semantics: zero out every (blk_m, blk_k) activation tile whose max |value| is
<= threshold (those tiles fire no event), then do a dense matmul.  The kernel
must match this bit-for-bit up to f32 accumulation order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["event_matmul_int8_ref", "event_matmul_ref", "mask_dead_blocks"]


def mask_dead_blocks(a: jax.Array, *, blk_m: int, blk_k: int,
                     threshold: float = 0.0) -> jax.Array:
    """Zero tiles that contain no event (no |value| > threshold)."""
    m, k = a.shape
    assert m % blk_m == 0 and k % blk_k == 0
    tiles = a.reshape(m // blk_m, blk_m, k // blk_k, blk_k)
    live = jnp.any(jnp.abs(tiles) > threshold, axis=(1, 3), keepdims=True)
    return jnp.where(live, tiles, 0).reshape(m, k)


def event_matmul_ref(a: jax.Array, w: jax.Array, *, blk_m: int, blk_k: int,
                     threshold: float = 0.0) -> jax.Array:
    """Dense oracle of the block-event multiply phase: (M, K) @ (K, N)."""
    masked = mask_dead_blocks(a, blk_m=blk_m, blk_k=blk_k, threshold=threshold)
    return jnp.dot(masked.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def event_matmul_int8_ref(q: jax.Array, w: jax.Array, qparams, *, blk_m: int,
                          blk_k: int) -> jax.Array:
    """Dense oracle of the int8-value lowering (DESIGN.md §12).

    Semantics: a tile is live iff it holds a non-zero int8 code (threshold
    0 — a code of 0 dequantizes to exactly 0 under the symmetric QParams
    the fire phase emits), live tiles dequantize to f32, then dense matmul.
    """
    from repro.core.quantize import dequantize

    masked = mask_dead_blocks(q, blk_m=blk_m, blk_k=blk_k, threshold=0.0)
    return jnp.dot(dequantize(masked, qparams), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
