from repro.kernels.event_matmul.ops import (event_matmul, event_matmul_cfg,
                                            event_matmul_from_events,
                                            event_matmul_int8)
from repro.kernels.event_matmul.ref import (event_matmul_int8_ref,
                                            event_matmul_ref,
                                            mask_dead_blocks)

__all__ = ["event_matmul", "event_matmul_cfg", "event_matmul_from_events",
           "event_matmul_int8", "event_matmul_int8_ref", "event_matmul_ref",
           "mask_dead_blocks"]
