"""Pallas TPU kernel for the MNF fire phase (paper §4.2), fused.

One VMEM pass over the accumulator tensor performs:
  1. the fire decision (threshold compare; ReLU- or magnitude-mode),
  2. optional int8 fake-quantization of fired values (paper §5.2.3 step 2),
  3. per-tile event occupancy (does this (blk_m, blk_k) tile fire ≥1 event?)
     — the metadata the next layer's multiply phase compacts on.

Fusing 1–3 means the accumulator is read exactly once from HBM, the analogue
of the paper's fire module reading each output neuron once from the
accumulate SRAM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fire_compact_kernel", "fire_compact_pallas"]


def fire_compact_kernel(acc_ref, fired_ref, occ_ref, *, threshold: float,
                        magnitude: bool, qscale: float | None):
    acc = acc_ref[...]
    if magnitude:
        live = jnp.abs(acc) > threshold
    else:
        live = acc > threshold
    fired = jnp.where(live, acc, 0)
    if qscale is not None:
        # Symmetric int8 fake-quant with a static calibration scale.
        q = jnp.clip(jnp.round(fired / qscale), -128, 127)
        fired = q * qscale
    fired_ref[...] = fired.astype(fired_ref.dtype)
    occ_ref[0, 0] = jnp.any(live).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("blk_m", "blk_k", "threshold",
                                             "magnitude", "qscale",
                                             "interpret"))
def fire_compact_pallas(acc: jax.Array, *, blk_m: int = 8, blk_k: int = 128,
                        threshold: float = 0.0, magnitude: bool = False,
                        qscale: float | None = None,
                        interpret: bool = False):
    """Returns (fired (M, K), occupancy (M/blk_m, K/blk_k) int32)."""
    m, k = acc.shape
    assert m % blk_m == 0 and k % blk_k == 0, (m, k, blk_m, blk_k)
    grid = (m // blk_m, k // blk_k)
    kernel = functools.partial(fire_compact_kernel, threshold=threshold,
                               magnitude=magnitude, qscale=qscale)
    fired, occ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((blk_m, blk_k), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((blk_m, blk_k), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), acc.dtype),
            jax.ShapeDtypeStruct((m // blk_m, k // blk_k), jnp.int32),
        ],
        interpret=interpret,
        name="mnf_fire_compact",
    )(acc)
    return fired, occ
