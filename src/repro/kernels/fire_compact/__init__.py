from repro.kernels.fire_compact.ops import (fire_and_encode,
                                            fire_and_encode_cfg, fire_compact)
from repro.kernels.fire_compact.ref import fire_compact_ref

__all__ = ["fire_and_encode", "fire_and_encode_cfg", "fire_compact",
           "fire_compact_ref"]
