"""Pure-jnp oracle for the fire_compact kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fire_compact_ref"]


def fire_compact_ref(acc: jax.Array, *, blk_m: int = 8, blk_k: int = 128,
                     threshold: float = 0.0, magnitude: bool = False,
                     qscale: float | None = None):
    if magnitude:
        live = jnp.abs(acc) > threshold
    else:
        live = acc > threshold
    fired = jnp.where(live, acc, 0)
    if qscale is not None:
        fired = jnp.clip(jnp.round(fired / qscale), -128, 127) * qscale
    fired = fired.astype(acc.dtype)
    m, k = acc.shape
    occ = jnp.any(live.reshape(m // blk_m, blk_m, k // blk_k, blk_k),
                  axis=(1, 3)).astype(jnp.int32)
    return fired, occ
