"""Jit'd wrapper: fire phase + event re-encoding for the next layer.

``fire_and_encode`` is the engine registry's "pallas" fire backend
(``repro.engine.fire`` wraps its output in an EventStream);
``fire_and_encode_cfg`` translates an EngineConfig into the kernel knobs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.kernels.fire_compact.kernel import fire_compact_pallas

__all__ = ["fire_compact", "fire_and_encode", "fire_and_encode_cfg"]


@functools.partial(jax.jit, static_argnames=("blk_m", "blk_k", "threshold",
                                             "magnitude", "qscale",
                                             "interpret"))
def fire_compact(acc: jax.Array, *, blk_m: int = 8, blk_k: int = 128,
                 threshold: float = 0.0, magnitude: bool = False,
                 qscale: float | None = None, interpret: bool = False):
    """Fused fire decision + occupancy over an (M, K) accumulator.

    Pads to tile multiples; returns (fired (M, K), occupancy grid int32).
    """
    m, k = acc.shape
    ap = ev.pad_to_block_multiple(acc, blk_m, 0)
    ap = ev.pad_to_block_multiple(ap, blk_k, 1)
    fired, occ = fire_compact_pallas(ap, blk_m=blk_m, blk_k=blk_k,
                                     threshold=threshold, magnitude=magnitude,
                                     qscale=qscale, interpret=interpret)
    return fired[:m, :k], occ


def fire_and_encode(acc: jax.Array, *, blk_m: int = 8, blk_k: int = 128,
                    threshold: float = 0.0, magnitude: bool = False,
                    capacity: int | None = None,
                    interpret: bool = False):
    """Full fire module: returns (fired dense, BlockEvents for next layer)."""
    fired, _ = fire_compact(acc, blk_m=blk_m, blk_k=blk_k,
                            threshold=threshold, magnitude=magnitude,
                            interpret=interpret)
    fp = ev.pad_to_block_multiple(fired, blk_m, 0)
    fp = ev.pad_to_block_multiple(fp, blk_k, 1)
    bev = ev.encode_block_events(fp, blk_m=blk_m, blk_k=blk_k,
                                 capacity=capacity, threshold=0.0)
    return fired, bev


def fire_and_encode_cfg(acc: jax.Array, cfg):
    """EngineConfig adapter (the engine registry's "pallas" fire backend)."""
    c = cfg.for_width(*acc.shape)
    return fire_and_encode(acc, blk_m=c.blk_m, blk_k=c.blk_k,
                           threshold=c.threshold, magnitude=c.magnitude,
                           capacity=c.capacity,
                           interpret=c.resolve_interpret())
