"""Public wrappers around the event-native max-pool kernel (DESIGN.md §7).

``event_max_pool2d`` consumes a conv ``EventStream`` (pixel-granular or
strip-aligned) and computes the pooled feature-map rows in **one** Pallas
launch — the engine registry's "pallas" backend of ``maxpool2d_events``.
``pool_plan`` exposes the static launch accounting (window taps, event grid
consumed vs the dense window read) that benchmarks record in
BENCH_engine.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.kernels.event_pool.kernel import (event_pool_pallas,
                                             event_pool_window_pallas)

__all__ = ["event_max_pool2d", "event_max_pool2d_window", "pool_plan",
           "pool_window_plan"]


def event_max_pool2d(stream, k: int, stride: int, *,
                     interpret: bool = False) -> jax.Array:
    """Event-native max-pool, one Pallas launch.  Returns (B·OH·OW, C).

    ``stream`` must carry an NHWC ``logical_shape``; the engine API gates
    eligibility (ReLU-family fire — non-negative events — and window within
    the map) before dispatching here.
    """
    b, h, w, c = stream.logical_shape
    bev = stream.events
    src, row, live = ev.pool_window_map(stream.logical_shape, k, stride,
                                        stream.blk_m)
    p_n = src.shape[0]
    nkb, bk = bev.num_k_blocks, stream.blk_k
    if p_n == 0:                       # degenerate batch/map: no launch
        return jnp.zeros((0, c), bev.values.dtype)
    src_j = jnp.asarray(src)
    cnt = jnp.where(jnp.asarray(live), bev.counts[src_j], 0)
    y = event_pool_pallas(bev.values, bev.block_idx, jnp.asarray(row),
                          src_j, cnt.astype(jnp.int32), nkb=nkb,
                          interpret=interpret)
    return y.reshape(p_n, nkb * bk)[:, :c]


def event_max_pool2d_window(stream, k: int, stride: int, *,
                            interpret: bool = False) -> jax.Array:
    """Window-major event pool, one Pallas launch.  Returns (B·OH·OW, C).

    The strip rework of :func:`event_max_pool2d`: the grid walks output
    *strips* (8 pooled pixels each — 8x fewer steps) and every subtap
    consumes the whole gathered tile through the strip-masked affine
    remap.  Requires a strip stream on an eligible geometry
    (``core.events.pool_window_ineligible_reason``); the engine gates.
    """
    b, h, w, c = stream.logical_shape
    bev = stream.events
    bm = stream.blk_m
    assert bm == ev.STRIP_W, (bm, "window-major pool wants a strip stream")
    src, live, shift, _ = ev.pool_strip_map(stream.logical_shape, k, stride)
    g_n = src.shape[0]
    nkb, bk = bev.num_k_blocks, stream.blk_k
    if g_n == 0:                       # degenerate batch/map: no launch
        return jnp.zeros((0, c), bev.values.dtype)
    src_j = jnp.asarray(src)
    cnt = jnp.where(jnp.asarray(live), bev.counts[src_j], 0)
    y = event_pool_window_pallas(bev.values, bev.block_idx,
                                 jnp.asarray(shift), src_j,
                                 cnt.astype(jnp.int32), nkb=nkb,
                                 row_stride=stride, interpret=interpret)
    return y.reshape(g_n * bm, nkb * bk)[:, :c]


def pool_plan(logical_shape: tuple, k: int, stride: int, *,
              nkb: int, capacity: int | None = None) -> dict:
    """Static launch accounting for one event-pool layer vs the dense pool.

    ``event_grid`` counts the (window tap × event slot) steps the kernel's
    grid walks per output pixel; ``dense_reads`` is what the dense
    ``reduce_window`` pool touches (k·k·C per output pixel).  The ratio is
    the work the event encoding skips when the map is sparse.  The grid is
    granularity-independent (pixel and strip inputs walk the same
    (P_out, k·k, E) steps — only the source tile a step DMAs differs).
    """
    b, h, w, c = logical_shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    e = nkb if capacity is None else min(capacity, nkb)
    p_out = b * oh * ow
    return dict(
        launches=1, window_taps=k * k,
        grid=(p_out, k * k, e),
        event_grid=p_out * k * k * e,
        dense_reads=p_out * k * k * c,
        out_rows=p_out)


def pool_window_plan(logical_shape: tuple, k: int, stride: int, *,
                     nkb: int, capacity: int | None = None) -> dict:
    """Launch accounting of the window-major grid vs the per-event one.

    ``grid_reduction`` is the step-count ratio the rework buys: the
    per-event grid walks P_out·k²·E steps, the window-major grid
    (P_out/8)·k²·parts·E — a strip serves 8 output pixels per step while
    straddle parts multiply taps by ``parts`` (2 at stride 1, ≤3 at
    stride ≤ 3 for k ≤ 3), so the net is 8/parts ≈ 2.7–4x fewer DMAs plus
    full-tile row use instead of 1-of-8 row picks.
    """
    b, h, w, c = logical_shape
    reason = ev.pool_window_ineligible_reason(logical_shape, k, stride,
                                              ev.STRIP_W)
    assert reason is None, (logical_shape, k, stride, reason)
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    e = nkb if capacity is None else min(capacity, nkb)
    parts = ((ev.STRIP_W - 1) * stride + k - 1) // ev.STRIP_W + 1
    g_out = b * oh * (ow // ev.STRIP_W)
    p_out = b * oh * ow
    return dict(
        launches=1, window_taps=k * k, parts=parts,
        grid=(g_out, k * k * parts, e),
        event_grid=g_out * k * k * parts * e,
        pixel_event_grid=p_out * k * k * e,
        grid_reduction=(p_out * k * k * e)
        / max(g_out * k * k * parts * e, 1),
        dense_reads=p_out * k * k * c,
        out_rows=p_out)
