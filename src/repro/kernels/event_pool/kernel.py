"""Event-native max-pool Pallas TPU kernel (DESIGN.md §7).

One launch pools an entire layer straight from its fired ``EventStream`` —
the dense feature map is never read.  The grid is (P_out, T, E): output
pixel × window tap (T = k·k) × event slot, mirroring the fused conv
kernel's plan-driven indirection:

  a_vals (G_in, E, bm, bk)   the stream's event tiles, consumed in place —
                             the tile DMA'd for step (p, t, e) is
                             ``a_vals[src[p, t], e]`` (scalar-prefetched
                             window plan from ``core.events.pool_window_map``).
  out    (P_out, nkb, bk)    pooled rows, written once per pixel from a
                             VMEM segment-max scratch.

Per live event the kernel picks the window pixel's row out of the (bm, bk)
tile with a 0/1 selection matmul (exact value move, same idiom as the
fused conv kernel's row shifts) and max-accumulates it into the scratch
row named by the event's direct K-block address — a segment max keyed by
weight-tile address, identity 0.  Because fire emits non-negative values
and event-absent positions are exactly 0, the result is bit-identical to
the dense ``reduce_window`` max of the fired map.

``@pl.when(e < cnt[p, t])`` idles the unit on padded event slots — the
paper's low-power idle, now covering the pool windows too: a fully dead
window does zero work and emits the exact-0 pooled row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["event_pool_kernel", "event_pool_pallas",
           "event_pool_window_kernel", "event_pool_window_pallas"]


def event_pool_kernel(row_ref, src_ref, cnt_ref, a_idx_ref,
                      # ^ scalar-prefetch refs (window plan + event addresses)
                      a_vals_ref,              # VMEM input (1, 1, bm, bk)
                      out_ref,                 # VMEM output (1, nkb, bk)
                      acc_ref):                # VMEM scratch (nkb, bk) f32
    p = pl.program_id(0)
    t = pl.program_id(1)
    e = pl.program_id(2)
    num_t = pl.num_programs(1)
    num_e = pl.num_programs(2)

    @pl.when((t == 0) & (e == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(e < cnt_ref[p, t])
    def _segmax():
        a = a_vals_ref[0, 0]                  # (bm, bk) source event tile
        bm = a.shape[0]
        r = row_ref[p, t]
        # Exact row pick: 0/1 selection matmul (no rounding, rides the MXU —
        # the same move idiom as the fused conv kernel's straddle shifts).
        sel = (jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1) == r
               ).astype(a.dtype)
        picked = jnp.dot(sel, a, preferred_element_type=jnp.float32)
        kb = a_idx_ref[src_ref[p, t], e]      # direct K-block address
        cur = pl.load(acc_ref, (pl.dslice(kb, 1), slice(None)))
        pl.store(acc_ref, (pl.dslice(kb, 1), slice(None)),
                 jnp.maximum(cur, picked))

    @pl.when((t == num_t - 1) & (e == num_e - 1))
    def _writeback():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nkb", "interpret", "out_dtype"))
def event_pool_pallas(a_vals: jax.Array, a_idx: jax.Array, row: jax.Array,
                      src: jax.Array, cnt: jax.Array, *, nkb: int,
                      interpret: bool = False,
                      out_dtype=jnp.float32) -> jax.Array:
    """One fused launch: y[p] = max_t max_e rowpick(a[src[p,t], e]), id 0.

    a_vals/a_idx: event tiles (G_in, E, bm, bk) / addresses (G_in, E).
    row/src/cnt: (P_out, T) window plan — source group, row within its tile,
    live event count per (output pixel, window tap).  Returns
    (P_out, nkb, bk) pooled rows in K-block layout.
    """
    g_in, e, bm, bk = a_vals.shape
    p_out, t_n = src.shape
    assert row.shape == src.shape == cnt.shape, (row.shape, src.shape,
                                                 cnt.shape)

    grid = (p_out, t_n, e)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk),
                         lambda pi, ti, ei, rw, sr, ct, ai:
                         (sr[pi, ti], ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nkb, bk),
                               lambda pi, ti, ei, rw, sr, ct, ai:
                               (pi, 0, 0)),
        scratch_shapes=[pltpu.VMEM((nkb, bk), jnp.float32)],
    )
    return pl.pallas_call(
        event_pool_kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((p_out, nkb, bk), out_dtype),
        interpret=interpret,
        name="mnf_event_pool",
    )(row, src, cnt, a_idx, a_vals)


# ---------------------------------------------------------------------------
# Window-major grid (DESIGN.md §7): one grid step per *output strip* —
# 8 pooled pixels — instead of per output pixel, and every subtap consumes
# the whole gathered (bm, bk) tile through a strip-masked affine row remap
# (out row i <- src row stride*i + shift; unsourced rows are exact 0, the
# max identity).  8x fewer grid steps than the per-event kernel, no wasted
# row picks — the raw-steady-state rework the ROADMAP calls out.
# ---------------------------------------------------------------------------

def event_pool_window_kernel(shift_ref, src_ref, cnt_ref, a_idx_ref,
                             # ^ scalar-prefetch refs (strip plan + addrs)
                             a_vals_ref,           # VMEM input (1, 1, bm, bk)
                             out_ref,              # VMEM out (1, bm, nkb, bk)
                             acc_ref,              # VMEM scratch (nkb, bm, bk)
                             *, row_stride: int):
    g = pl.program_id(0)
    t = pl.program_id(1)
    e = pl.program_id(2)
    num_t = pl.num_programs(1)
    num_e = pl.num_programs(2)

    @pl.when((t == 0) & (e == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(e < cnt_ref[g, t])
    def _segmax():
        a = a_vals_ref[0, 0]                  # (bm, bk) source event tile
        bm = a.shape[0]
        d = shift_ref[t]
        # Strip-masked affine remap as a 0/1 selection matmul (the fused
        # conv kernel's exact-move idiom): out row i takes src row
        # stride*i + d; rows whose source leaves [0, bm) get an all-zero
        # selection row — the exact 0 the segment max treats as identity.
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 1)
        sel = (cols == rows * row_stride + d).astype(a.dtype)
        remap = jnp.dot(sel, a, preferred_element_type=jnp.float32)
        kb = a_idx_ref[src_ref[g, t], e]      # direct K-block address
        cur = pl.load(acc_ref, (pl.dslice(kb, 1), slice(None), slice(None)))
        pl.store(acc_ref, (pl.dslice(kb, 1), slice(None), slice(None)),
                 jnp.maximum(cur, remap[None]))

    @pl.when((t == num_t - 1) & (e == num_e - 1))
    def _writeback():
        # Scratch is K-block-major (segment addresses lead — the dslice
        # axis); the output strip wants rows leading.  One VMEM transpose
        # per strip at writeback, amortized over the whole tap walk.
        out_ref[0] = acc_ref[...].transpose(1, 0, 2).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nkb", "row_stride",
                                             "interpret", "out_dtype"))
def event_pool_window_pallas(a_vals: jax.Array, a_idx: jax.Array,
                             shift: jax.Array, src: jax.Array,
                             cnt: jax.Array, *, nkb: int, row_stride: int,
                             interpret: bool = False,
                             out_dtype=jnp.float32) -> jax.Array:
    """One fused launch over the window-major grid (G_out, T, E).

    a_vals/a_idx: event tiles (G_in, E, bm, bk) / addresses (G_in, E).
    shift/src/cnt: the ``core.events.pool_strip_map`` plan — per-subtap row
    offset (T,), source strip group (G_out, T), live event count
    (G_out, T).  Returns (G_out, bm, nkb, bk): pooled rows per output
    strip, rows-leading (reshape to (P_out, nkb·bk) outside).
    """
    g_in, e, bm, bk = a_vals.shape
    g_out, t_n = src.shape
    assert cnt.shape == src.shape, (cnt.shape, src.shape)
    assert shift.shape == (t_n,), (shift.shape, t_n)

    grid = (g_out, t_n, e)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk),
                         lambda gi, ti, ei, sh, sr, ct, ai:
                         (sr[gi, ti], ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, nkb, bk),
                               lambda gi, ti, ei, sh, sr, ct, ai:
                               (gi, 0, 0, 0)),
        scratch_shapes=[pltpu.VMEM((nkb, bm, bk), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(event_pool_window_kernel, row_stride=row_stride),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g_out, bm, nkb, bk), out_dtype),
        interpret=interpret,
        name="mnf_event_pool_window",
    )(shift, src, cnt, a_idx, a_vals)
