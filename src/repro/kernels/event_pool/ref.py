"""Pure-jnp twin of the event-native max-pool kernel (DESIGN.md §7).

Walks the same static window plan (``core.events.pool_window_map``) as the
Pallas kernel: each of the k·k window taps is a row gather of the input
stream's event tiles, scattered into a per-output-pixel segment-max
accumulator keyed by the event's direct K-block address.  The engine
registry's "block" backend of ``maxpool2d_events``.

Bit-exactness contract (tested in tests/test_event_pool.py): the fire phase
emits non-negative activations (ReLU at the threshold), event-absent
positions are exactly 0, and max is order-invariant over a multiset — so
the segment max over events, with identity 0, equals the dense
``reduce_window`` max of the fired map bit for bit.  The identity-0
argument is exactly why the engine gates this path on non-``magnitude``
fire configs (negative events would be clipped).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import events as ev

__all__ = ["event_max_pool2d_ref"]


def event_max_pool2d_ref(stream, k: int, stride: int) -> jnp.ndarray:
    """Segment-max pool over a conv EventStream.  Returns (B·OH·OW, C).

    ``stream`` is pixel-granular (blk_m == 1) or strip-aligned
    (blk_m == STRIP_W); the plan addresses either through the same
    (group, row-in-tile) decomposition of raster pixel indices.
    """
    b, h, w, c = stream.logical_shape
    bev = stream.events
    nkb, bk = bev.num_k_blocks, stream.blk_k
    src, row, live = ev.pool_window_map(stream.logical_shape, k, stride,
                                        stream.blk_m)
    p_n, t_n = src.shape
    acc = jnp.zeros((p_n, nkb, bk), bev.values.dtype)
    if p_n == 0:
        return acc.reshape(p_n, nkb * bk)[:, :c]
    e = bev.capacity
    slot = jnp.arange(e, dtype=jnp.int32)[None, :]
    parr = jnp.arange(p_n, dtype=jnp.int32)[:, None]
    for t in range(t_n):
        g = jnp.asarray(src[:, t])
        lv = jnp.asarray(live[:, t])
        r = jnp.asarray(row[:, t])
        # Dead taps (outside the map — cannot happen for VALID pooling, kept
        # for plan symmetry) and padded event slots must not contribute the
        # clipped source's values: mask to the identity 0.
        cnt = jnp.where(lv, bev.counts[g], 0)
        vals = jnp.take_along_axis(
            bev.values[g], r[:, None, None, None], axis=2)[:, :, 0]  # (P,E,bk)
        vals = jnp.where((slot < cnt[:, None])[:, :, None], vals, 0)
        acc = acc.at[parr, bev.block_idx[g]].max(vals)
    return acc.reshape(p_n, nkb * bk)[:, :c]
