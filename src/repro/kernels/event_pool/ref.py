"""Pure-jnp twins of the event-native max-pool kernels (DESIGN.md §7).

Two grids over the same segment-max semantics:

  * ``event_max_pool2d_ref`` — the original *per-event* plan
    (``core.events.pool_window_map``): one accumulator row per output
    pixel, k·k row gathers each.  General (any granularity); the oracle.
  * ``event_max_pool2d_window_ref`` — the *window-major* strip plan
    (``core.events.pool_strip_map``): one accumulator tile per output
    strip (8 pooled pixels), each subtap an affine strip gather
    (``gather_row_strips`` — the fused conv kernel's row-remap idiom) that
    uses all 8 gathered rows instead of picking one, so the tap walk is
    8x shorter and no gathered row is wasted.  The raw-steady-state path
    the bench sweep measures against dense ``reduce_window``.

Bit-exactness contract (tested in tests/test_event_pool.py): the fire phase
emits non-negative activations (ReLU at the threshold), event-absent
positions are exactly 0, and max is order-invariant over a multiset — so
the segment max over events, with identity 0, equals the dense
``reduce_window`` max of the fired map bit for bit — for either grid.  The
identity-0 argument is exactly why the engine gates this path on
non-``magnitude`` fire configs (negative events would be clipped), and why
the affine row remap's out-of-range zeros are free (0 is the identity).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import events as ev

__all__ = ["event_max_pool2d_ref", "event_max_pool2d_window_ref"]


def event_max_pool2d_ref(stream, k: int, stride: int) -> jnp.ndarray:
    """Segment-max pool over a conv EventStream.  Returns (B·OH·OW, C).

    ``stream`` is pixel-granular (blk_m == 1) or strip-aligned
    (blk_m == STRIP_W); the plan addresses either through the same
    (group, row-in-tile) decomposition of raster pixel indices.
    """
    b, h, w, c = stream.logical_shape
    bev = stream.events
    nkb, bk = bev.num_k_blocks, stream.blk_k
    src, row, live = ev.pool_window_map(stream.logical_shape, k, stride,
                                        stream.blk_m)
    p_n, t_n = src.shape
    acc = jnp.zeros((p_n, nkb, bk), bev.values.dtype)
    if p_n == 0:
        return acc.reshape(p_n, nkb * bk)[:, :c]
    e = bev.capacity
    slot = jnp.arange(e, dtype=jnp.int32)[None, :]
    parr = jnp.arange(p_n, dtype=jnp.int32)[:, None]
    for t in range(t_n):
        g = jnp.asarray(src[:, t])
        lv = jnp.asarray(live[:, t])
        r = jnp.asarray(row[:, t])
        # Dead taps (outside the map — cannot happen for VALID pooling, kept
        # for plan symmetry) and padded event slots must not contribute the
        # clipped source's values: mask to the identity 0.
        cnt = jnp.where(lv, bev.counts[g], 0)
        vals = jnp.take_along_axis(
            bev.values[g], r[:, None, None, None], axis=2)[:, :, 0]  # (P,E,bk)
        vals = jnp.where((slot < cnt[:, None])[:, :, None], vals, 0)
        acc = acc.at[parr, bev.block_idx[g]].max(vals)
    return acc.reshape(p_n, nkb * bk)[:, :c]


def event_max_pool2d_window_ref(stream, k: int, stride: int) -> jnp.ndarray:
    """Window-major segment-max pool over a *strip* EventStream.

    Returns (B·OH·OW, C), bit-identical to :func:`event_max_pool2d_ref`
    (and hence to the dense ``reduce_window``).  Requires
    ``core.events.pool_window_ineligible_reason(...) is None`` — the engine
    gates; the per-event grid stays the general path.
    """
    b, h, w, c = stream.logical_shape
    bev = stream.events
    bm = stream.blk_m
    assert bm == ev.STRIP_W, (bm, "window-major pool wants a strip stream")
    nkb, bk = bev.num_k_blocks, stream.blk_k
    src, live, shift, _ = ev.pool_strip_map(stream.logical_shape, k, stride)
    g_n, t_n = src.shape
    acc = jnp.zeros((g_n, nkb, bm, bk), bev.values.dtype)
    if g_n == 0:
        return acc.reshape(0, nkb * bk)[:, :c]
    e = bev.capacity
    slot = jnp.arange(e, dtype=jnp.int32)[None, :]
    garr = jnp.arange(g_n, dtype=jnp.int32)[:, None]
    for t in range(t_n):
        # Affine strip gather (out row i <- src row stride*i + shift; rows
        # with no source are exact 0) — dead parts and padded event slots
        # mask to the identity 0 before the scatter-max.
        gat = ev.gather_row_strips(bev, jnp.asarray(src[:, t]),
                                   jnp.asarray(live[:, t]), int(shift[t]),
                                   row_stride=stride)
        vals = jnp.where((slot < gat.counts[:, None])[:, :, None, None],
                         gat.values, 0)                  # (G, E, bm, bk)
        acc = acc.at[garr, gat.block_idx].max(vals)
    # Group g's row i is output raster pixel g*8 + i (output strips tile
    # the pooled raster), so the (strip, row) transpose is the whole
    # un-tiling.
    return acc.transpose(0, 2, 1, 3).reshape(g_n * bm, nkb * bk)[:, :c]
