from repro.kernels.event_pool.kernel import (event_pool_kernel,
                                             event_pool_pallas,
                                             event_pool_window_kernel,
                                             event_pool_window_pallas)
from repro.kernels.event_pool.ops import (event_max_pool2d,
                                          event_max_pool2d_window,
                                          pool_plan, pool_window_plan)
from repro.kernels.event_pool.ref import (event_max_pool2d_ref,
                                          event_max_pool2d_window_ref)

__all__ = ["event_pool_kernel", "event_pool_pallas", "event_max_pool2d",
           "event_max_pool2d_ref", "pool_plan",
           "event_pool_window_kernel", "event_pool_window_pallas",
           "event_max_pool2d_window", "event_max_pool2d_window_ref",
           "pool_window_plan"]
