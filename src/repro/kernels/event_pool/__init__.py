from repro.kernels.event_pool.kernel import (event_pool_kernel,
                                             event_pool_pallas)
from repro.kernels.event_pool.ops import event_max_pool2d, pool_plan
from repro.kernels.event_pool.ref import event_max_pool2d_ref

__all__ = ["event_pool_kernel", "event_pool_pallas", "event_max_pool2d",
           "event_max_pool2d_ref", "pool_plan"]
