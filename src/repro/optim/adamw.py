"""AdamW with decoupled weight decay + global-norm clipping.

Self-contained (no optax in this container).  Optimizer state mirrors the
param tree leaf-for-leaf, so the same NamedShardings apply (ZeRO-1 falls out
of the fsdp rule on the "embed" logical axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                  # used when schedule is None
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Callable[[jax.Array], jax.Array] | None = None


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    lr = cfg.schedule(count) if cfg.schedule is not None else cfg.lr
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    new = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([n[0] for n in new])
    new_m = tdef.unflatten([n[1] for n in new])
    new_v = tdef.unflatten([n[2] for n in new])
    metrics = dict(grad_norm=gn, lr=jnp.asarray(lr, jnp.float32))
    return new_p, OptState(new_m, new_v, count), metrics
