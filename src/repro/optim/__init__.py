from repro.optim.adamw import (AdamWConfig, OptState, adamw_init,
                               adamw_update, clip_by_global_norm, global_norm)
from repro.optim.compression import (event_psum, make_compressed_grad_fn,
                                     quantized_psum, topk_threshold)
from repro.optim.schedule import constant, warmup_cosine, warmup_linear

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "global_norm", "event_psum",
           "make_compressed_grad_fn", "quantized_psum", "topk_threshold",
           "constant", "warmup_cosine", "warmup_linear"]
