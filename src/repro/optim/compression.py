"""Distributed gradient compression — MNF applied to the collective layer.

Two compressed all-reduce primitives for explicit-DP (shard_map) training:

  * ``quantized_psum``     — int8-quantized all-reduce with per-tensor f32
    scale (chunk-wise max calibration), 4x wire reduction vs f32.
  * ``event_psum``         — *event-driven gradient exchange* (beyond-paper):
    only gradient entries with |g| above a threshold *fire* into the
    collective; sub-threshold values accumulate in a local error-feedback
    residual and fire later.  This is exactly the paper's fire phase applied
    to gradients: sparsity-proportional communication with no information
    loss over time.

On a real interconnect the fired values travel as (value, index) events
(ragged all-gather); under XLA collectives we transport the masked dense
tensor — the *semantics* (and convergence behaviour, which tests check) are
identical, and the wire-bytes saving is reported by the cost model.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["quantized_psum", "event_psum", "topk_threshold",
           "make_compressed_grad_fn"]


def quantized_psum(x: jax.Array, axis_name: str, *, bits: int = 8):
    """int-quantized psum; returns the mean-equivalent f32 result."""
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    # Share one scale across the group (max of local maxima).
    amax = jax.lax.pmax(amax, axis_name)
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale


def topk_threshold(x: jax.Array, k_frac: float) -> jax.Array:
    """Magnitude threshold that keeps ~k_frac of entries (sorted estimate)."""
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.shape[0] * k_frac))
    kth = jax.lax.top_k(flat, k)[0][-1]
    return kth


def event_psum(x: jax.Array, residual: jax.Array, axis_name: str, *,
               k_frac: float = 0.05):
    """Fire-phase gradient exchange with error feedback.

    Returns (summed fired gradient, new residual).  residual carries the
    sub-threshold mass forward (error feedback), so sum over steps is
    unbiased.
    """
    acc = x + residual
    theta = topk_threshold(acc, k_frac)
    fired = jnp.where(jnp.abs(acc) >= theta, acc, 0.0)   # fire decision
    new_residual = acc - fired                           # error feedback
    total = jax.lax.psum(fired, axis_name)
    return total, new_residual


def make_compressed_grad_fn(mode: str = "none", *, k_frac: float = 0.05,
                            bits: int = 8):
    """Returns reduce(grad_leaf, residual_leaf, axis_name) -> (g, residual)."""
    if mode == "none":
        return lambda g, r, ax: (jax.lax.psum(g, ax), r)
    if mode == "int8":
        return lambda g, r, ax: (quantized_psum(g, ax, bits=bits), r)
    if mode == "event":
        return lambda g, r, ax: event_psum(g, r, ax, k_frac=k_frac)
    raise ValueError(mode)
