"""Dataflow energy model (Fig. 1, Table 5) — Timeloop/Accelergy-style.

Per-access energies are the paper's Table 5 values.  For each dataflow we
count accesses at every memory level for a Conv layer (Table 1 shapes) at a
given activation density, then multiply by the per-access energy.

Access-count formulations (standard Timeloop loop-nest accounting, see
Sze et al. tutorial [35]):
  weight-stationary   — weights read once from DRAM, inputs re-read per
                        filter position, psums spilled per input pass;
  input-stationary    — inputs read once, weights re-streamed per input
                        tile, psums spilled;
  output-stationary   — psums pinned in registers, inputs+weights
                        re-streamed per output;
  MNF event-driven    — weights resident in local SRAM (no DRAM in steady
                        state), each *event* reads its weight rows once from
                        local SRAM, accumulators read+written per event in
                        the two-port accumulate SRAM; zero activations cost
                        nothing anywhere.
"""
from __future__ import annotations

import dataclasses

__all__ = ["AccessEnergy", "TABLE5_OTHERS", "TABLE5_MNF", "ConvShape",
           "TABLE1", "dataflow_energy", "mnf_energy", "compare_dataflows"]


@dataclasses.dataclass(frozen=True)
class AccessEnergy:
    """pJ per access + access width in bits (Table 5)."""

    dram_pj: float
    dram_bits: int
    sram_pj: float
    sram_bits: int
    buf_pj: float
    buf_bits: int
    reg_pj: float
    reg_bits: int
    mac_pj: float = 0.56         # 8-bit MAC @ 22-28nm (Accelergy/Aladdin)


TABLE5_OTHERS = AccessEnergy(dram_pj=512.0, dram_bits=64,
                             sram_pj=74.0, sram_bits=64,
                             buf_pj=1.59, buf_bits=16,
                             reg_pj=0.97, reg_bits=16 * 3)

# MNF column of Table 5: narrower DRAM port, small local SRAMs (3.87 pJ),
# 216-bit wide weight-vector buffer reads + 32-bit accumulator access.
TABLE5_MNF = AccessEnergy(dram_pj=256.0, dram_bits=32,
                          sram_pj=3.87, sram_bits=32,
                          buf_pj=12.35, buf_bits=216,
                          reg_pj=0.018, reg_bits=8 * 3)


@dataclasses.dataclass(frozen=True)
class ConvShape:
    in_ch: int
    out_ch: int
    in_size: int
    out_size: int
    k: int

    @property
    def stride(self) -> int:
        return max(1, self.in_size // self.out_size)

    @property
    def macs(self) -> int:
        return self.out_size ** 2 * self.k ** 2 * self.in_ch * self.out_ch

    @property
    def weights(self) -> int:
        return self.k ** 2 * self.in_ch * self.out_ch

    @property
    def inputs(self) -> int:
        return self.in_size ** 2 * self.in_ch

    @property
    def outputs(self) -> int:
        return self.out_size ** 2 * self.out_ch


# Table 1 workloads
TABLE1 = {
    "layer1": ConvShape(256, 384, 56, 56, 3),
    "layer2": ConvShape(384, 256, 13, 13, 3),
    "layer3": ConvShape(64, 128, 224, 224, 3),
}


def _energy(counts: dict, e: AccessEnergy) -> float:
    """counts: accesses (in elements, 8-bit acts/weights, 32-bit psums)."""
    pj = 0.0
    pj += counts.get("dram", 0) * 8 / e.dram_bits * e.dram_pj
    pj += counts.get("dram32", 0) * 32 / e.dram_bits * e.dram_pj
    pj += counts.get("sram", 0) * 8 / e.sram_bits * e.sram_pj
    pj += counts.get("sram32", 0) * 32 / e.sram_bits * e.sram_pj
    pj += counts.get("buf", 0) * 8 / e.buf_bits * e.buf_pj
    pj += counts.get("buf32", 0) * 32 / e.buf_bits * e.buf_pj
    pj += counts.get("reg", 0) * e.reg_pj
    pj += counts.get("mac", 0) * e.mac_pj
    return pj


def dataflow_energy(shape: ConvShape, dataflow: str, d_act: float = 1.0,
                    d_w: float = 1.0, e: AccessEnergy = TABLE5_OTHERS
                    ) -> float:
    """Energy (pJ) to run one conv layer under a classic dataflow.

    Sparse operands still transit DRAM in compressed form (d_act/d_w scale
    the streamed volumes); MACs scale with the d_act·d_w intersection.
    """
    macs = shape.macs * d_act * d_w
    w, a, o = shape.weights * d_w, shape.inputs * d_act, shape.outputs
    reuse_a = shape.k ** 2 / shape.stride ** 2     # positions touching a pixel
    if dataflow == "ws":
        counts = dict(
            dram=w + a + o,                        # stream everything once
            sram=w + a * reuse_a + o,              # inputs re-read per k²
            buf=macs * 2,                          # operand feeds
            sram32=2 * o * shape.in_ch * d_act,    # psum spills per channel
            reg=macs, mac=macs)
    elif dataflow == "is":
        counts = dict(
            dram=w + a + o,
            sram=a + w * (shape.out_size ** 2 / 64) + o,  # weights restream
            buf=macs * 2,
            sram32=2 * o * shape.in_ch * d_act,
            reg=macs, mac=macs)
    elif dataflow == "os":
        counts = dict(
            dram=w + a + o,
            sram=a * reuse_a + w * (shape.out_size ** 2 / 64),
            buf=macs * 2,
            sram32=2 * o,                          # psums stay local
            reg=macs, mac=macs)
    else:
        raise ValueError(dataflow)
    return _energy(counts, e)


def mnf_energy(shape: ConvShape, d_act: float = 1.0, d_w: float = 1.0,
               e: AccessEnergy = TABLE5_MNF) -> float:
    """Energy (pJ) for the MNF event-driven dataflow on the same layer.

    Weights live in local SRAM (loaded once at deployment — amortized out of
    steady-state inference, paper §1 'fit all parameters on-chip'); every
    event reads k²/s² weight vectors and read-modify-writes k²/s²·c_out
    accumulators; non-events cost nothing.
    """
    events = shape.inputs * d_act
    reuse = shape.k ** 2 / shape.stride ** 2
    macs = events * reuse * shape.out_ch
    counts = dict(
        dram=0,                                     # no steady-state DRAM
        sram=events * reuse * shape.out_ch,         # weight vector reads
        buf32=2 * macs / 27,                        # accum vector r/w bursts
        reg=macs,
        mac=macs)
    return _energy(counts, e)


def compare_dataflows(shape: ConvShape, d_act: float, d_w: float = 1.0):
    return dict(
        ws=dataflow_energy(shape, "ws", d_act, d_w),
        inp=dataflow_energy(shape, "is", d_act, d_w),
        os=dataflow_energy(shape, "os", d_act, d_w),
        mnf=mnf_energy(shape, d_act, d_w),
    )
