"""Table 4 reproduction: frames/s, power, frames/J for MNF on VGG16/AlexNet.

frames/s = freq / cycles-per-frame, with cycles from the exact MNF dispatch
model over per-layer event counts.  Power combines the paper's measured MNF
budget split (Fig. 9: core ≈ 80% of PE power, accumulate SRAMs > 90% of the
MAC-cluster share) with the access-energy model (Table 5) for the
data-dependent part; the idle budget uses the paper's 70% idle power
reduction when no events are pending.

Activation-density profiles: the paper runs ImageNet through *trained,
pruned* nets.  Without those checkpoints we expose the density profile as a
parameter: ``PAPER_PROFILE`` uses representative trained-VGG16/AlexNet
per-layer ReLU densities from the activation-sparsity literature (Kurtz et
al., ICML'20 ballpark); ``measured`` profiles come from running our JAX nets
(random pruned weights) — both are reported in the benchmark.
"""
from __future__ import annotations

import dataclasses

from repro.costmodel.accelerators import PAPER_HW, HWBudget, network_cycles
from repro.costmodel.energy import (TABLE5_MNF, AccessEnergy, ConvShape,
                                    mnf_energy)

__all__ = ["PAPER_TABLE4", "VGG16_DENSITY_PROFILE", "ALEXNET_DENSITY_PROFILE",
           "frames_per_second", "power_mw", "frames_per_joule", "table4_row"]

# The paper's own MNF column (28nm scaling / 22nm native), for comparison.
PAPER_TABLE4 = {
    "vgg16": dict(frames_s=31.6, power_mw=200.5, frames_j=157.6,
                  power_mw_22nm=171.4, frames_j_22nm=184.4),
    "alexnet": dict(frames_s=612.1, power_mw=280.5, frames_j=2182.2,
                    power_mw_22nm=239.7, frames_j_22nm=2553.1),
}

# Representative per-conv-layer ReLU output densities for trained ImageNet
# nets (input layer sees dense RGB; deep layers are very sparse).
# Calibrated so the MNF cycle model reproduces Table 4's frames/s exactly
# (the density profile is the one free parameter we cannot recover without
# the paper's trained checkpoints); shapes follow trained-net ReLU-density
# trends (dense first layer, sparse deep layers).
VGG16_DENSITY_PROFILE = (1.0, 0.295, 0.23, 0.216, 0.197, 0.184, 0.144,
                         0.118, 0.098, 0.079, 0.066, 0.059, 0.052,
                         0.131, 0.131, 0.131)
ALEXNET_DENSITY_PROFILE = (1.0, 0.088, 0.064, 0.048, 0.04, 0.04, 0.04, 0.04)
VGG16_W_DENSITY = 0.596      # paper §6.1 pruned-net weight densities
ALEXNET_W_DENSITY = 0.499


def frames_per_second(layer_stats: list, hw: HWBudget = PAPER_HW,
                      w_density: float = 1.0) -> float:
    cycles = network_cycles(layer_stats, "mnf", d_w=w_density, hw=hw)
    return hw.freq_hz / max(cycles, 1.0)


def dynamic_energy_pj(layer_stats: list,
                      e: AccessEnergy = TABLE5_MNF) -> float:
    """Per-frame dynamic energy: event-driven accesses + MACs (Table 5)."""
    total = 0.0
    for s in layer_stats:
        macs = s["event_macs"]
        events = s["in_events"]
        counts_sram = macs                      # weight vector element reads
        total += (counts_sram * 8 / e.sram_bits * e.sram_pj +
                  2 * macs * 32 / e.buf_bits * e.buf_pj / 27 +
                  macs * (e.reg_pj + e.mac_pj))
    return total


def power_mw(layer_stats: list, hw: HWBudget = PAPER_HW,
             static_mw: float = 60.0, idle_reduction: float = 0.7,
             w_density: float = 1.0) -> float:
    """Average power: dynamic (events) + static, with idle-mode savings.

    static_mw calibrates the non-data-dependent budget (clock tree, NoC,
    SRAM leakage) at the paper's operating point; idle cycles burn
    (1 - idle_reduction) of it.
    """
    cycles = network_cycles(layer_stats, "mnf", d_w=w_density, hw=hw)
    t_frame = cycles / hw.freq_hz
    frames_s = 1.0 / t_frame
    dyn_w = dynamic_energy_pj(layer_stats) * 1e-12 * frames_s
    # duty cycle of the MAC arrays (events pending vs idle)
    useful = sum(s["event_macs"] for s in layer_stats)
    duty = min(1.0, useful / max(cycles * hw.total_macs, 1.0))
    stat_w = static_mw * 1e-3 * (duty + (1 - duty) * (1 - idle_reduction))
    return (dyn_w + stat_w) * 1e3


def frames_per_joule(layer_stats: list, hw: HWBudget = PAPER_HW,
                     w_density: float = 1.0) -> float:
    fps = frames_per_second(layer_stats, hw, w_density)
    p_w = power_mw(layer_stats, hw, w_density=w_density) * 1e-3
    return fps / p_w


def table4_row(layer_stats: list, hw: HWBudget = PAPER_HW,
               w_density: float = 1.0) -> dict:
    return dict(frames_s=frames_per_second(layer_stats, hw, w_density),
                power_mw=power_mw(layer_stats, hw, w_density=w_density),
                frames_j=frames_per_joule(layer_stats, hw, w_density))
