from repro.costmodel.accelerators import (PAPER_HW, UTIL_CURVES, HWBudget,
                                          baseline_layer_cycles,
                                          dense_layer_cycles,
                                          mnf_layer_cycles, mnf_utilization,
                                          network_cycles)
from repro.costmodel.energy import (TABLE1, TABLE5_MNF, TABLE5_OTHERS,
                                    AccessEnergy, ConvShape,
                                    compare_dataflows, dataflow_energy,
                                    mnf_energy)
from repro.costmodel.table4 import (ALEXNET_DENSITY_PROFILE, PAPER_TABLE4,
                                    VGG16_DENSITY_PROFILE, frames_per_joule,
                                    frames_per_second, power_mw, table4_row)
from repro.costmodel.utilization import (mnf_utilization_at_density,
                                         snap_utilization_at_density,
                                         utilization_sweep)

__all__ = [
    "PAPER_HW", "UTIL_CURVES", "HWBudget", "baseline_layer_cycles",
    "dense_layer_cycles", "mnf_layer_cycles", "mnf_utilization",
    "network_cycles", "TABLE1", "TABLE5_MNF", "TABLE5_OTHERS",
    "AccessEnergy", "ConvShape", "compare_dataflows", "dataflow_energy",
    "mnf_energy", "ALEXNET_DENSITY_PROFILE", "PAPER_TABLE4",
    "VGG16_DENSITY_PROFILE", "frames_per_joule", "frames_per_second",
    "power_mw", "table4_row", "mnf_utilization_at_density",
    "snap_utilization_at_density", "utilization_sweep",
]
