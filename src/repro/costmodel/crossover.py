"""Occupancy-adaptive routing: the event-vs-dense crossover model
(DESIGN.md §11).

The MNF paper's utilization argument cuts both ways: event-driven compute
wins only while activation sparsity is high enough that the skipped work
outweighs per-event overhead.  Our bench confirms the event path is not a
universal win on this harness (conv_fused 0.52x at 1x1/stride-2, pallas
chained linear 0.87x at full occupancy).  This module decides, **per layer
boundary and at trace time**, whether the engine should consume the
incoming ``EventStream`` on its event path or densify and run the dense
dispatch — plus the cost estimates every decision records.

Two cost sources, in authority order:

  * **Measured crossover table** — ``kind == "crossover"`` entries in
    BENCH_engine.json (written by ``kernel_bench.py --sweep``): per
    (boundary kind, backend, shape class) the measured per-route
    microseconds over an occupancy sweep.  Lookups interpolate
    piecewise-linearly between occupancy anchors (the idiom of
    ``accelerators.UTIL_CURVES``) and fall back from the exact shape class
    to the (boundary, backend) aggregate to the boundary aggregate.
  * **Analytic seed** — the paper-calibrated cycle models
    (``mnf_layer_cycles`` / ``dense_layer_cycles``): used when no table
    covers the boundary, and always used to fill the ``est_event_cost`` /
    ``est_dense_cost`` trace fields so decisions stay explainable even
    when the table drove them.

Decisions are **compile-time static**: every input (occupancy hint,
geometry, table) is a trace-time Python value — ``EventStream.occupancy()``
is a traced array and is deliberately *not* consulted, so one compiled
boundary has exactly one route and jit caching cannot flip it
(DESIGN.md §11).

``ROUTE_HYSTERESIS`` is the stated tolerance band of the CI smoke gate: a
route is "against the table" only when the measured event/dense ratio at
its occupancy leaves the [1/(1+h), 1+h] band *and* the chosen route sits on
the losing side.  Decisions themselves take the argmin — the band only
keeps near-crossover boundaries from flapping CI on timing noise.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.costmodel.accelerators import (PAPER_HW, dense_layer_cycles,
                                          mnf_layer_cycles)

__all__ = ["ROUTE_HYSTERESIS", "EVENT_ROUTES", "RouteDecision",
           "boundary_costs", "CrossoverTable", "linear_shape_class",
           "load_crossover_table", "set_active_table", "active_table",
           "decide_route", "route_conflicts"]


def linear_shape_class(m: int, k: int, n: int) -> str:
    """Shape class of an FC boundary for crossover curves.

    Keyed on the output width and a power-of-two K bucket: N fixes the
    weight tile the event matmul streams, K's magnitude fixes how many
    K-blocks one row can touch, and batch M scales both paths linearly —
    so boundaries of one (N, K-bucket) family share a measured crossover
    curve, and the conv→FC seam's K = H·W·C lands in the same family
    whatever the batch.  Used by ``engine.route_linear``, the model
    boundary summaries, and the ``kernel_bench --sweep`` calibration
    entries, so lookups always hit the curves the sweep wrote.
    """
    kb = 1 << max(int(k) - 1, 0).bit_length()
    return f"n{n}kb{kb}"

#: Stated hysteresis margin of the route-vs-table CI gate (fractional band
#: around ratio 1.0).  25% absorbs harness timing noise near the crossover
#: while still catching a route that is wrong by more than it could ever
#: recover.
ROUTE_HYSTERESIS = 0.25

#: Route labels the engine can record.  "strip"/"pixel"/"window"/"event"
#: are event-path flavors (the stream is consumed); "dense" consumes the
#: dense twin (or decodes, visibly) and runs the dense dispatch.
EVENT_ROUTES = ("strip", "pixel", "window", "event")

#: Per-launch overhead of the event path, in model cycles: dispatch /
#: gather bookkeeping a dense dispatch does not pay.  Calibrated to the CPU
#:  harness order of magnitude (one launch ~ one small dense tap); only the
#: *seed* model uses it — measured tables carry real overheads implicitly.
LAUNCH_OVERHEAD_CYCLES = 64.0


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One boundary's routing decision plus the estimates that explain it.

    route:          chosen route label ("dense" or an event flavor).
    est_event_cost: estimated event-path cost (model cycles — the analytic
                    seed, always filled, even when the table decided).
    est_dense_cost: estimated dense-path cost (model cycles).
    occupancy:      the static occupancy the decision was made at.
    ratio:          event/dense cost ratio that drove the decision (table
                    ratio when available, else est_event/est_dense).
    source:         "forced" | "geometry" | "table" | "model".
    """

    route: str
    est_event_cost: float
    est_dense_cost: float
    occupancy: float
    ratio: float
    source: str

    @property
    def is_event(self) -> bool:
        return self.route in EVENT_ROUTES


def boundary_costs(kind: str, occupancy: float, *, dense_macs: float,
                   avg_touched: float, c_out: int,
                   hw=PAPER_HW) -> tuple[float, float]:
    """Analytic (event_cycles, dense_cycles) seed for one boundary.

    ``dense_macs`` is the dense dispatch's work (window reads for a pool);
    the event side scales it by occupancy through the paper's cycle model:
    at occupancy 1 the event path does the dense work *divided by its
    channel-remainder utilization* — slightly worse than dense, which is
    exactly the measured full-density behaviour the sweep confirms.
    """
    occ = min(max(float(occupancy), 0.0), 1.0)
    in_elems = dense_macs / max(avg_touched * c_out, 1e-9)
    ev = mnf_layer_cycles(occ * in_elems, avg_touched, c_out, hw)
    return ev + LAUNCH_OVERHEAD_CYCLES, dense_layer_cycles(dense_macs, hw)


class CrossoverTable:
    """Measured event-vs-dense ratios, occupancy-interpolated.

    Built from ``kind == "crossover"`` BENCH entries, each::

        {"kind": "crossover", "boundary": "conv"|"pool"|"linear",
         "backend": "block", "shape_class": "k3s1", "occupancy": 0.43,
         "sparsity": 0.5, "us": {"strip": 12.3, "pixel": 30.1, "dense": 9.8}}

    ``ratio()`` returns (event route us) / (dense us) at the queried
    occupancy, interpolating between the two nearest measured anchors and
    clamping outside the measured range.  Curves are kept per event
    *flavor* (strip/pixel/window/event) plus a flavor-blind best-event
    aggregate; a lookup with ``flavor=`` prefers its flavor's curve —
    the achievable flavor is granularity-bound, so a strip-granular
    boundary must be judged on strip time even when the pixel path is
    faster.  Keys fall back most-specific first: (boundary, backend,
    shape_class) -> (boundary, backend) -> (boundary,); aggregates
    average the ratios of their member entries at each anchor.
    """

    def __init__(self, entries: list[dict]):
        self._curves: dict[tuple, list[tuple[float, float]]] = {}
        buckets: dict[tuple, dict[float, list[float]]] = {}
        for e in entries:
            if e.get("kind") != "crossover":
                continue
            us = e.get("us") or {}
            dense = us.get("dense")
            flavors = {r: v for r, v in us.items()
                       if r in EVENT_ROUTES and v is not None}
            if not dense or not flavors:
                continue
            # One curve per event flavor plus the flavor-blind best (None):
            # the achievable flavor is granularity-bound (a strip stream
            # can only ride the strip kernel), so a decision must compare
            # *its* flavor against dense — on a backend where one flavor is
            # a slow correctness twin, the min would misroute it.
            ratios = {None: min(flavors.values()) / dense}
            ratios.update({r: v / dense for r, v in flavors.items()})
            occ = float(e.get("occupancy", 1.0))
            keys = [(e.get("boundary"),)]
            if e.get("backend"):
                keys.append((e.get("boundary"), e.get("backend")))
                if e.get("shape_class"):
                    keys.append((e.get("boundary"), e.get("backend"),
                                 e.get("shape_class")))
            for key in keys:
                for flavor, ratio in ratios.items():
                    buckets.setdefault((key, flavor), {}).setdefault(
                        round(occ, 6), []).append(ratio)
        for key, anchors in buckets.items():
            self._curves[key] = sorted(
                (occ, sum(rs) / len(rs)) for occ, rs in anchors.items())

    def __len__(self) -> int:
        return len(self._curves)

    def ratio(self, boundary: str, occupancy: float, *,
              backend: str | None = None,
              shape_class: str | None = None,
              flavor: str | None = None) -> float | None:
        """Interpolated event/dense time ratio; None = no coverage.

        ``flavor`` conditions the lookup on the event flavor the caller
        can actually take ("strip"/"pixel"/"window"/"event"); per key the
        flavor-specific curve wins over the flavor-blind aggregate."""
        for key in ((boundary, backend, shape_class),
                    (boundary, backend), (boundary,)):
            if None in key[1:]:
                continue
            for fl in ((flavor, None) if flavor is not None else (None,)):
                curve = self._curves.get((key, fl))
                if curve:
                    return _interp(curve, float(occupancy))
        for fl in ((flavor, None) if flavor is not None else (None,)):
            curve = self._curves.get(((boundary,), fl))
            if curve:
                return _interp(curve, float(occupancy))
        return None


def _interp(curve: list[tuple[float, float]], x: float) -> float:
    if x <= curve[0][0]:
        return curve[0][1]
    for i in range(1, len(curve)):
        if x <= curve[i][0]:
            x0, y0 = curve[i - 1]
            x1, y1 = curve[i]
            t = (x - x0) / max(x1 - x0, 1e-12)
            return y0 + t * (y1 - y0)
    return curve[-1][1]


def load_crossover_table(path: str) -> CrossoverTable:
    """Table from a BENCH_engine.json file (empty table if absent).

    Accepts either the raw entry list or the benchmark file's
    ``{"device": ..., "entries": [...]}`` wrapper.
    """
    if not os.path.exists(path):
        return CrossoverTable([])
    with open(path) as f:
        data = json.load(f)
    entries = data.get("entries", []) if isinstance(data, dict) else data
    return CrossoverTable(entries)


#: Process-global calibrated table consulted by adaptive dispatch.  The
#: engine never reads files implicitly — benchmarks / serving install the
#: table they loaded; None = analytic seed only.
_ACTIVE_TABLE: CrossoverTable | None = None


def set_active_table(table: CrossoverTable | None) -> CrossoverTable | None:
    """Install (or clear) the process-global table; returns the previous."""
    global _ACTIVE_TABLE
    prev = _ACTIVE_TABLE
    _ACTIVE_TABLE = table
    return prev


def active_table() -> CrossoverTable | None:
    return _ACTIVE_TABLE


def decide_route(mode: str, boundary: str, *, occupancy: float | None,
                 event_route: str | None, dense_macs: float,
                 avg_touched: float, c_out: int, backend: str | None = None,
                 shape_class: str | None = None,
                 table: CrossoverTable | None = None) -> RouteDecision:
    """The one routing decision point (engine.api calls this per boundary).

    mode:        EngineConfig.route — "auto" (geometry-static event-first,
                 the pre-adaptive behaviour), "adaptive", or a forced label
                 ("dense" / "event" / "strip" / "pixel" / "window").
    occupancy:   static occupancy hint (None = assume full occupancy 1.0
                 for estimates; "auto" mode never routes on it).
    event_route: the event flavor geometry dispatch would take (None =
                 no event path exists; the decision is "dense" whatever
                 the mode — the visible-fallback case).
    """
    occ = 1.0 if occupancy is None else min(max(float(occupancy), 0.0), 1.0)
    est_ev, est_de = boundary_costs(boundary, occ, dense_macs=dense_macs,
                                    avg_touched=avg_touched, c_out=c_out)
    tab = table if table is not None else _ACTIVE_TABLE
    flavor = event_route if event_route in EVENT_ROUTES else None
    t_ratio = tab.ratio(boundary, occ, backend=backend,
                        shape_class=shape_class,
                        flavor=flavor) if tab else None
    ratio = t_ratio if t_ratio is not None else est_ev / max(est_de, 1e-12)
    if event_route is None:
        route, source = "dense", "geometry"
    elif mode == "auto":
        route, source = event_route, "geometry"
    elif mode == "adaptive":
        route = "dense" if ratio > 1.0 else event_route
        source = "table" if t_ratio is not None else "model"
    else:                                   # forced
        route = event_route if mode == "event" else mode
        source = "forced"
    return RouteDecision(route=route, est_event_cost=est_ev,
                         est_dense_cost=est_de, occupancy=occ,
                         ratio=float(ratio), source=source)


def route_conflicts(records: list[dict], table: CrossoverTable, *,
                    hysteresis: float = ROUTE_HYSTERESIS) -> list[dict]:
    """Routes that contradict the calibrated table beyond the hysteresis.

    The CI smoke gate: for every boundary record carrying a route and an
    occupancy, look up the measured event/dense ratio; a record routed onto
    the event path while the table says dense wins by more than the band
    (ratio > 1 + h), or routed dense while events win by more than the band
    (ratio < 1 / (1 + h)), is a conflict.  Records the table does not cover
    are never conflicts (the analytic seed owns them).
    """
    out = []
    for r in records:
        route = r.get("route")
        if route is None or r.get("occupancy") is None:
            continue
        boundary = {"conv2d": "conv", "maxpool2d": "pool",
                    "linear": "linear"}.get(r.get("op"))
        if boundary is None:
            continue
        event_taken = route in EVENT_ROUTES
        ratio = table.ratio(boundary, float(r["occupancy"]),
                            backend=r.get("backend"),
                            shape_class=r.get("shape_class"),
                            flavor=route if event_taken else None)
        if ratio is None:
            continue
        if (event_taken and ratio > 1.0 + hysteresis) or \
                (not event_taken and not r.get("fallback_decode")
                 and ratio < 1.0 / (1.0 + hysteresis)):
            out.append(dict(r, table_ratio=ratio))
    return out
