"""Multiplier utilization vs density (Fig. 2): MNF vs SNAP.

MNF utilization comes from the exact dispatch model (accelerators.py): every
event drives a dense burst across all multipliers, so utilization is ~100%
at every density — the only loss is the channel remainder when c_out is not
a multiple of the multipliers covering it (the paper's stated explanation
for Fig. 2's small ripples).

SNAP's curve uses its published utilization behaviour (this paper §3.2: AIM
pair matching starves the array as sparsity grows; <75% beyond 50%).
"""
from __future__ import annotations

from repro.costmodel.accelerators import (PAPER_HW, UTIL_CURVES, HWBudget,
                                          mnf_layer_cycles)

__all__ = ["mnf_utilization_at_density", "snap_utilization_at_density",
           "utilization_sweep"]


def mnf_utilization_at_density(density: float, *, c_out: int = 384,
                               k: int = 3, in_elems: int = 56 * 56 * 256,
                               hw: HWBudget = PAPER_HW) -> float:
    """Utilization of the multiplier array at a given activation density."""
    n_events = max(density * in_elems, 1.0)
    avg_touched = float(k * k)          # stride-1 interior pixels
    useful = n_events * avg_touched * c_out
    cycles = mnf_layer_cycles(n_events, avg_touched, c_out, hw)
    return min(1.0, useful / (cycles * hw.total_macs))


def snap_utilization_at_density(density: float, w_density: float = 0.6
                                ) -> float:
    sparsity = 1.0 - density * w_density
    return UTIL_CURVES["snap"](sparsity)


def utilization_sweep(densities=(1.0, 0.8, 0.6, 0.4, 0.2, 0.1, 0.05),
                      c_out: int = 384):
    rows = []
    for d in densities:
        rows.append(dict(density=d,
                         mnf=mnf_utilization_at_density(d, c_out=c_out),
                         snap=snap_utilization_at_density(d)))
    return rows
