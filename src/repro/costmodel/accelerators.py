"""Cycle models: MNF and the baselines it is compared against (Fig. 8).

All accelerators are normalized to the paper's hardware budget (Table 3:
11 PEs × 27 multipliers = 297 MACs @ 200 MHz) — the paper does the same
("we estimated the number of cycles ... using the same hardware
configuration").

MNF cycle model (exact, from §5.2.3's dispatch):
  * an event is broadcast to all PEs; output channels are striped across
    PEs; each PE covers its channel slice with mult-per-MAC-module
    multipliers per filter position per cycle;
  * cycles per event = ceil(channels_per_pe / mults_per_module) — the
    channel-remainder effect is exactly Fig. 2's "utilization is slightly
    different between density levels because the number of channels is not
    always a multiple of the MACs available".

Baseline models use each design's published work formulation (which
sparsity it exploits) and the utilization-vs-sparsity behaviour this paper
reports for them in §1/§3 (SNAP <75% beyond 50% sparsity, SCNN <60% beyond
60%, GoSPA <45% at 90%), interpolated piecewise-linearly between published
anchor points.  Fig. 8's absolute baseline cycles also include each design's
front-end stalls (identification of valid pairs); we fold those into an
efficiency constant calibrated once against Fig. 8's VGG16 ratios and then
*held fixed* for AlexNet (the cross-workload check).
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["HWBudget", "PAPER_HW", "mnf_layer_cycles", "mnf_utilization",
           "dense_layer_cycles", "baseline_layer_cycles", "UTIL_CURVES",
           "network_cycles"]


@dataclasses.dataclass(frozen=True)
class HWBudget:
    pes: int = 11
    mac_modules_per_pe: int = 9      # filter positions processed in parallel
    mults_per_module: int = 3        # channels processed per module per cycle
    freq_hz: float = 200e6

    @property
    def total_macs(self) -> int:
        return self.pes * self.mac_modules_per_pe * self.mults_per_module


PAPER_HW = HWBudget()


# ---------------------------------------------------------------------------
# MNF
# ---------------------------------------------------------------------------

def mnf_channel_util(c_out: int, w_density: float = 1.0,
                     hw: HWBudget = PAPER_HW) -> float:
    """Multiplier utilization from the channel remainder (Fig. 2 ripples).

    Each MAC module sweeps mults_per_module channels per cycle; the last
    sweep of a channel slice is partially filled when the (compressed)
    channel count is not a multiple of the module width.
    """
    c_eff = max(c_out * w_density, 1.0)
    per_pe = max(math.ceil(c_eff / hw.pes), 1)
    swept = math.ceil(per_pe / hw.mults_per_module) * hw.mults_per_module
    return per_pe / swept


def mnf_layer_cycles(n_events: float, avg_touched: float, c_out: int,
                     hw: HWBudget = PAPER_HW, w_density: float = 1.0
                     ) -> float:
    """Cycles for one Conv/FC layer.

    n_events: input events fired into the layer (non-zero activations).
    avg_touched: mean filter positions each event updates (k·k/stride² area,
                 Algorithm 1's walk length; 1 for FC).
    c_out: output channels (FC: output neurons treated as channels).
    w_density: fraction of non-zero weights.  Table 4's MNF throughput
        arithmetic (frames/s × 297 MACs vs. dense MACs/frame) implies the
        multiply phase streams *compressed* weight vectors — pruned-away
        weights occupy no multiplier slots — so work scales with w_density.
        (Table 2 lists only "activation driven"; we flag this inference in
        EXPERIMENTS.md.)

    The OFM is spatially partitioned across PEs (§5.3: neurons of a layer
    are striped over the accumulate SRAMs), so *distinct events proceed in
    parallel on distinct PEs* — throughput is work-limited at the full MAC
    array width, degraded only by the channel-remainder utilization.
    """
    work = n_events * avg_touched * c_out * w_density
    util = mnf_channel_util(c_out, w_density, hw)
    return work / (hw.total_macs * util)


def mnf_utilization(n_events: float, avg_touched: float, c_out: int,
                    useful_macs: float, hw: HWBudget = PAPER_HW) -> float:
    cycles = mnf_layer_cycles(n_events, avg_touched, c_out, hw)
    if cycles == 0:
        return 1.0
    return min(1.0, useful_macs / (cycles * hw.total_macs))


def dense_layer_cycles(dense_macs: float, hw: HWBudget = PAPER_HW) -> float:
    """Ideal dense engine at full utilization (lower bound reference)."""
    return dense_macs / hw.total_macs


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def _piecewise(points):
    xs = [p[0] for p in points]
    ys = [p[1] for p in points]

    def f(x):
        if x <= xs[0]:
            return ys[0]
        for i in range(1, len(xs)):
            if x <= xs[i]:
                t = (x - xs[i - 1]) / (xs[i] - xs[i - 1])
                return ys[i - 1] + t * (ys[i] - ys[i - 1])
        return ys[-1]

    return f


# utilization as a function of combined sparsity (1 - d_act*d_w), anchored
# on the figures this paper quotes for each design (§1, §3).
UTIL_CURVES = {
    # SCNN: "<60% with sparsity of more than 60%"
    "scnn": _piecewise([(0.0, 0.92), (0.6, 0.60), (0.9, 0.35), (1.0, 0.2)]),
    # SparTen: between SCNN and GoSPA (MICRO'19 reports ~0.6-0.8 mid range)
    "sparten": _piecewise([(0.0, 0.95), (0.5, 0.80), (0.9, 0.45), (1.0, 0.3)]),
    # GoSPA: "<45% with a sparsity of 90%"
    "gospa": _piecewise([(0.0, 0.95), (0.5, 0.78), (0.9, 0.45), (1.0, 0.35)]),
    # SNAP (Fig 2 comparison): "<75% beyond 50% sparsity"
    "snap": _piecewise([(0.0, 0.98), (0.5, 0.75), (0.75, 0.55), (1.0, 0.35)]),
}

# Front-end pipeline efficiency (valid-pair identification, output scatter
# contention) — one constant per design, calibrated on Fig 8 VGG16 and held
# for AlexNet.  SCNN-Dense runs the dense workload through SCNN's cartesian
# tiling (its N×N array maps poorly to dense conv — the paper's 19× anchor).
# Calibrated once against Fig. 8's VGG16 ratios (19.0/8.31/3.15/2.57x) and
# then held fixed; the AlexNet column is evaluated held-out (reproduced to
# 9-16% for the sparse designs; SCNN-Dense overshoots — see EXPERIMENTS.md).
FRONTEND_EFF = {
    "scnn_dense": 0.4708,
    "scnn": 0.2760,
    "sparten": 0.5638,
    "gospa": 0.6819,
}


def baseline_layer_cycles(design: str, dense_macs: float, d_act: float,
                          d_w: float, hw: HWBudget = PAPER_HW) -> float:
    """Cycles for one layer on a baseline design.

    d_act/d_w: activation/weight densities in [0, 1].
    """
    if design == "scnn_dense":
        work = dense_macs                     # no sparsity exploited
        util = UTIL_CURVES["scnn"](0.0) * FRONTEND_EFF["scnn_dense"]
    else:
        work = dense_macs * d_act * d_w       # intersection designs
        sparsity = 1.0 - d_act * d_w
        util = UTIL_CURVES[design](sparsity) * FRONTEND_EFF[design]
    return work / (hw.total_macs * util)


def network_cycles(layer_stats: list, design: str, d_w: float = 1.0,
                   hw: HWBudget = PAPER_HW) -> float:
    """Total cycles over per-layer stats dicts (from models.cnn.run_with_stats).

    For MNF the stats carry exact event counts; baselines use density.
    """
    total = 0.0
    for s in layer_stats:
        d_act = s["in_events"] / max(s["in_elems"], 1)
        if design == "mnf":
            total += mnf_layer_cycles(s["in_events"],
                                      max(s["avg_touched"], 1.0),
                                      s["c_out"], hw, w_density=d_w)
        elif design == "dense_ideal":
            total += dense_layer_cycles(s["dense_macs"], hw)
        else:
            total += baseline_layer_cycles(design, s["dense_macs"], d_act,
                                           d_w, hw)
    return total
