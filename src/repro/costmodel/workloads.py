"""Analytic per-layer stats for the paper's workloads at a density profile.

Mirrors the schema of models.cnn.run_with_stats (dense_macs, event_macs,
in_events, in_elems, c_out, avg_touched) but computes counts from layer
shapes × a per-layer activation-density profile — so the full 224×224
VGG16/AlexNet accounting runs instantly on CPU.  The measured path
(run_with_stats on the JAX net) cross-checks this model in tests at reduced
resolution.
"""
from __future__ import annotations

from repro.core.mnf_conv import conv_out_size
from repro.models.cnn import CNNSpec, ConvSpec, FCSpec, PoolSpec, _trace_shapes

__all__ = ["analytic_network_stats"]


def analytic_network_stats(spec: CNNSpec, density_profile) -> list:
    """density_profile: per-compute-layer INPUT activation density."""
    shapes = _trace_shapes(spec)
    stats = []
    li = 0
    for i, layer in enumerate(spec.layers):
        h, w, c = shapes[i]
        if isinstance(layer, PoolSpec):
            continue
        d = density_profile[min(li, len(density_profile) - 1)]
        if isinstance(layer, ConvSpec):
            oy = conv_out_size(h, layer.k, layer.stride, layer.padding)
            ox = conv_out_size(w, layer.k, layer.stride, layer.padding)
            dense = oy * ox * layer.k ** 2 * c * layer.out_ch
            in_elems = h * w * c
            events = in_elems * d
            # interior pixels touch (k/s)² outputs; borders fewer — use the
            # exact mean = dense/(in_elems·c_out) when density is uniform.
            avg_touched = dense / (in_elems * layer.out_ch)
            stats.append(dict(kind="conv", dense_macs=float(dense),
                              event_macs=float(events * avg_touched *
                                               layer.out_ch),
                              in_events=float(events),
                              in_elems=float(in_elems), c_out=layer.out_ch,
                              avg_touched=float(avg_touched),
                              out_density=density_profile[
                                  min(li + 1, len(density_profile) - 1)]))
        elif isinstance(layer, FCSpec):
            in_elems = h * w * c
            events = in_elems * d
            stats.append(dict(kind="fc",
                              dense_macs=float(in_elems * layer.out),
                              event_macs=float(events * layer.out),
                              in_events=float(events),
                              in_elems=float(in_elems), c_out=layer.out,
                              avg_touched=1.0,
                              out_density=density_profile[
                                  min(li + 1, len(density_profile) - 1)]))
        li += 1
    return stats
