from repro.parallel.pipeline import pipeline_apply
from repro.parallel.sharding import (ShardingRules, logical_to_pspec,
                                     make_rules, make_sharder,
                                     mesh_axis_size, named_sharding_tree)

__all__ = ["pipeline_apply", "ShardingRules", "logical_to_pspec",
           "make_rules", "make_sharder", "mesh_axis_size",
           "named_sharding_tree"]
