"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis (shard_map).

An optional distribution mode for very deep stacks at >512-chip scale: the
layer stack splits into ``n_stages`` contiguous stages; microbatches stream
through stages with ``jax.lax.ppermute`` moving activations stage-to-stage.
The steady-state schedule overlaps stage compute with neighbor transfers
(the decoupled access/execute discipline of the paper's PE, lifted to the
inter-chip level).

The production dry-run mesh uses DP×TP (no pipe axis); this module is
exercised by its own small-mesh tests and is selectable from the launcher
via --pipeline.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn: Callable, stage_params, x: jax.Array, *,
                   mesh: Mesh, axis: str = "pipe",
                   n_microbatches: int | None = None) -> jax.Array:
    """Run ``y = stages(x)`` with each stage on one slice of ``axis``.

    stage_fn(params_slice, microbatch) -> microbatch (same shape).
    stage_params: pytree with leading dim == n_stages (one slice per stage).
    x: (n_micro, mb, ...) pre-split microbatches.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0] if n_microbatches is None else n_microbatches
    assert x.shape[0] == n_micro

    def per_stage(params_local, x_local):
        # params_local: (1, ...) slice; x_local: (n_micro, mb, ...) only
        # meaningful on stage 0 at t=0.
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        total_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x_local[0])
        outs = jnp.zeros_like(x_local)

        def tick(t, carry):
            buf, outs = carry
            # Stage 0 ingests microbatch t (if any) — others take the
            # neighbor's output from the previous tick (already in buf).
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jax.lax.dynamic_index_in_dim(x_local, mb_idx, axis=0,
                                                  keepdims=False)
            cur = jnp.where(stage == 0,
                            jnp.where(t < n_micro, inject, jnp.zeros_like(buf)),
                            buf)
            live = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(params_local, cur)
            y = jnp.where(live, y, jnp.zeros_like(y))
            # Last stage records its completed microbatch.
            out_idx = jnp.clip(t - stage, 0, n_micro - 1)
            rec = jnp.where(live & (stage == n_stages - 1), y, 0.0)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jax.lax.dynamic_index_in_dim(
                    outs, out_idx, 0, keepdims=False) + rec, out_idx, 0)
            # Shift activations to the next stage.
            buf = jax.lax.ppermute(
                y, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, total_ticks, tick, (buf, outs))
        # Only the last stage holds real outputs; psum broadcasts them
        # (all other stages contribute zeros).
        return jax.lax.psum(outs, axis)

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = jax.shard_map(per_stage, mesh=mesh,
                       in_specs=(spec_params, P()), out_specs=P(),
                       check_vma=False)
    return fn(stage_params, x)
