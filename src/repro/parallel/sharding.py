"""Logical-axis sharding rules (MaxText-style) -> NamedSharding resolution.

Every param leaf carries a tuple of logical axis names (see
models/param_utils).  A rule table maps logical names to mesh axes; the
resolver drops any assignment that fails divisibility or would reuse a mesh
axis already consumed by an earlier dim of the same leaf — this is what lets
one rule table serve all 40 heterogeneous (arch × shape) cells without
GSPMD padding surprises (e.g. qwen2's 12 heads are not 16-way shardable; its
ff=8960 is).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "make_rules", "logical_to_pspec",
           "named_sharding_tree", "make_sharder", "mesh_axis_size",
           "abstract_mesh_compat", "data_axis_size", "serve_batch_pspec",
           "shard_map_compat"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (str), tuple of axes, or None."""

    table: dict

    def get(self, name: Optional[str]):
        if name is None:
            return None
        return self.table.get(name)


def make_rules(mesh: Mesh, *, fsdp: bool = False,
               seq_shard: bool = False, overrides: dict | None = None
               ) -> ShardingRules:
    """Default rule table for a ("pod"?, "data", "model") mesh."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    table = {
        "batch": dp,
        "seq": "model" if seq_shard else None,
        "attn_seq": "model",         # SP fallback inside attention when
                                     # heads don't divide the model axis
        "cache_seq": "model",        # decode caches: shard time over model
        "vocab": "model",
        "embed": "data" if fsdp else None,   # FSDP/ZeRO param+opt sharding
        "ff": "model",
        "ff_expert": None,
        "experts": "model",          # expert parallelism
        "q_heads": "model",
        "kv_heads": "model",
        "kv_lora": None,
        "lora": None,
        "heads": "model",
        "layers": None,
    }
    if overrides:
        table.update(overrides)
    return ShardingRules(table)


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


# When several logical axes of one leaf map to the same mesh axis, assign in
# priority order (lower = first claim).  This is what makes the resolver pick
# head-sharding when heads divide the model axis and fall back to
# sequence-sharding (attn_seq) when they don't (e.g. qwen2's 12 heads on a
# 16-way model axis).
_PRIORITY = {
    "vocab": 0, "experts": 0, "ff": 0, "ff_expert": 0, "embed": 0,
    "batch": 0, "q_heads": 1, "kv_heads": 1, "heads": 1,
    "cache_seq": 2, "attn_seq": 3, "seq": 4,
}


def logical_to_pspec(axes: tuple, shape: tuple, mesh: Mesh,
                     rules: ShardingRules) -> P:
    """Resolve one leaf.  Divisibility-, reuse- and priority-checked."""
    n = len(axes)
    order = sorted(range(n), key=lambda i: (_PRIORITY.get(axes[i], 9), i))
    used: set = set()
    out = [None] * n
    for i in order:
        dim, name = shape[i], axes[i]
        mesh_ax = rules.get(name)
        if mesh_ax is None:
            continue
        ax_tuple = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        if any(a in used for a in ax_tuple):
            continue                 # mesh axis already consumed by this leaf
        if dim % mesh_axis_size(mesh, mesh_ax) != 0:
            continue                 # not divisible: keep replicated
        used.update(ax_tuple)
        out[i] = mesh_ax
    # Trailing Nones can be dropped (PartitionSpec convention).
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding_tree(specs, shapes, mesh: Mesh, rules: ShardingRules):
    """specs: logical-axes tree; shapes: matching ShapeDtypeStruct tree."""
    is_axes = lambda x: isinstance(x, tuple)

    def resolve(axes, sds):
        return NamedSharding(mesh, logical_to_pspec(tuple(axes), sds.shape,
                                                    mesh, rules))

    return jax.tree.map(resolve, specs, shapes, is_leaf=is_axes)


def abstract_mesh_compat(axis_sizes, axis_names) -> "jax.sharding.AbstractMesh":
    """``AbstractMesh`` across jax versions: 0.4.x takes one
    ``((name, size), ...)`` shape tuple, newer jax takes ``(sizes, names)``.
    Shape arithmetic only — no devices behind it, so rule-table resolution
    can be tested at any mesh size on a 1-device box."""
    import inspect as _inspect
    am = jax.sharding.AbstractMesh
    params = list(_inspect.signature(am.__init__).parameters)
    if params[1] == "shape_tuple":          # jax 0.4.x
        return am(tuple(zip(axis_names, axis_sizes)))
    return am(tuple(axis_sizes), tuple(axis_names))


def data_axis_size(mesh: Mesh) -> int:
    """Total data-parallel width of a ("pod"?, "data", ...) mesh."""
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.axis_names]))


def serve_batch_pspec(mesh: Mesh, batch: int, ndim: int = 4,
                      rules: ShardingRules | None = None) -> P:
    """Batch-leading activation PartitionSpec for a serve bucket.

    Resolves through the same rule table / divisibility logic as every
    other leaf in the repo: the leading axis shards over the data axes
    when ``batch`` divides them (bucket 1 on a multi-device mesh stays
    replicated instead of tripping pjit's divisibility check).
    """
    rules = rules or make_rules(mesh)
    axes = ("batch",) + (None,) * (ndim - 1)
    return logical_to_pspec(axes, (batch,) + (1,) * (ndim - 1), mesh, rules)


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across jax versions (top-level vs experimental API).

    Replication checking is disabled: the event pipeline's gather/segment
    ops predate rep rules on older jax, and the serving tier's out_specs
    never claim replication the body doesn't establish.
    """
    import inspect as _inspect
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    kw = {}
    params = _inspect.signature(sm).parameters
    for flag in ("check_rep", "check_vma"):
        if flag in params:
            kw[flag] = False
            break
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_sharder(mesh: Mesh, rules: ShardingRules):
    """Returns sc(x, logical_axes) for activation sharding constraints."""

    def sc(x, axes):
        pspec = logical_to_pspec(tuple(axes), x.shape, mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))

    return sc
