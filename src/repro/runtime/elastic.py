"""Elastic scaling: rebuild the mesh from surviving devices and reshard.

When a pod/host drops (or capacity grows), the controller calls
``elastic_remesh``: it picks the largest usable (data, model) factorization
of the surviving device count, rebuilds sharding rules, and re-places the
checkpointed state under the new mesh.  Because checkpoints store *logical*
shapes and shardings are re-resolved from logical axis specs, restore onto
any mesh is mechanical (checkpoint.restore(shardings=new)).

The data pipeline is stateless-resumable (batch = f(step, host)), so elastic
re-entry only needs the step counter.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from repro.parallel.sharding import (ShardingRules, make_rules,
                                     named_sharding_tree)

__all__ = ["choose_mesh_shape", "elastic_remesh", "reshard_tree"]


def choose_mesh_shape(n_devices: int, *, model_parallel: int = 16,
                      max_pod: int = 256) -> tuple:
    """Largest (pod, data, model) grid using <= n_devices devices.

    Keeps model-parallel fixed (weights must still fit) and gives the rest
    to data; drops stragglers that break divisibility.
    """
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    rest = n_devices // mp
    if rest > max_pod // mp and rest % 2 == 0:
        return (2, rest // 2, mp)
    return (rest, mp)


def elastic_remesh(n_devices: int, *, model_parallel: int = 16,
                   devices: Optional[Sequence] = None) -> Mesh:
    shape = choose_mesh_shape(n_devices, model_parallel=model_parallel)
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    devs = list(devices or jax.devices())[:math.prod(shape)]
    import numpy as np
    return Mesh(np.asarray(devs).reshape(shape), axes)


def reshard_tree(tree, specs, new_mesh: Mesh, *, fsdp: bool = False,
                 rules: ShardingRules | None = None):
    """device_put every leaf under the new mesh's resolved shardings."""
    rules = rules or make_rules(new_mesh, fsdp=fsdp)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    shardings = named_sharding_tree(specs, shapes, new_mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
