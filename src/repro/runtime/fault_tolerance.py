"""Fault-tolerant training runtime: restart, stragglers, graceful preemption.

``ResilientLoop`` wraps a train-step callable with:
  * step-atomic async checkpointing every N steps (+ final),
  * auto-resume from the latest complete checkpoint,
  * SIGTERM/SIGINT handling — a preemption notice triggers one synchronous
    checkpoint before exit (standard TPU-pod eviction protocol),
  * a straggler detector: per-step wall-time EWMA; steps slower than
    ``straggler_factor``× the EWMA are flagged (on a real pod the hook would
    feed the controller's drop-and-remesh path; here it feeds metrics and the
    elastic module's re-mesh decision).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax

from repro import checkpoint as ckpt_lib

__all__ = ["LoopConfig", "StragglerDetector", "ResilientLoop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep_last: int = 3
    straggler_factor: float = 2.5
    ewma_alpha: float = 0.1


class StragglerDetector:
    """Flags steps (or, multi-host, peers) that exceed factor× EWMA time."""

    def __init__(self, factor: float = 2.5, alpha: float = 0.1):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.flagged: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.factor * self.ewma
        if is_straggler:
            self.flagged.append((step, dt, self.ewma))
        # Straggler samples do not poison the EWMA.
        if not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class ResilientLoop:
    def __init__(self, cfg: LoopConfig, step_fn: Callable,
                 batch_fn: Callable[[int], Any]):
        """step_fn(state, batch) -> (state, metrics); state is a pytree
        whose first element convention is (params, opt_state)."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.detector = StragglerDetector(cfg.straggler_factor,
                                          cfg.ewma_alpha)
        self._preempted = False
        self._pending_save = None
        self.metrics_log: list[dict] = []

    def _handle_signal(self, signum, frame):
        self._preempted = True

    def _maybe_gc(self):
        steps = ckpt_lib.all_steps(self.cfg.ckpt_dir)
        for s in steps[:-self.cfg.keep_last]:
            import shutil, os
            shutil.rmtree(os.path.join(self.cfg.ckpt_dir,
                                       f"step_{s:08d}"), ignore_errors=True)

    def run(self, init_state):
        cfg = self.cfg
        state = init_state
        start = 0
        latest = ckpt_lib.latest_step(cfg.ckpt_dir)
        if latest is not None:
            state, start = ckpt_lib.restore(state, cfg.ckpt_dir, latest)
            start = latest
        old_term = signal.signal(signal.SIGTERM, self._handle_signal)
        old_int = signal.signal(signal.SIGINT, self._handle_signal)
        try:
            step = start
            while step < cfg.total_steps and not self._preempted:
                batch = self.batch_fn(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(state)[0])
                dt = time.monotonic() - t0
                straggle = self.detector.observe(step, dt)
                metrics = dict(metrics, step=step, step_time_s=dt,
                               straggler=straggle)
                self.metrics_log.append(
                    {k: (float(v) if hasattr(v, "dtype") or
                         isinstance(v, (int, float)) else v)
                     for k, v in metrics.items()})
                step += 1
                if step % cfg.ckpt_every == 0:
                    if self._pending_save is not None:
                        self._pending_save.join()
                    self._pending_save = ckpt_lib.save_async(
                        state, cfg.ckpt_dir, step)
                    self._maybe_gc()
            # Final / preemption checkpoint: synchronous, never skipped.
            if self._pending_save is not None:
                self._pending_save.join()
            ckpt_lib.save(jax.tree.map(lambda x: x, state),
                          cfg.ckpt_dir, step)
            return state, step, self._preempted
        finally:
            signal.signal(signal.SIGTERM, old_term)
            signal.signal(signal.SIGINT, old_int)
