from repro.runtime.elastic import (choose_mesh_shape, elastic_remesh,
                                   reshard_tree)
from repro.runtime.fault_tolerance import (LoopConfig, ResilientLoop,
                                           StragglerDetector)

__all__ = ["choose_mesh_shape", "elastic_remesh", "reshard_tree",
           "LoopConfig", "ResilientLoop", "StragglerDetector"]
