"""Sharded, prefetching, resumable data loader.

Wraps a pure ``batch_fn(step) -> pytree`` (see synthetic.py) with a
background prefetch thread and device placement.  State is just the step
counter — checkpointable as one int, resumable on any host count (the batch
fn reshards itself from host_index/host_count).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import jax

__all__ = ["PrefetchLoader"]


class PrefetchLoader:
    def __init__(self, batch_fn: Callable[[int], dict], *, start_step: int = 0,
                 prefetch: int = 2, sharding=None):
        self._batch_fn = batch_fn
        self._step = start_step
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sharding is None:
            return batch
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), batch, self._sharding)

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self._batch_fn(step)
            except Exception as e:                     # surface in __next__
                self._q.put(e)
                return
            self._q.put((step, self._place(batch)))
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        step, batch = item
        self._step = step + 1
        return step, batch

    @property
    def state(self) -> dict:
        """Checkpointable loader state."""
        return dict(step=self._step)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
