from repro.data.loader import PrefetchLoader
from repro.data.synthetic import (TokenStreamConfig, cnn_batch, lm_batch,
                                  markov_lm_batch)

__all__ = ["PrefetchLoader", "TokenStreamConfig", "cnn_batch", "lm_batch",
           "markov_lm_batch"]
