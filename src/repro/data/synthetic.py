"""Deterministic synthetic data: stateless token streams + CNN inputs.

Batches are a pure function of (seed, step, host shard), which gives exact
resume after restart/elastic re-shard with no iterator state to checkpoint —
the data-pipeline half of fault tolerance.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStreamConfig", "lm_batch", "markov_lm_batch", "cnn_batch"]


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _fold(seed: int, *xs: int) -> np.random.Generator:
    ss = np.random.SeedSequence([seed, *xs])
    return np.random.default_rng(ss)


def lm_batch(cfg: TokenStreamConfig, step: int, *, host_index: int = 0,
             host_count: int = 1) -> dict:
    """Uniform-random tokens (throughput benchmarking)."""
    per_host = cfg.global_batch // host_count
    rng = _fold(cfg.seed, step, host_index)
    toks = rng.integers(0, cfg.vocab_size, (per_host, cfg.seq_len + 1),
                        dtype=np.int32)
    return dict(tokens=jnp.asarray(toks[:, :-1]),
                labels=jnp.asarray(toks[:, 1:]))


def markov_lm_batch(cfg: TokenStreamConfig, step: int, *, order: int = 1,
                    host_index: int = 0, host_count: int = 1) -> dict:
    """Learnable synthetic language: a fixed random Markov chain over the
    vocab (same transition table for every step), so a trained model's loss
    genuinely decreases — used by the end-to-end train example."""
    per_host = cfg.global_batch // host_count
    table_rng = _fold(cfg.seed, 0xC0FFEE)
    v = cfg.vocab_size
    # Sparse-ish transition structure: each token has 8 likely successors.
    successors = table_rng.integers(0, v, (v, 8), dtype=np.int32)
    rng = _fold(cfg.seed, step, host_index)
    toks = np.empty((per_host, cfg.seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, v, per_host)
    choices = rng.integers(0, 8, (per_host, cfg.seq_len))
    noise = rng.random((per_host, cfg.seq_len)) < 0.05
    rand_tok = rng.integers(0, v, (per_host, cfg.seq_len), dtype=np.int32)
    for t in range(cfg.seq_len):
        nxt = successors[toks[:, t], choices[:, t]]
        toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
    return dict(tokens=jnp.asarray(toks[:, :-1]),
                labels=jnp.asarray(toks[:, 1:]))


def cnn_batch(batch: int, size: int, channels: int, step: int, *,
              seed: int = 0, activation_sparsity: float = 0.5) -> jax.Array:
    """ReLU-like sparse images for the MNF CNN pipeline."""
    rng = _fold(seed, step)
    x = rng.standard_normal((batch, size, size, channels)).astype(np.float32)
    mask = rng.random((batch, size, size, channels)) >= activation_sparsity
    return jnp.asarray(np.abs(x) * mask)
