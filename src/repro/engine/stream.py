"""EventStream — the inter-layer currency of the MNF pipeline (DESIGN.md §5).

The paper's point is that activations stay *compressed between layers*: the
fire phase of layer L emits events, and the multiply phase of layer L+1
consumes them directly — no dense round-trip.  ``EventStream`` carries the
``BlockEvents`` of a fired activation matrix together with the logical shape
and tile geometry needed to consume (or, for oracle backends, to decode)
them.  ``engine.fire`` produces one; ``engine.linear`` accepts one.

One stream type is the currency for both FC and conv layers: a conv feature
map rides the same flattened (M, K) = (B·H·W, C) event view, with the
batched NHWC ``logical_shape`` carried alongside so ``conv2d`` can address
row groups spatially (pixel-granular ``blk_m == 1`` encoding — each row
group is one pixel, so a shifted tap slice is a gather of groups).

A pytree (jit/vmap/scan-safe): ``events`` and the optional cached ``fired``
dense twin are children; shape and tile geometry are static.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import events as ev
# Import by submodule path: ``repro.core`` re-exports the ``quantize``
# *function* under the same name, shadowing the module attribute.
from repro.core.quantize import QParams as _QParams
from repro.core.quantize import dequantize as _dequantize
from repro.engine import trace

__all__ = ["EventStream"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EventStream:
    """Block events of a fired (M, K) activation matrix, plus geometry.

    events: BlockEvents over the block-padded matrix (Mp = ceil(M/blk_m),
            Kp = ceil(K/blk_k) multiples).
    fired:  optional cached dense twin (M, K) — kept when produced for free
            (the fire phase computes it anyway); ``None`` after transforms
            that only exist in event form.
    shape:  logical (M, K) before padding          [static]
    blk_m, blk_k: tile geometry of the encoding    [static]
    logical_shape: batched pre-flatten shape       [static] — (B, H, W, C)
            for conv feature maps (rows are raster-order pixels, K is the
            channel axis); ``None`` for plain (M, K) FC activations.
    qparams: quantization parameters of the event values (DESIGN.md §12) —
            set when ``values`` carry int8 (symmetric, zero_point == 0, so
            absent events are exact zeros in both domains); the kept
            ``fired`` twin is always the dequantized f32 map.  ``None``
            for f32 streams.
    signed: the producing fire rule can emit *negative* event values
            [static] — set by signed/magnitude fire (DESIGN.md §13).  The
            ReLU-fire invariant (every event value >= 0) underpins the
            pool's bitwise segment-max argument, so consumers that rely on
            it gate on this flag (``engine.pool_ineligible_reason``); the
            recurrent decode path *requires* it (two-sided per-token
            deltas).
    """

    events: ev.BlockEvents
    fired: jax.Array | None
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    blk_m: int = dataclasses.field(metadata=dict(static=True))
    blk_k: int = dataclasses.field(metadata=dict(static=True))
    logical_shape: tuple | None = dataclasses.field(
        default=None, metadata=dict(static=True))
    qparams: _QParams | None = None
    signed: bool = dataclasses.field(default=False,
                                     metadata=dict(static=True))

    # -- construction -------------------------------------------------------

    @classmethod
    def empty(cls, shape: tuple[int, int], *, blk_m: int, blk_k: int,
              capacity: int | None = None, fired: jax.Array | None = None,
              dtype=jnp.float32,
              logical_shape: tuple | None = None) -> "EventStream":
        """An explicitly event-free stream for a degenerate (M, K) shape.

        A zero-row activation (empty batch, fully-dead layer) has a
        zero-size event grid; building it here — instead of running the
        encode machinery or a fire backend over it — keeps 0-extent
        launches away from Pallas (which rejects zero-size grid slices).
        The array shapes match what :meth:`encode` would produce.
        """
        m, k = shape
        g = -(-m // blk_m) if m > 0 else 0
        nkb = -(-k // blk_k) if k > 0 else 0
        cap = nkb if capacity is None else min(capacity, nkb)
        cap = max(cap, 1) if nkb > 0 else 1
        bev = ev.BlockEvents(
            values=jnp.zeros((g, cap, blk_m, blk_k), dtype),
            block_idx=jnp.zeros((g, cap), jnp.int32),
            counts=jnp.zeros((g,), jnp.int32),
            num_k_blocks=nkb)
        return cls(events=bev, fired=fired, shape=(m, k), blk_m=blk_m,
                   blk_k=blk_k, logical_shape=logical_shape)

    @classmethod
    def encode(cls, x: jax.Array, *, blk_m: int, blk_k: int,
               capacity: int | None = None, threshold: float = 0.0,
               keep_dense: bool = True) -> "EventStream":
        """Encode a dense (M, K) activation matrix into a stream."""
        m, k = x.shape
        if m == 0 or k == 0:
            return cls.empty((m, k), blk_m=blk_m, blk_k=blk_k,
                             capacity=capacity, dtype=x.dtype,
                             fired=x if keep_dense else None)
        xp = ev.pad_to_block_multiple(x, blk_m, 0)
        xp = ev.pad_to_block_multiple(xp, blk_k, 1)
        bev = ev.encode_block_events(xp, blk_m=blk_m, blk_k=blk_k,
                                     capacity=capacity, threshold=threshold)
        return cls(events=bev, fired=x if keep_dense else None,
                   shape=(m, k), blk_m=blk_m, blk_k=blk_k)

    @classmethod
    def encode_nhwc(cls, x: jax.Array, *, blk_k: int, blk_m: int = 1,
                    capacity: int | None = None, threshold: float = 0.0,
                    keep_dense: bool = True) -> "EventStream":
        """Encode a dense (B, H, W, C) feature map into a conv stream.

        Rows of the event view are raster-order pixels; K is the channel
        axis.  ``blk_m == 1`` (default) is the pixel-granular encoding the
        per-tap ``conv2d`` path gathers; ``blk_m == STRIP_W`` is the
        strip-aligned encoding (each row group is an 8-pixel strip along W,
        which must divide W) that the fused-tap kernel consumes with an
        STRIP_W-fold smaller event grid (DESIGN.md §6).
        """
        b, h, w, c = x.shape
        assert blk_m == 1 or (blk_m == ev.STRIP_W and w % ev.STRIP_W == 0), \
            (blk_m, x.shape, "strip encoding needs blk_m == STRIP_W and "
                             "W % STRIP_W == 0")
        flat = x.reshape(b * h * w, c)
        s = cls.encode(flat, blk_m=blk_m, blk_k=min(blk_k, max(c, 1)),
                       capacity=capacity, threshold=threshold,
                       keep_dense=keep_dense)
        return dataclasses.replace(s, logical_shape=(b, h, w, c))

    # -- views --------------------------------------------------------------

    @property
    def num_events(self) -> jax.Array:
        """Total live block events (the quantity the cost model prices)."""
        return self.events.counts.sum()

    def per_row_scalar_events(self) -> jax.Array:
        """Non-zero activation count per logical row, (M,) f32 — derived
        from the compacted event values alone (no dense twin), lossless at
        threshold 0.  For conv streams, row r is raster-order pixel r, so
        ``.reshape(B, H, W)`` is the per-pixel fired-event map the cost
        model weights by receptive-field fan-out."""
        return ev.scalar_event_rows(self.events)[:self.shape[0]]

    @property
    def num_scalar_events(self) -> jax.Array:
        """Total non-zero activations (the paper's event count), twin-free."""
        return self.per_row_scalar_events().sum()

    def occupancy(self) -> jax.Array:
        """Live fraction of the (row-group × K-block) event grid.

        A degenerate stream (0-row or 0-column logical shape) has an empty
        grid; its occupancy is defined as 0.0 rather than 0/0.
        """
        g = self.events.block_idx.shape[0]
        denom = g * self.events.num_k_blocks
        if denom == 0:
            return jnp.zeros((), jnp.float32)
        return self.num_events / denom

    def dense(self) -> jax.Array:
        """Dense (M, K) view.  Free if the fired twin was kept; otherwise a
        decode (the round-trip the chained path exists to avoid — oracle
        backends only).  Real decodes are visible to ``trace_dispatch``."""
        if self.fired is not None:
            return self.fired
        trace.record(op="stream.dense", decode=True, shape=self.shape)
        m, k = self.shape
        g = self.events.block_idx.shape[0]
        y = ev.decode_block_events(self.events, blk_m=self.blk_m,
                                   blk_k=self.blk_k, m=g * self.blk_m,
                                   k=self.events.num_k_blocks * self.blk_k)
        if self.qparams is not None:
            y = _dequantize(y, self.qparams)
        return y[:m, :k]

    def dense_nhwc(self) -> jax.Array:
        """Dense (B, H, W, C) view of a conv stream (``logical_shape`` set).

        Same cost semantics as :meth:`dense`: free via the cached fired twin,
        a recorded decode otherwise.
        """
        assert self.logical_shape is not None and \
            len(self.logical_shape) == 4, self.logical_shape
        return self.dense().reshape(self.logical_shape)

    def without_dense(self) -> "EventStream":
        """Drop the cached dense twin — events-only from here on (what a
        chained-layer test uses to prove no densify happens)."""
        return dataclasses.replace(self, fired=None)

    # -- transforms ---------------------------------------------------------

    def retile_fc(self) -> "EventStream":
        """Re-tile a conv stream to the flattened (B, H·W·C) FC view.

        Event-domain image of ``dense_nhwc().reshape(B, -1)``: the static
        address plan of :func:`repro.core.events.retile_block_events` moves
        block events — values travel by gather only — so no decode happens
        and the result equals encoding the flattened dense twin at the same
        (blk_m=1, blk_k) geometry, array for array (DESIGN.md §12).  The
        cached twin and ``qparams`` ride along.  Asserts eligibility; gate
        with :func:`repro.core.events.retile_ineligible_reason`.
        """
        reason = ev.retile_ineligible_reason(self.logical_shape, self.blk_m,
                                             self.blk_k)
        assert reason is None, reason
        b, h, w, c = self.logical_shape
        bev = ev.retile_block_events(self.events, self.logical_shape,
                                     self.blk_m)
        fired = None
        if self.fired is not None:
            fired = self.fired.reshape(b, h * w * c)
        return EventStream(events=bev, fired=fired, shape=(b, h * w * c),
                           blk_m=1, blk_k=self.blk_k, logical_shape=None,
                           qparams=self.qparams, signed=self.signed)

    def dequantize_events(self) -> "EventStream":
        """Dequantize int8 event values in place — still event-domain.

        A per-tile scalar multiply (symmetric: zero stays zero, padding
        slots stay exact zeros), not a decode: consumers that want f32
        values (the pool's segment max) read the same floats the kept twin
        carries, bitwise.  No-op on f32 streams.
        """
        if self.qparams is None:
            return self
        vals = _dequantize(self.events.values, self.qparams)
        bev = dataclasses.replace(self.events, values=vals)
        return dataclasses.replace(self, events=bev, qparams=None)
