"""EngineConfig — the one knob bundle for MNF compute (DESIGN.md §3).

Every execution-path parameter that used to be scattered across
``mnf_linear`` / ``tap_event_conv2d`` / ``event_matmul`` / ``fire_and_encode``
call sites (tile shapes, event capacity, fire threshold, interpret mode,
backend choice) lives here, so layers pass one object down the stack and
new backends (sharded, quantized) extend the config instead of every
signature in the repo.
"""
from __future__ import annotations

import dataclasses

import jax

__all__ = ["BACKENDS", "RECURRENT_BLK_K", "EngineConfig"]

#: Default K-block width of the fire-gated recurrent decode (DESIGN.md §13).
#: A per-token drive is a single row (blk_m == 1), so the only useful event
#: granularity is narrow K blocks over the channel axis: 16 channels per
#: block gives head_dim-64 wkv6 four independently skippable state
#: row-blocks (and a Mamba d_inner of 1536 ninety-six) while staying a
#: whole sublane-multiple payload.  ``for_recurrent`` clamps to
#: min(cfg.blk_k, RECURRENT_BLK_K, D); pass a smaller ``blk_k`` to sweep
#: finer granularities.
RECURRENT_BLK_K = 16

#: Execution backends, in "fidelity order" (see DESIGN.md §4):
#:   dense  — jnp oracle (no event machinery; the correctness reference)
#:   scalar — paper-faithful Algorithm 1/2 scalar events (semantics/cost ref)
#:   block  — pure-jnp block-event dataflow (TPU encoding, XLA execution)
#:   pallas — the Pallas TPU kernels (interpret-mode on CPU)
BACKENDS = ("dense", "scalar", "block", "pallas")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """All knobs of the MNF event pipeline, consolidated.

    backend:    one of BACKENDS or "auto" (resolve per device: pallas on TPU,
                block elsewhere — DESIGN.md §4).
    blk_m:      event tile rows (row-group height of the block encoding).
    blk_k:      event tile K width (VMEM lane width on TPU).
    blk_n:      output tile width of the Pallas multiply kernel.
    capacity:   static event-list capacity per row group (None = lossless).
    threshold:  fire/encode threshold (0.0 == exact for ReLU networks).
    magnitude:  fire on |a| > threshold (LM generalization) vs a > threshold.
    signed:     explicit signed-event fire (DESIGN.md §13): same |a| > θ
                gate as ``magnitude`` but the emitted stream is *flagged*
                signed, so consumers that assume ReLU-family (non-negative)
                events reject it by name instead of silently mis-pooling.
                The recurrent decode path sets it (per-token deltas are
                two-sided); ``for_recurrent`` is the one adapter that
                turns it on.
    interpret:  run Pallas kernels in interpret mode; None = auto (interpret
                everywhere except real TPU devices).
    out_dtype:  accumulator/output dtype of the multiply phase.
    route:      boundary routing policy (DESIGN.md §11): "auto" routes by
                geometry alone (event path whenever one exists — the
                pre-adaptive behaviour), "adaptive" consults the crossover
                cost model (``costmodel.crossover``) against
                ``occupancy_hint``, and "dense" / "event" / "strip" /
                "pixel" / "window" force a route (tests, benches).  Every
                value is a trace-time constant, so routing is static per
                compiled boundary.
    occupancy_hint: expected occupancy of incoming streams in [0, 1]
                (None = assume 1.0).  A *static* planning value — adaptive
                routing deliberately never reads the traced
                ``EventStream.occupancy()`` (jit-compiled boundaries must
                not route on data).
    int8_events: fire emits int8 event values (DESIGN.md §12): the fired
                map is requantized per layer (symmetric, dynamic
                calibration) and the stream carries the ``QParams``;
                consumers dequantize at tile load, so accumulators stay
                f32 and the chain matches its fake-quant round-trip twin
                bitwise within a backend.
    int8_bits:  quantization width (8 = int8; kept a knob so narrower
                event payloads can be explored without a new config).
    """

    backend: str = "auto"
    blk_m: int = 8
    blk_k: int = 128
    blk_n: int = 128
    capacity: int | None = None
    threshold: float = 0.0
    magnitude: bool = False
    signed: bool = False
    interpret: bool | None = None
    out_dtype: str = "float32"
    route: str = "auto"
    occupancy_hint: float | None = None
    int8_events: bool = False
    int8_bits: int = 8

    # NOTE: backend names beyond BACKENDS are allowed — the registry is open
    # (custom backends register at runtime); unknown names fail at dispatch
    # with the list of what IS registered.

    # -- resolution ---------------------------------------------------------

    def resolve_backend(self) -> str:
        """Concrete backend name ("auto" -> per-device policy)."""
        if self.backend != "auto":
            return self.backend
        return "pallas" if jax.default_backend() == "tpu" else "block"

    def resolve_interpret(self) -> bool:
        """Pallas interpret mode (None -> interpret off TPU only)."""
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def resolved(self) -> "EngineConfig":
        """Pin backend + interpret to their per-device values."""
        return dataclasses.replace(self, backend=self.resolve_backend(),
                                   interpret=self.resolve_interpret())

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)

    # -- adapters -----------------------------------------------------------

    @classmethod
    def from_mnf(cls, mnf) -> "EngineConfig":
        """Build from a ``configs.base.MNFConfig`` (the model-stack knobs)."""
        return cls(backend="pallas" if mnf.use_pallas else "block",
                   blk_m=mnf.blk_m, blk_k=mnf.blk_k,
                   threshold=mnf.threshold, magnitude=mnf.magnitude)

    def for_recurrent(self, k: int) -> "EngineConfig":
        """The config a fire-gated recurrent decode step runs under.

        A per-token drive is one row per (batch × head) — ``blk_m`` is
        forced to 1 — and the gating granularity is narrow K blocks over
        the channel axis (``RECURRENT_BLK_K``, further clamped by the
        drive width and any explicitly smaller ``blk_k``).  ``signed`` is
        turned on: recurrent deltas are two-sided, and the emitted stream
        must say so (DESIGN.md §13).
        """
        return dataclasses.replace(
            self, blk_m=1,
            blk_k=min(self.blk_k, RECURRENT_BLK_K, max(k, 1)),
            signed=True)

    def for_width(self, m: int, k: int) -> "EngineConfig":
        """Clamp tile sizes to an (M, K) operand (small CPU test shapes)."""
        return dataclasses.replace(self, blk_m=min(self.blk_m, max(m, 1)),
                                   blk_k=min(self.blk_k, max(k, 1)))

    def for_conv(self, ci: int, *, width: int | None = None,
                 k: int | None = None, stride: int = 1, padding: int = 0,
                 co: int | None = None,
                 strips: bool | None = None) -> "EngineConfig":
        """Clamp the K tile to a conv's input-channel depth; optionally pick
        the event-row granularity (strip vs pixel tiling — DESIGN.md §6).

        Conv taps contract over CI, so a ``blk_k`` wider than CI would only
        pad; every conv backend applies this one clamp (the shared twin of
        ``for_width`` for the channel axis).

        With ``width`` and ``k`` given, also resolves ``blk_m``: STRIP_W
        (8-pixel row strips — the fused-tap kernel's granularity) when the
        layer is strip-eligible (stride in ``core.events.STRIP_STRIDES``,
        i.e. unit-stride and stride-2 downsampling convs both qualify), 1
        (pixel) otherwise.  ``strips=True`` *requires* strip tiling: a
        stride/width combo that would silently degrade to pixel granularity
        raises ``ValueError`` naming the failing rule instead.
        ``strips=False`` forces pixel tiling.
        """
        from repro.core.events import STRIP_W, strip_ineligible_reason

        cfg = dataclasses.replace(self, blk_k=min(self.blk_k, max(ci, 1)))
        if width is None and k is None and strips is None:
            return cfg
        if strips is False:
            return dataclasses.replace(cfg, blk_m=1)
        if width is None or k is None:
            raise ValueError(
                "for_conv strip selection needs the conv geometry: "
                "width= and k= (got width=%r, k=%r)" % (width, k))
        reason = strip_ineligible_reason(width, k, stride, padding, co)
        if strips and reason is not None:
            raise ValueError(
                f"strip tiling explicitly requested but the conv geometry "
                f"(width={width}, k={k}, stride={stride}, padding={padding}) "
                f"would silently degrade to pixel granularity: {reason}")
        return dataclasses.replace(cfg,
                                   blk_m=1 if reason is not None else STRIP_W)

    def for_pool(self, c: int, *, width: int | None = None,
                 k: int | None = None, stride: int = 1, padding: int = 0,
                 co: int | None = None) -> "EngineConfig":
        """Resolve the config an event-native max-pool emits under.

        ``c`` is the pooled channel depth (pooling preserves channels, so
        the K clamp mirrors :meth:`for_conv`).  ``blk_m`` becomes the
        granularity of the **emitted** pooled stream, chosen from its
        consumer: pass the consuming conv's geometry (``width`` = pooled
        map width, plus ``k``/``stride``/``padding``/``co``) to upgrade to
        strip tiling when that conv can ride the fused-tap kernel; with no
        consumer geometry the pooled stream stays pixel-granular
        (DESIGN.md §7).
        """
        from repro.core.events import STRIP_W, strip_ineligible_reason

        cfg = dataclasses.replace(self, blk_k=min(self.blk_k, max(c, 1)))
        if width is None or k is None:
            return dataclasses.replace(cfg, blk_m=1)
        reason = strip_ineligible_reason(width, k, stride, padding, co)
        return dataclasses.replace(cfg,
                                   blk_m=1 if reason is not None else STRIP_W)
