"""Built-in engine backends (DESIGN.md §4).

Registers the repo's four existing execution paths of each MNF op under the
backend registry, with one uniform signature per op:

  matmul        fn(a, w, cfg)                     a: (M, K), w: (K, N)
  linear        fn(x, w, b, cfg)                  x: (M, K)
  linear_events fn(stream, w, b, cfg)             stream: EventStream
  conv2d        fn(x, w, b, cfg, stride, padding) x: (B, H, W, CI), NHWC/HWIO
  fire          fn(acc, cfg) -> (fired, BlockEvents)   acc: (M, K)

"dense" and "scalar" are oracles (no / scalar event machinery); "block" is
the pure-jnp block-event dataflow; "pallas" runs the TPU kernels
(interpret-mode off-TPU per cfg.resolve_interpret()).  Backends that cannot
consume an EventStream simply don't register ``linear_events`` — the API
falls back to a documented decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.fire import FireConfig
from repro.core.fire import fire as jnp_fire
from repro.core.mnf_conv import (dense_conv2d, scalar_event_conv2d,
                                 tap_event_conv2d)
from repro.core.mnf_linear import (block_event_linear,
                                   block_event_linear_from_events,
                                   dense_linear, scalar_event_linear)
from repro.engine.config import EngineConfig
from repro.engine.registry import register_backend
from repro.engine.stream import EventStream
from repro.kernels.event_matmul.ops import (event_matmul, event_matmul_cfg,
                                            event_matmul_from_events)
from repro.kernels.fire_compact.ops import fire_and_encode_cfg

__all__ = []  # registration side effects only


def _bias(y: jax.Array, b: jax.Array | None) -> jax.Array:
    return y if b is None else y + b


# ---------------------------------------------------------------------------
# matmul / linear
# ---------------------------------------------------------------------------

@register_backend("matmul", "dense")
def _matmul_dense(a, w, cfg: EngineConfig):
    return dense_linear(a, w)


@register_backend("matmul", "scalar")
def _matmul_scalar(a, w, cfg: EngineConfig):
    return jax.vmap(lambda row: scalar_event_linear(row, w))(a)


@register_backend("matmul", "block")
def _matmul_block(a, w, cfg: EngineConfig):
    c = cfg.for_width(*a.shape)
    return block_event_linear(a, w, blk_m=c.blk_m, blk_k=c.blk_k,
                              capacity=c.capacity, threshold=c.threshold)


register_backend("matmul", "pallas", event_matmul_cfg)


for _name in ("dense", "scalar", "block", "pallas"):
    def _linear(x, w, b, cfg, _name=_name):
        from repro.engine.registry import get_backend
        return _bias(get_backend("matmul", _name)(x, w, cfg), b)
    register_backend("linear", _name, _linear)


# ---------------------------------------------------------------------------
# linear on a pre-encoded EventStream (the chained, no-round-trip path)
# ---------------------------------------------------------------------------

@register_backend("linear_events", "block")
def _linear_events_block(stream, w, b, cfg: EngineConfig):
    m, k = stream.shape
    assert w.shape[0] == k, (w.shape, stream.shape)
    y = block_event_linear_from_events(stream.events, w)
    return _bias(y[:m], b)


@register_backend("linear_events", "pallas")
def _linear_events_pallas(stream, w, b, cfg: EngineConfig):
    m, k = stream.shape
    n = w.shape[1]
    assert w.shape[0] == k, (w.shape, stream.shape)
    wp = ev.pad_to_block_multiple(w, stream.blk_k, 0)
    wp = ev.pad_to_block_multiple(wp, cfg.blk_n, 1)
    y = event_matmul_from_events(stream.events, wp, blk_n=cfg.blk_n,
                                 interpret=cfg.resolve_interpret())
    return _bias(y[:m, :n], b)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

@register_backend("conv2d", "dense")
def _conv2d_dense(x, w, b, cfg: EngineConfig, stride, padding):
    return dense_conv2d(x, w, stride=stride, padding=padding, b=b)


@register_backend("conv2d", "scalar")
def _conv2d_scalar(x, w, b, cfg: EngineConfig, stride, padding):
    y = jax.vmap(lambda img: scalar_event_conv2d(
        img, w, stride=stride, padding=padding))(x)
    return _bias(y, b)


@register_backend("conv2d", "block")
def _conv2d_block(x, w, b, cfg: EngineConfig, stride, padding):
    ci = x.shape[-1]
    c = cfg.replace(blk_k=min(cfg.blk_k, ci))
    y = tap_event_conv2d(x, w, stride=stride, padding=padding, blk_m=c.blk_m,
                         blk_k=c.blk_k, capacity=c.capacity,
                         threshold=c.threshold)
    return _bias(y, b)


@register_backend("conv2d", "pallas")
def _conv2d_pallas(x, w, b, cfg: EngineConfig, stride, padding):
    ci = x.shape[-1]
    c = cfg.replace(blk_k=min(cfg.blk_k, ci))
    interpret = c.resolve_interpret()

    def tap_matmul(a, wt):
        return event_matmul(a, wt, blk_m=c.blk_m, blk_k=c.blk_k,
                            blk_n=c.blk_n, capacity=c.capacity,
                            threshold=c.threshold, interpret=interpret)

    y = tap_event_conv2d(x, w, stride=stride, padding=padding,
                         matmul=tap_matmul)
    return _bias(y, b)


# ---------------------------------------------------------------------------
# fire (threshold + re-encode for the next layer)
# ---------------------------------------------------------------------------

def _fire_jnp(acc, cfg: EngineConfig):
    c = cfg.for_width(*acc.shape)
    fired = jnp_fire(acc, FireConfig(threshold=c.threshold,
                                     magnitude=c.magnitude))
    bev = EventStream.encode(fired, blk_m=c.blk_m, blk_k=c.blk_k,
                             capacity=c.capacity, threshold=0.0,
                             keep_dense=False).events
    return fired, bev


for _name in ("dense", "scalar", "block"):
    register_backend("fire", _name, _fire_jnp)


register_backend("fire", "pallas", fire_and_encode_cfg)
