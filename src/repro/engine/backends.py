"""Built-in engine backends (DESIGN.md §4).

Registers the repo's four existing execution paths of each MNF op under the
backend registry, with one uniform signature per op:

  matmul           fn(a, w, cfg)                     a: (M, K), w: (K, N)
  linear           fn(x, w, b, cfg)                  x: (M, K)
  linear_events    fn(stream, w, b, cfg)             stream: EventStream
  conv2d           fn(x, w, b, cfg, stride, padding) x: (B, H, W, CI)
  maxpool2d        fn(x, k, stride, cfg)             x: (B, H, W, C) dense
  maxpool2d_events fn(stream, k, stride, cfg) -> (B*OH*OW, C) pooled rows
  fire             fn(acc, cfg) -> (fired, BlockEvents)   acc: (M, K)

"dense" and "scalar" are oracles (no / scalar event machinery); "block" is
the pure-jnp block-event dataflow; "pallas" runs the TPU kernels
(interpret-mode off-TPU per cfg.resolve_interpret()).  Backends that cannot
consume an EventStream simply don't register ``linear_events`` — the API
falls back to a documented decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.fire import FireConfig
from repro.core.fire import fire as jnp_fire
from repro.core.mnf_conv import (conv_out_size, dense_conv2d,
                                 scalar_event_conv2d, tap_event_conv2d)
from repro.core.mnf_linear import (block_event_linear,
                                   block_event_linear_from_events,
                                   dense_linear, scalar_event_linear)
from repro.engine.config import EngineConfig
from repro.engine.registry import register_backend
from repro.engine.stream import EventStream
from repro.kernels.event_matmul.ops import (event_matmul, event_matmul_cfg,
                                            event_matmul_from_events)
from repro.kernels.fire_compact.ops import fire_and_encode_cfg

__all__ = []  # registration side effects only


def _bias(y: jax.Array, b: jax.Array | None) -> jax.Array:
    return y if b is None else y + b


# ---------------------------------------------------------------------------
# matmul / linear
# ---------------------------------------------------------------------------

@register_backend("matmul", "dense")
def _matmul_dense(a, w, cfg: EngineConfig):
    return dense_linear(a, w)


@register_backend("matmul", "scalar")
def _matmul_scalar(a, w, cfg: EngineConfig):
    return jax.vmap(lambda row: scalar_event_linear(row, w))(a)


@register_backend("matmul", "block")
def _matmul_block(a, w, cfg: EngineConfig):
    c = cfg.for_width(*a.shape)
    return block_event_linear(a, w, blk_m=c.blk_m, blk_k=c.blk_k,
                              capacity=c.capacity, threshold=c.threshold)


register_backend("matmul", "pallas", event_matmul_cfg)


for _name in ("dense", "scalar", "block", "pallas"):
    def _linear(x, w, b, cfg, _name=_name):
        from repro.engine.registry import get_backend
        return _bias(get_backend("matmul", _name)(x, w, cfg), b)
    register_backend("linear", _name, _linear)


# ---------------------------------------------------------------------------
# linear on a pre-encoded EventStream (the chained, no-round-trip path)
# ---------------------------------------------------------------------------

@register_backend("linear_events", "block")
def _linear_events_block(stream, w, b, cfg: EngineConfig):
    m, k = stream.shape
    assert w.shape[0] == k, (w.shape, stream.shape)
    y = block_event_linear_from_events(stream.events, w,
                                       qparams=stream.qparams)
    return _bias(y[:m], b)


@register_backend("linear_events", "pallas")
def _linear_events_pallas(stream, w, b, cfg: EngineConfig):
    m, k = stream.shape
    n = w.shape[1]
    assert w.shape[0] == k, (w.shape, stream.shape)
    wp = ev.pad_to_block_multiple(w, stream.blk_k, 0)
    wp = ev.pad_to_block_multiple(wp, cfg.blk_n, 1)
    y = event_matmul_from_events(stream.events, wp, blk_n=cfg.blk_n,
                                 interpret=cfg.resolve_interpret(),
                                 qparams=stream.qparams)
    return _bias(y[:m, :n], b)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

@register_backend("conv2d", "dense")
def _conv2d_dense(x, w, b, cfg: EngineConfig, stride, padding):
    return dense_conv2d(x, w, stride=stride, padding=padding, b=b)


@register_backend("conv2d", "scalar")
def _conv2d_scalar(x, w, b, cfg: EngineConfig, stride, padding):
    y = jax.vmap(lambda img: scalar_event_conv2d(
        img, w, stride=stride, padding=padding))(x)
    return _bias(y, b)


@register_backend("conv2d", "block")
def _conv2d_block(x, w, b, cfg: EngineConfig, stride, padding):
    c = cfg.for_conv(x.shape[-1])
    y = tap_event_conv2d(x, w, stride=stride, padding=padding, blk_m=c.blk_m,
                         blk_k=c.blk_k, capacity=c.capacity,
                         threshold=c.threshold)
    return _bias(y, b)


@register_backend("conv2d", "pallas")
def _conv2d_pallas(x, w, b, cfg: EngineConfig, stride, padding):
    c = cfg.for_conv(x.shape[-1])
    interpret = c.resolve_interpret()

    def tap_matmul(a, wt):
        return event_matmul(a, wt, blk_m=c.blk_m, blk_k=c.blk_k,
                            blk_n=c.blk_n, capacity=c.capacity,
                            threshold=c.threshold, interpret=interpret)

    y = tap_event_conv2d(x, w, stride=stride, padding=padding,
                         matmul=tap_matmul)
    return _bias(y, b)


# ---------------------------------------------------------------------------
# conv2d on a pre-encoded conv EventStream (the event-resident path):
# layer L's fired feature-map events feed layer L+1's k·k taps as row-group
# gathers — the dense map is never materialized (DESIGN.md §5).
# ---------------------------------------------------------------------------

def _tap_row_map(stream, k: int, stride: int, padding: int):
    """Yield (dy, dx, idx, live) per tap: the row-group gather that realizes
    the shifted spatial slice of the tap decomposition in the event domain.

    For output pixel (b, oy, ox), tap (dy, dx) reads input pixel
    (iy, ix) = (oy·s + dy − p, ox·s + dx − p); ``live`` masks taps that fall
    in the zero padding border (no source group — no events).
    """
    bsz, h, wd, _ = stream.logical_shape
    oy = conv_out_size(h, k, stride, padding)
    ox = conv_out_size(wd, k, stride, padding)
    bi = jnp.arange(bsz, dtype=jnp.int32)[:, None, None]
    oyi = jnp.arange(oy, dtype=jnp.int32)[None, :, None]
    oxi = jnp.arange(ox, dtype=jnp.int32)[None, None, :]
    for dy in range(k):
        for dx in range(k):
            iy = oyi * stride + dy - padding
            ix = oxi * stride + dx - padding
            live = (iy >= 0) & (iy < h) & (ix >= 0) & (ix < wd)
            idx = (bi * h + jnp.clip(iy, 0, h - 1)) * wd \
                + jnp.clip(ix, 0, wd - 1)
            live = jnp.broadcast_to(live, (bsz, oy, ox)).reshape(-1)
            idx = jnp.broadcast_to(idx, (bsz, oy, ox)).reshape(-1)
            yield dy, dx, idx, live


def _conv2d_events(stream, w, b, cfg: EngineConfig, stride, padding,
                   tap_matmul):
    """Shared event-resident conv: Σ_taps tap_matmul(gathered events, W_tap)."""
    assert stream.blk_m == 1, \
        "conv streams are pixel-granular (emit with engine.fire_conv)"
    bsz, h, wd, ci = stream.logical_shape
    k, _, ci2, co = w.shape
    assert ci == ci2, (stream.logical_shape, w.shape)
    oy = conv_out_size(h, k, stride, padding)
    ox = conv_out_size(wd, k, stride, padding)
    acc = jnp.zeros((bsz * oy * ox, co),
                    jnp.promote_types(stream.events.values.dtype, w.dtype))
    for dy, dx, idx, live in _tap_row_map(stream, k, stride, padding):
        tap = ev.gather_row_groups(stream.events, idx, live)
        acc = acc + tap_matmul(tap, w[dy, dx])
    return _bias(acc.reshape(bsz, oy, ox, co), b)


@register_backend("conv2d_events", "block")
def _conv2d_events_block(stream, w, b, cfg: EngineConfig, stride, padding):
    def tap_matmul(tap, wt):
        return block_event_linear_from_events(tap, wt,
                                              qparams=stream.qparams)

    return _conv2d_events(stream, w, b, cfg, stride, padding, tap_matmul)


@register_backend("conv2d_events", "pallas")
def _conv2d_events_pallas(stream, w, b, cfg: EngineConfig, stride, padding):
    co = w.shape[-1]
    blk_n = min(cfg.blk_n, max(co, 1))
    interpret = cfg.resolve_interpret()

    def tap_matmul(tap, wt):
        wp = ev.pad_to_block_multiple(wt, stream.blk_k, 0)
        wp = ev.pad_to_block_multiple(wp, blk_n, 1)
        y = event_matmul_from_events(tap, wp, blk_n=blk_n,
                                     interpret=interpret,
                                     qparams=stream.qparams)
        return y[:, :co]

    return _conv2d_events(stream, w, b, cfg, stride, padding, tap_matmul)


# ---------------------------------------------------------------------------
# conv2d on a *strip-aligned* conv EventStream (the fused-tap path): one
# launch per layer, the whole k·k tap loop fused inside — 8x smaller event
# grid than the per-tap gathers above, bit-exact with them (DESIGN.md §6).
# The per-tap ``conv2d_events`` path stays registered as the oracle.
# ---------------------------------------------------------------------------

def _strip_out_shape(stream, w, stride, padding):
    assert stride in ev.STRIP_STRIDES, \
        "strip path covers stride in STRIP_STRIDES (engine.conv2d gates)"
    bsz, h, wd, ci = stream.logical_shape
    k, _, ci2, co = w.shape
    assert ci == ci2, (stream.logical_shape, w.shape)
    return bsz, conv_out_size(h, k, stride, padding), \
        conv_out_size(wd, k, stride, padding), co


@register_backend("conv2d_events_strip", "block")
def _conv2d_events_strip_block(stream, w, b, cfg: EngineConfig, stride,
                               padding):
    from repro.kernels.event_conv.ref import fused_event_conv2d_ref
    bsz, oy, ox, co = _strip_out_shape(stream, w, stride, padding)
    y = fused_event_conv2d_ref(stream, w, stride=stride, padding=padding)
    return _bias(y.reshape(bsz, oy, ox, co), b)


@register_backend("conv2d_events_strip", "pallas")
def _conv2d_events_strip_pallas(stream, w, b, cfg: EngineConfig, stride,
                                padding):
    from repro.kernels.event_conv.ops import fused_event_conv2d
    bsz, oy, ox, co = _strip_out_shape(stream, w, stride, padding)
    blk_n = min(cfg.blk_n, max(co, 1))
    y = fused_event_conv2d(stream, w, stride=stride, padding=padding,
                           blk_n=blk_n, interpret=cfg.resolve_interpret())
    return _bias(y.reshape(bsz, oy, ox, co), b)


# ---------------------------------------------------------------------------
# maxpool2d — dense VALID max-pool (every backend) plus the event-native
# segment-max over a conv EventStream (block/pallas): conv→pool→conv
# boundaries stay events-only, no dense feature map in between (DESIGN.md §7).
# ---------------------------------------------------------------------------

def _maxpool_dense(x, k, stride, cfg: EngineConfig):
    assert x.ndim == 4, (x.shape, "maxpool2d wants an NHWC feature map")
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1),
        "VALID")


for _name in ("dense", "scalar", "block", "pallas"):
    register_backend("maxpool2d", _name, _maxpool_dense)


@register_backend("maxpool2d_events", "block")
def _maxpool2d_events_block(stream, k, stride, cfg: EngineConfig):
    from repro.kernels.event_pool.ref import event_max_pool2d_ref
    return event_max_pool2d_ref(stream, k, stride)


@register_backend("maxpool2d_events", "pallas")
def _maxpool2d_events_pallas(stream, k, stride, cfg: EngineConfig):
    from repro.kernels.event_pool.ops import event_max_pool2d
    return event_max_pool2d(stream, k, stride,
                            interpret=cfg.resolve_interpret())


# Window-major strip pool (DESIGN.md §7): output-strip grid, strip-masked
# affine max — 8x fewer grid steps than the per-event segment max.  The
# engine routes strip streams here whenever the pooled width tiles into
# whole strips (core.events.pool_window_ineligible_reason); the per-event
# op above stays the general path and the bitwise oracle.

@register_backend("maxpool2d_events_window", "block")
def _maxpool2d_events_window_block(stream, k, stride, cfg: EngineConfig):
    from repro.kernels.event_pool.ref import event_max_pool2d_window_ref
    return event_max_pool2d_window_ref(stream, k, stride)


@register_backend("maxpool2d_events_window", "pallas")
def _maxpool2d_events_window_pallas(stream, k, stride, cfg: EngineConfig):
    from repro.kernels.event_pool.ops import event_max_pool2d_window
    return event_max_pool2d_window(stream, k, stride,
                                   interpret=cfg.resolve_interpret())


# ---------------------------------------------------------------------------
# recurrent_step_* — the fire-gated decode state update (DESIGN.md §13):
# consume a signed row stream of the increment drive, skip dead
# channel-blocks of the state update.  Block is the pure-jnp twin (bitwise
# vs the dense step at threshold 0); pallas is the kernel (bitwise
# within-backend — see kernels/wkv6/step.py).  Oracle backends don't
# register the op: the API falls back to the dense step, visibly.
# ---------------------------------------------------------------------------

@register_backend("recurrent_step_wkv6", "block")
def _recurrent_wkv6_block(stream, state, ops, cfg: EngineConfig):
    from repro.kernels.wkv6.step import wkv6_step_events_ref
    return wkv6_step_events_ref(stream.events, ops["r"], ops["v"], ops["w"],
                                ops["u"], state, blk_k=stream.blk_k)


@register_backend("recurrent_step_wkv6", "pallas")
def _recurrent_wkv6_pallas(stream, state, ops, cfg: EngineConfig):
    from repro.kernels.wkv6.step import wkv6_step_events_pallas
    return wkv6_step_events_pallas(stream.events, ops["r"], ops["v"],
                                   ops["w"], ops["u"], state,
                                   blk_k=stream.blk_k,
                                   interpret=cfg.resolve_interpret())


@register_backend("recurrent_step_mamba", "block")
def _recurrent_mamba_block(stream, state, ops, cfg: EngineConfig):
    from repro.kernels.mamba_scan.step import mamba_step_events_ref
    return mamba_step_events_ref(stream.events, ops["da"], ops["bmat"],
                                 ops["cmat"], state, blk_k=stream.blk_k)


@register_backend("recurrent_step_mamba", "pallas")
def _recurrent_mamba_pallas(stream, state, ops, cfg: EngineConfig):
    from repro.kernels.mamba_scan.step import mamba_step_events_pallas
    return mamba_step_events_pallas(stream.events, ops["da"], ops["bmat"],
                                    ops["cmat"], state, blk_k=stream.blk_k,
                                    interpret=cfg.resolve_interpret())


# ---------------------------------------------------------------------------
# fire (threshold + re-encode for the next layer)
# ---------------------------------------------------------------------------

def _fire_jnp(acc, cfg: EngineConfig):
    c = cfg.for_width(*acc.shape)
    fired = jnp_fire(acc, FireConfig(threshold=c.threshold,
                                     magnitude=c.magnitude,
                                     signed=c.signed))
    bev = EventStream.encode(fired, blk_m=c.blk_m, blk_k=c.blk_k,
                             capacity=c.capacity, threshold=0.0,
                             keep_dense=False).events
    return fired, bev


for _name in ("dense", "scalar", "block"):
    register_backend("fire", _name, _fire_jnp)


register_backend("fire", "pallas", fire_and_encode_cfg)


# fire_conv shares the fire implementations — ``engine.fire_conv`` hands the
# backend the flattened (B·OY·OX, CO) accumulator with a pixel-granular
# (blk_m == 1) config.  A separate registry op keeps the seam open for a
# backend that fuses the conv fire phase differently (e.g. an NHWC-native
# Pallas kernel).
for _name in ("dense", "scalar", "block"):
    register_backend("fire_conv", _name, _fire_jnp)


register_backend("fire_conv", "pallas", fire_and_encode_cfg)
