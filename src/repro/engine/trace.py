"""Dispatch tracing — make the engine's fallbacks visible (DESIGN.md §5.1).

The engine API silently degrades in two places: a backend without a
registered ``linear_events`` / ``conv2d_events`` op decodes the incoming
``EventStream`` (the round-trip the chained path exists to avoid), and
``EventStream.dense()`` on a twin-less stream is a real decode.  Both used to
be invisible.  ``trace_dispatch()`` collects a record per dispatch so tests
and benchmarks can assert *where* densification happens::

    with engine.trace_dispatch() as records:
        y = engine.linear(stream, w, cfg=cfg)
    assert not any(r.get("fallback_decode") for r in records)

Records are appended at Python dispatch time, which under ``jax.jit`` means
trace time: the counts describe the compiled graph's structure (how many
decode ops it contains), which is exactly the per-boundary accounting the
benchmarks report.  Conv dispatches additionally mark the tiling they
rode — ``strip=True, launches=1`` for the fused strip kernel vs
``launches=k*k`` for the per-tap path — so grid/launch accounting and the
strip-degradation CI guard read straight off the records.  Nesting is
supported; each context sees every record emitted while it is active.
"""
from __future__ import annotations

import contextlib

__all__ = ["record", "trace_dispatch"]

_SINKS: list[list] = []


def record(**fields) -> None:
    """Append one dispatch record to every active ``trace_dispatch`` context.

    No-op (and allocation-free) when no context is active — safe to call on
    every hot-path dispatch.
    """
    if _SINKS:
        rec = dict(fields)
        for sink in _SINKS:
            sink.append(rec)


@contextlib.contextmanager
def trace_dispatch():
    """Context manager yielding the list of dispatch records."""
    sink: list = []
    _SINKS.append(sink)
    try:
        yield sink
    finally:
        _SINKS.remove(sink)
