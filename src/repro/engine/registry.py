"""Backend registry — the engine's extension seam (DESIGN.md §4).

Implementations register as ``(op, backend_name) -> fn`` pairs; the engine
API dispatches through here, so a new backend (sharded, quantized, a new
kernel generation) is one ``register_backend`` call away from every model in
the repo — no call-site edits.
"""
from __future__ import annotations

from typing import Callable, Iterable

__all__ = ["register_backend", "get_backend", "dispatch", "list_backends",
           "registered_ops"]

_REGISTRY: dict[tuple[str, str], Callable] = {}


def register_backend(op: str, name: str, fn: Callable | None = None):
    """Register ``fn`` as backend ``name`` of operation ``op``.

    Usable directly or as a decorator::

        @register_backend("linear", "dense")
        def _dense_linear(x, w, b, cfg): ...

    Re-registration overwrites (latest wins) so notebooks can hot-swap.
    """
    def _put(f: Callable) -> Callable:
        _REGISTRY[(op, name)] = f
        return f

    return _put if fn is None else _put(fn)


def get_backend(op: str, name: str) -> Callable:
    try:
        return _REGISTRY[(op, name)]
    except KeyError:
        avail = list_backends(op)
        raise KeyError(
            f"no backend {name!r} registered for op {op!r}; "
            f"available: {avail or '(none)'}") from None


def dispatch(op: str, cfg) -> Callable:
    """Resolve ``cfg.backend`` (incl. "auto") and return the implementation."""
    return get_backend(op, cfg.resolve_backend())


def list_backends(op: str) -> list[str]:
    return sorted(n for (o, n) in _REGISTRY if o == op)


def registered_ops() -> list[str]:
    return sorted({o for (o, _) in _REGISTRY})
