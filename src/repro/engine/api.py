"""Engine front-door ops (DESIGN.md §3) — the only entry points the model
stack uses for MNF compute.

Every op takes an :class:`EngineConfig` and dispatches through the backend
registry; ``linear`` additionally accepts an :class:`EventStream` so
consecutive MNF layers chain events without a decode→re-encode round-trip
(the paper's end-to-end event dataflow).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.costmodel import crossover as xover
from repro.engine import trace
from repro.engine.config import EngineConfig
from repro.engine.registry import dispatch, get_backend, list_backends
from repro.engine.stream import EventStream

__all__ = ["matmul", "linear", "conv2d", "maxpool2d",
           "pool_ineligible_reason", "route_conv", "route_pool",
           "route_linear", "route_recurrent", "recurrent_ineligible_reason",
           "recurrent_step", "fire", "fire_conv", "fire_delta", "sparsify",
           "describe"]

_DEFAULT = EngineConfig()


# ---------------------------------------------------------------------------
# Boundary routing (DESIGN.md §11).  One decision function per op kind,
# used both by the dispatching op below *and* by the model planners
# (models/cnn aligns keep_dense / emitted granularity with the route a
# boundary will take) — same inputs, same decision, so plan time and
# dispatch time can never disagree.  Every input is a trace-time Python
# value (geometry, cfg.occupancy_hint, the installed crossover table);
# the traced ``EventStream.occupancy()`` is deliberately never consulted,
# which is what makes each compiled boundary's route static.
# ---------------------------------------------------------------------------

def route_conv(logical_shape: tuple, w_shape: tuple, cfg: EngineConfig, *,
               stride: int = 1, padding: int = 0,
               blk_m: int = 1) -> "xover.RouteDecision":
    """Routing decision for a conv boundary consuming an event stream of
    granularity ``blk_m`` (STRIP_W = strip-aligned, 1 = pixel-granular).

    The event flavor is granularity-bound — a strip stream can only ride
    the fused strip kernel, a pixel stream only the per-tap path — so the
    decision is event-flavor vs dense; the strip/pixel *choice* is made by
    the producer (``models.cnn`` emits the granularity the consumer's
    geometry wants).
    """
    from repro.core.mnf_conv import conv_out_size
    name = cfg.resolve_backend()
    bsz, h, wd, ci = logical_shape
    kh, kw, _, co = w_shape
    if blk_m == ev.STRIP_W:
        event_route = "strip" if (
            ev.strip_eligible(wd, kh, stride, padding, co=co)
            and name in list_backends("conv2d_events_strip")) else None
    else:
        event_route = "pixel" if name in list_backends("conv2d_events") \
            else None
    oy = conv_out_size(h, kh, stride, padding)
    ox = conv_out_size(wd, kw, stride, padding)
    dec = xover.decide_route(
        cfg.route, "conv", occupancy=cfg.occupancy_hint,
        event_route=event_route,
        dense_macs=float(bsz * oy * ox * kh * kw * ci * co),
        avg_touched=(oy * ox * kh * kw) / max(bsz * h * wd, 1) * bsz,
        c_out=co, backend=name, shape_class=f"k{kh}s{stride}")
    if dec.is_event and dec.route != event_route:
        # Forced event flavor the stream's granularity cannot serve:
        # take the flavor that exists (the trace shows what ran).
        dec = _with_route(dec, event_route or "dense")
    return dec


def route_pool(logical_shape: tuple, k: int, stride: int,
               cfg: EngineConfig, *, blk_m: int = 1,
               eligible: bool = True) -> "xover.RouteDecision":
    """Routing decision for a max-pool boundary.

    Two event flavors exist: the window-major strip grid ("window" — strip
    streams whose pooled width tiles into whole strips) and the per-event
    segment max ("pixel" — the general path).  Geometry prefers "window"
    where it applies; ``eligible=False`` (magnitude fire, degenerate
    window, backend without the op — ``pool_ineligible_reason``) forces
    the visible dense fallback whatever the mode.

    The shape class is channel-aware (``k2s2c128``): a dense pool's cost
    scales with C while ``k``/``stride`` stay fixed across a net, so
    pooling boundaries of different widths sit at different crossovers —
    merging their measured curves under one key misroutes the narrow one
    (the wide shape's event win pollutes the aggregate).
    """
    name = cfg.resolve_backend()
    b, h, w, c = logical_shape
    oh = max((h - k) // stride + 1, 0)
    ow = max((w - k) // stride + 1, 0)
    if not eligible:
        event_route = None
    elif (ev.pool_window_ineligible_reason(logical_shape, k, stride,
                                           blk_m) is None
          and name in list_backends("maxpool2d_events_window")
          and cfg.route != "pixel"):
        event_route = "window"
    else:
        event_route = "pixel"
    dec = xover.decide_route(
        cfg.route, "pool", occupancy=cfg.occupancy_hint,
        event_route=event_route,
        dense_macs=float(b * oh * ow * k * k * c),
        avg_touched=(oh * ow * k * k) / max(h * w, 1), c_out=c,
        backend=name, shape_class=f"k{k}s{stride}c{c}")
    if dec.is_event and dec.route != event_route:
        dec = _with_route(dec, event_route or "dense")
    return dec


def route_linear(m: int, k: int, n: int, cfg: EngineConfig, *,
                 eligible: bool = True) -> "xover.RouteDecision":
    """Routing decision for an FC boundary consuming a fire stream.

    For a conv→FC seam pass the *flattened* FC shape (m = B, k = H·W·C);
    ``eligible=False`` (a conv stream whose geometry cannot re-tile to the
    FC view — ``core.events.retile_ineligible_reason``) forces the visible
    dense fallback whatever the mode.  The shape class comes from
    :func:`costmodel.crossover.linear_shape_class`, so FC boundaries of one
    (N, K-bucket) family share a measured crossover curve.
    """
    name = cfg.resolve_backend()
    event_route = "event" if (eligible and
                              name in list_backends("linear_events")) \
        else None
    dec = xover.decide_route(
        cfg.route, "linear", occupancy=cfg.occupancy_hint,
        event_route=event_route, dense_macs=float(m * k * n),
        avg_touched=1.0, c_out=n, backend=name,
        shape_class=xover.linear_shape_class(m, k, n))
    if dec.is_event and dec.route != event_route:
        dec = _with_route(dec, event_route or "dense")
    return dec


def route_recurrent(kind: str, g: int, d: int, n: int, cfg: EngineConfig, *,
                    eligible: bool = True) -> "xover.RouteDecision":
    """Routing decision for a fire-gated recurrent decode step.

    ``kind`` is "wkv6" or "mamba"; ``g`` the flattened row count (B·H for
    wkv6, B for mamba), ``d`` the drive width (head_dim / d_inner), ``n``
    the state's trailing width (head_dim / d_state).  The dense step's work
    is the decay + increment over the full (G, D, N) state — 2·G·D·N MACs —
    and the event path scales the increment half by occupancy.
    ``eligible=False`` (see :func:`recurrent_ineligible_reason`) forces the
    visible dense fallback whatever the mode.
    """
    name = cfg.resolve_backend()
    event_route = "event" if (
        eligible and name in list_backends(f"recurrent_step_{kind}")) \
        else None
    dec = xover.decide_route(
        cfg.route, "recurrent", occupancy=cfg.occupancy_hint,
        event_route=event_route, dense_macs=float(2 * g * d * n),
        avg_touched=1.0, c_out=n, backend=name,
        shape_class=f"{kind}d{d}")
    if dec.is_event and dec.route != event_route:
        dec = _with_route(dec, event_route or "dense")
    return dec


def _with_route(dec, route: str):
    return dataclasses.replace(dec, route=route)


def _route_fields(dec: "xover.RouteDecision", shape_class: str) -> dict:
    """The per-decision trace fields every boundary record carries
    (satellite contract pinned by tests/test_routing.py)."""
    return dict(route=dec.route, est_event_cost=dec.est_event_cost,
                est_dense_cost=dec.est_dense_cost, occupancy=dec.occupancy,
                route_source=dec.source, shape_class=shape_class)


def matmul(a: jax.Array, w: jax.Array,
           cfg: EngineConfig = _DEFAULT) -> jax.Array:
    """y = a @ W via the configured backend.  a: (M, K), w: (K, N)."""
    return dispatch("matmul", cfg)(a, w, cfg)


def linear(x, w: jax.Array, b: jax.Array | None = None,
           cfg: EngineConfig = _DEFAULT) -> jax.Array:
    """y = x @ W (+ b).  ``x`` is a dense (..., K) array or an EventStream.

    EventStream inputs are consumed *directly* by event-native backends
    (block, pallas) — the chained-layer fast path.  A *conv* stream (NHWC
    ``logical_shape``) is first re-tiled to the flattened (B, H·W·C) FC
    view by static address plan — the event-domain image of
    ``dense_nhwc().reshape(B, -1)`` (DESIGN.md §12) — so the conv→FC seam
    chains events-only; re-tile-ineligible geometry decodes visibly with a
    named ``retile_ineligible_reason``.  Oracle backends (dense, scalar)
    decode once; that round-trip is exactly what they exist to measure
    against.
    """
    if isinstance(x, EventStream):
        is_conv_stream = (x.logical_shape is not None
                          and len(x.logical_shape) == 4)
        if is_conv_stream and 0 in x.logical_shape:
            # Degenerate conv stream (empty batch / 0-extent map): the FC
            # view is (B, H·W·C) — exact zero result, no backend dispatch.
            y = jnp.zeros((x.logical_shape[0], w.shape[-1]),
                          jnp.promote_types(jnp.result_type(
                              x.events.values.dtype, jnp.float32), w.dtype))
            return y if b is None else y + b
        if x.shape[0] == 0:
            # Zero-row stream (empty batch / dead layer): exact empty
            # result, no backend dispatch — Pallas must not see a 0-extent
            # launch.  Same accumulator dtype as the dispatch path, so the
            # output dtype does not flip with the batch size.
            y = jnp.zeros((0, w.shape[-1]),
                          jnp.promote_types(x.events.values.dtype, w.dtype))
            return y if b is None else y + b
        retile_reason = None
        retiled = False
        if is_conv_stream:
            retile_reason = ev.retile_ineligible_reason(
                x.logical_shape, x.blk_m, x.blk_k)
            if retile_reason is None:
                x = x.retile_fc()
                retiled = True
        if retile_reason is None:
            m, k = x.shape
        else:
            bsz, hh, ww, cc = x.logical_shape
            m, k = bsz, hh * ww * cc
        name = cfg.resolve_backend()
        dec = route_linear(m, k, w.shape[-1], cfg,
                           eligible=retile_reason is None)
        fields = _route_fields(dec,
                               xover.linear_shape_class(m, k, w.shape[-1]))
        if retiled:
            fields["retile"] = True
        if dec.is_event:
            trace.record(op="linear", backend=name, chained=True, **fields)
            return get_backend("linear_events", name)(x, w, b, cfg)
        if dec.source == "geometry":
            # No event path serves this stream (re-tile-ineligible conv
            # geometry or backend without the op): visible decode, with
            # the named rule when a re-tile was refused.
            if retile_reason is not None:
                fields["reason"] = retile_reason
            trace.record(op="linear", backend=name, fallback_decode=True,
                         **fields)
        else:
            # Dense by *choice* (adaptive / forced): the cost model says
            # dense wins here — not a fallback, and the smoke gate must
            # not count it as one.
            trace.record(op="linear", backend=name, routed_dense=True,
                         **fields)
        xd = x.dense_nhwc().reshape(m, k) if (is_conv_stream and
                                              not retiled) else x.dense()
        return linear(xd, w, b, cfg)
    lead = x.shape[:-1]
    y = dispatch("linear", cfg)(x.reshape(-1, x.shape[-1]), w, b, cfg)
    return y.reshape(*lead, w.shape[-1])


def conv2d(x, w: jax.Array, b: jax.Array | None = None,
           cfg: EngineConfig = _DEFAULT, *, stride: int = 1,
           padding: int = 0) -> jax.Array:
    """2-D convolution.  x: (B, H, W, CI) dense or a conv ``EventStream``
    (NHWC ``logical_shape`` — what ``fire_conv`` emits), w: (KH, KW, CI, CO).

    Conv streams are consumed *directly* by event-native backends — layer
    L's fired feature-map events feed layer L+1's k·k taps with no dense map
    materialized (DESIGN.md §5).  A strip-aligned stream (blk_m == STRIP_W)
    on a strip-eligible layer rides ``conv2d_events_strip`` — the fused-tap
    path: one kernel launch for the whole layer, event grid STRIP_W-fold
    smaller (DESIGN.md §6); downsampling convs (stride 2 and AlexNet's
    stride-4 conv1 alike) ride it too, each tap gathering interleaved
    partial strips (``core.events.STRIP_STRIDES``), dead straddle parts
    compacted out of the inner grid at plan time.
    A pixel-granular stream takes the per-tap ``conv2d_events`` path (k·k
    row-group gathers — the oracle the fused kernel is bit-exact against).
    Backends without the matching event op, and strip streams whose
    geometry cannot ride the fused kernel, decode once; every such fallback
    is visible to ``trace_dispatch``.  Under ``cfg.route`` ("adaptive" or a
    forced label) the boundary instead takes the :func:`route_conv`
    decision — the chosen route and its cost estimates ride every record
    (DESIGN.md §11).
    """
    if isinstance(x, EventStream):
        name = cfg.resolve_backend()
        is_conv_stream = (x.logical_shape is not None
                          and len(x.logical_shape) == 4)
        if is_conv_stream and x.shape[0] == 0:
            # Empty batch: exact empty output, no backend dispatch (Pallas
            # must not see a 0-extent launch).  Accumulator dtype matches
            # the dispatch path (batch size must not change the dtype).
            bsz, h, wd, _ = x.logical_shape
            from repro.core.mnf_conv import conv_out_size
            oy = conv_out_size(h, w.shape[0], stride, padding)
            ox = conv_out_size(wd, w.shape[1], stride, padding)
            y = jnp.zeros((bsz, oy, ox, w.shape[-1]),
                          jnp.promote_types(x.events.values.dtype, w.dtype))
            return y if b is None else y + b
        k = w.shape[0]
        if is_conv_stream:
            dec = route_conv(x.logical_shape, w.shape, cfg, stride=stride,
                             padding=padding, blk_m=x.blk_m)
            fields = _route_fields(dec, f"k{k}s{stride}")
            if dec.route == "strip":
                # Compacted inner-grid accounting rides every strip record
                # (the BENCH per-layer compaction column reads these).
                subtaps, subtaps_worst = ev.strip_subtap_counts(
                    k, padding, stride)
                trace.record(op="conv2d", backend=name, chained=True,
                             strip=True, launches=1, stride=stride,
                             subtaps=subtaps, subtaps_worst=subtaps_worst,
                             compaction=subtaps / subtaps_worst,
                             **fields)
                return get_backend("conv2d_events_strip", name)(
                    x, w, b, cfg, stride, padding)
            if dec.route == "pixel":
                trace.record(op="conv2d", backend=name, chained=True,
                             launches=k * k, **fields)
                return get_backend("conv2d_events", name)(x, w, b, cfg,
                                                          stride, padding)
            if dec.source == "geometry":
                # No event path serves this stream (ineligible geometry or
                # backend without the op): visible decode, never a silent
                # re-tile.
                trace.record(op="conv2d", backend=name, fallback_decode=True,
                             strip=x.blk_m == ev.STRIP_W, **fields)
            else:
                # Dense by *choice* (adaptive / forced): the cost model says
                # dense wins this boundary — recorded as routed_dense, not a
                # fallback.  ``dense_nhwc`` reads the kept twin when the
                # producer kept it; otherwise it decodes (the planner keeps
                # twins at boundaries it knows will route dense).
                trace.record(op="conv2d", backend=name, routed_dense=True,
                             **fields)
            x = x.dense_nhwc()
        else:
            # Not a conv stream at all (no NHWC logical_shape): rough
            # estimates so even this record carries the routing schema.
            dec = xover.decide_route(
                cfg.route, "conv", occupancy=cfg.occupancy_hint,
                event_route=None,
                dense_macs=float(x.shape[0] * x.shape[1] * w.shape[-1]),
                avg_touched=1.0, c_out=w.shape[-1], backend=name)
            trace.record(op="conv2d", backend=name, fallback_decode=True,
                         **_route_fields(dec, f"k{k}s{stride}"))
            x = x.dense()
    return dispatch("conv2d", cfg)(x, w, b, cfg, stride, padding)


def pool_ineligible_reason(x, k: int, stride: int | None = None,
                           cfg: EngineConfig = _DEFAULT) -> str | None:
    """Why ``maxpool2d`` cannot pool ``x`` in the event domain (None = can).

    ``x`` is an :class:`EventStream` or an NHWC ``logical_shape`` tuple
    (models decide boundary formats statically, before the stream exists).
    The segment max runs with identity 0, so it needs a ReLU-family stream:
    every event value non-negative (``magnitude`` fire can emit negative
    events and is ineligible), event-absent positions exactly 0.  The
    geometry must give the VALID window at least one output pixel, and the
    resolved backend must register the ``maxpool2d_events`` op.
    """
    stride = k if stride is None else stride
    shape = x.logical_shape if isinstance(x, EventStream) else x
    if shape is None or len(shape) != 4:
        return "not a conv stream (no NHWC logical_shape)"
    b, h, w, c = shape
    if k < 1 or stride < 1:
        return f"degenerate window k={k}, stride={stride}"
    if h < k or w < k:
        return (f"VALID {k}x{k} window exceeds the {h}x{w} map "
                f"(no output pixels)")
    if cfg.magnitude:
        return ("magnitude fire can emit negative events; the segment max "
                "runs with identity 0 and needs a ReLU-family stream")
    if isinstance(x, EventStream) and x.signed:
        return ("stream carries signed event values (signed/magnitude "
                "fire); the segment max runs with identity 0 and needs a "
                "ReLU-family stream")
    name = cfg.resolve_backend()
    if name not in list_backends("maxpool2d_events"):
        return f"backend {name!r} has no maxpool2d_events op"
    return None


def maxpool2d(x, k: int, stride: int | None = None,
              cfg: EngineConfig = _DEFAULT, *, keep_dense: bool = True):
    """VALID max-pool.  x: (B, H, W, C) dense or a conv ``EventStream``.

    Conv streams are pooled *in the event domain* by eligible backends
    (``maxpool2d_events``): a segment max over the stream's pixel/strip
    events — fire emits non-negative values and event-absent positions are
    exactly 0, so the result is bit-identical to the dense
    ``reduce_window`` pool — re-emitted through the fire phase as a pooled
    ``EventStream`` at ``cfg.blk_m`` granularity (pick it from the
    consuming conv via :meth:`EngineConfig.for_pool`).  Conv→pool→conv
    boundaries therefore stay events-only end to end (DESIGN.md §7).
    Ineligible streams (see :func:`pool_ineligible_reason`) decode once —
    visibly, never silently — and dense inputs return the dense pooled map.

    Routing (DESIGN.md §11): :func:`route_pool` picks between the
    window-major strip grid ("window"), the per-event segment max
    ("pixel"), and — under adaptive/forced modes — a dense-by-choice pool
    of the kept twin; the dense route still re-emits through the fire
    phase, so the boundary's type and bits never depend on the route.
    """
    stride = k if stride is None else stride
    if isinstance(x, EventStream):
        qp_in = x.qparams
        if qp_in is not None:
            # Int8 stream: the segment max consumes the *dequantized* event
            # values (a per-tile scalar multiply — still event-domain, not
            # a decode), so it sees the same floats the fake-quant twin
            # pools, bitwise.  The pooled stream re-quantizes below under
            # the SAME QParams — quantize∘dequantize is exact on in-range
            # int8, so pooling never recalibrates (DESIGN.md §12).
            x = x.dequantize_events()
        name = cfg.resolve_backend()
        reason = pool_ineligible_reason(x, k, stride, cfg)
        shape_ok = (x.logical_shape is not None
                    and len(x.logical_shape) == 4)
        if shape_ok:
            dec = route_pool(x.logical_shape, k, stride, cfg, blk_m=x.blk_m,
                             eligible=reason is None)
        else:
            dec = xover.decide_route(
                cfg.route, "pool", occupancy=cfg.occupancy_hint,
                event_route=None, dense_macs=float(x.shape[0] * x.shape[1]),
                avg_touched=1.0, c_out=x.shape[1], backend=name)
        fields = _route_fields(
            dec, f"k{k}s{stride}c{x.logical_shape[3]}" if shape_ok
            else f"k{k}s{stride}")
        if reason is None:
            b, h, w, c = x.logical_shape
            oh = (h - k) // stride + 1
            ow = (w - k) // stride + 1
            # Emitted granularity: cfg.blk_m (the for_pool config path); a
            # pooled width that cannot tile strips stays pixel-granular —
            # consumers trust blk_m == STRIP_W implies W % STRIP_W == 0.
            bm = cfg.blk_m if cfg.blk_m == 1 or (
                cfg.blk_m == ev.STRIP_W and ow % ev.STRIP_W == 0) else 1
            if x.shape[0] == 0:        # degenerate stream: exact empty out
                return EventStream.empty(
                    (b * oh * ow, c), blk_m=bm, blk_k=cfg.blk_k,
                    dtype=x.events.values.dtype,
                    logical_shape=(b, oh, ow, c))
            if dec.is_event:
                # "window" rides the window-major strip grid (one step per
                # output strip); "pixel" the general per-event segment max.
                op_name = ("maxpool2d_events_window" if dec.route == "window"
                           else "maxpool2d_events")
                trace.record(op="maxpool2d", backend=name, chained=True,
                             pool_events=True, launches=1, **fields)
                rows = get_backend(op_name, name)(x, k, stride, cfg)
            else:
                # Dense by *choice* (adaptive / forced): pool the dense twin
                # — free when the producer kept it — through the dense
                # dispatch.  Bit-identical to the segment max, and the
                # boundary stays type-stable (re-emitted stream below).
                trace.record(op="maxpool2d", backend=name, routed_dense=True,
                             **fields)
                rows = dispatch("maxpool2d", cfg)(
                    x.dense_nhwc(), k, stride, cfg).reshape(b * oh * ow, c)
            # Pooled values are already fired (non-negative, sub-threshold
            # zeroed upstream): fire at threshold 0 is the identity
            # re-emission at the consumer's granularity.
            if qp_in is None:
                return fire_conv(rows.reshape(b, oh, ow, c),
                                 cfg.replace(threshold=0.0, int8_events=False),
                                 keep_dense=keep_dense, blk_m=bm)
            # Int8 passthrough: every pooled value is a dequantized event
            # value, so quantizing under the incoming QParams recovers the
            # original int8 codes exactly — no calibration, no new scale.
            from repro.core.quantize import quantize
            q_rows = quantize(rows, qp_in, bits=cfg.int8_bits)
            s = EventStream.encode_nhwc(q_rows.reshape(b, oh, ow, c),
                                        blk_k=cfg.blk_k, blk_m=bm,
                                        capacity=cfg.capacity, threshold=0.0,
                                        keep_dense=False)
            return dataclasses.replace(
                s, fired=rows if keep_dense else None, qparams=qp_in)
        trace.record(op="maxpool2d", backend=name, fallback_decode=True,
                     reason=reason, **fields)
        x = x.dense_nhwc() if x.logical_shape is not None else x.dense()
    return dispatch("maxpool2d", cfg)(x, k, stride, cfg)


# ---------------------------------------------------------------------------
# Fire-gated recurrent decode (DESIGN.md §13): the per-token state-update
# *increment drive* (wkv6's key vector, Mamba's Δt·x gate) is thresholded
# by signed fire and the state update skips dead channel-blocks — the decay
# applies everywhere (it is input-independent).  At threshold 0 the gated
# step is float-equal to the dense step (the decode-time twin of the CNN
# chain's threshold-0 invariant).
# ---------------------------------------------------------------------------

def recurrent_ineligible_reason(stream, kind: str = "wkv6",
                                cfg: EngineConfig = _DEFAULT) -> str | None:
    """Why ``recurrent_step`` cannot consume ``stream`` in the event domain
    (None = can).

    The recurrent step wants a per-token row stream: one row per flattened
    (batch × head), ``blk_m == 1``, *signed* event values (per-token deltas
    are two-sided — an unsigned/ReLU-fired stream already dropped every
    negative delta, silently corrupting the state), f32 values (state
    updates accumulate in f32), and a resolved backend registering the
    ``recurrent_step_{kind}`` op.
    """
    if stream.logical_shape is not None and len(stream.logical_shape) == 4:
        return ("conv stream (NHWC logical_shape) — the recurrent step "
                "consumes per-token (G, D) row streams")
    if stream.blk_m != 1:
        return (f"recurrent drives are one row per (batch x head): blk_m "
                f"must be 1, stream has blk_m={stream.blk_m}")
    if not stream.signed:
        return ("recurrent deltas are signed; this stream was fired "
                "unsigned (ReLU fire), so negative deltas were already "
                "dropped")
    if stream.qparams is not None:
        return ("int8 event values are not supported by the recurrent "
                "step (state updates accumulate in f32)")
    name = cfg.resolve_backend()
    if name not in list_backends(f"recurrent_step_{kind}"):
        return f"backend {name!r} has no recurrent_step_{kind} op"
    return None


def fire_delta(drive: jax.Array, cfg: EngineConfig = _DEFAULT, *,
               keep_dense: bool = True) -> EventStream:
    """Signed fire over a per-token increment drive (G, D) -> row stream.

    The recurrent twin of :func:`fire`: gates on |Δ| > threshold and emits
    the *signed* value — a negative supra-threshold delta is an event, not
    a drop — at the recurrent tile geometry (``EngineConfig.for_recurrent``:
    blk_m == 1, narrow K blocks).  The emitted stream is flagged ``signed``
    so ReLU-family consumers (the pool's segment max) reject it by name and
    :func:`recurrent_step` accepts it.
    """
    from repro.core.fire import FireConfig
    from repro.core.fire import fire as jnp_fire

    c = cfg.for_recurrent(drive.shape[-1]).for_width(*drive.shape)
    if 0 in drive.shape:
        # Degenerate drive (empty batch / zero-width channel axis): explicit
        # empty stream, no encode machinery (Pallas consumers must not see
        # a 0-extent launch).
        s = EventStream.empty(drive.shape, blk_m=1, blk_k=c.blk_k,
                              capacity=c.capacity, dtype=drive.dtype,
                              fired=drive if keep_dense else None)
        return dataclasses.replace(s, signed=True)
    fired = jnp_fire(drive, FireConfig(threshold=c.threshold, signed=True))
    s = EventStream.encode(fired, blk_m=1, blk_k=c.blk_k,
                           capacity=c.capacity, threshold=0.0,
                           keep_dense=keep_dense)
    return dataclasses.replace(s, signed=True)


def _recurrent_dense_step(kind: str, drive: jax.Array, state: jax.Array,
                          ops: dict):
    """The dense oracle of one recurrent step (the fallback path — same
    formulation the event backends use, so the route never changes bits at
    threshold 0 on the block backend)."""
    if kind == "wkv6":
        from repro.kernels.wkv6.step import wkv6_step_ref
        return wkv6_step_ref(ops["r"], drive, ops["v"], ops["w"], ops["u"],
                             state)
    from repro.kernels.mamba_scan.step import mamba_step_ref
    return mamba_step_ref(drive, ops["da"], ops["bmat"], ops["cmat"], state)


def recurrent_step(kind: str, stream: EventStream, state: jax.Array,
                   cfg: EngineConfig = _DEFAULT, **ops):
    """One fire-gated recurrent decode step (DESIGN.md §13).

    kind:    "wkv6" (ops r, v, w, u; state (G, D, D)) or
             "mamba" (ops da, bmat, cmat; state (B, DI, N)).
    stream:  signed row stream of the increment drive (``fire_delta``).
    Returns (readout, new_state) — for wkv6 the per-row output o (G, D)
    and S'; for mamba the state readout y (B, DI) (skip/gate terms are the
    model's) and h'.

    Event-eligible streams (see :func:`recurrent_ineligible_reason`)
    dispatch to the backend's gated kernel, which skips the state-update
    increment on dead channel-blocks; ineligible streams fall back to the
    dense oracle on the stream's dense view — visibly, with the named rule
    on the trace record.  Zero-extent steps (empty batch, zero-width
    drive) short-circuit to the oracle before any dispatch — Pallas must
    not see a 0-extent launch.
    """
    assert kind in ("wkv6", "mamba"), kind
    g, d = stream.shape
    if g == 0 or d == 0:
        drive = stream.fired if stream.fired is not None \
            else jnp.zeros(stream.shape, jnp.float32)
        return _recurrent_dense_step(kind, drive, state, ops)
    name = cfg.resolve_backend()
    reason = recurrent_ineligible_reason(stream, kind, cfg)
    n = state.shape[-1]
    dec = route_recurrent(kind, g, d, n, cfg, eligible=reason is None)
    fields = _route_fields(dec, f"{kind}d{d}")
    if dec.is_event:
        trace.record(op="recurrent_step", kind=kind, backend=name,
                     chained=True, **fields)
        return get_backend(f"recurrent_step_{kind}", name)(
            stream, state, ops, cfg)
    if dec.source == "geometry":
        # No event path serves this stream (ineligible stream or backend
        # without the op): visible fallback with the named rule.
        if reason is not None:
            fields["reason"] = reason
        trace.record(op="recurrent_step", kind=kind, backend=name,
                     fallback_decode=True, **fields)
    else:
        # Dense by *choice* (adaptive / forced): not a fallback.
        trace.record(op="recurrent_step", kind=kind, backend=name,
                     routed_dense=True, **fields)
    return _recurrent_dense_step(kind, stream.dense(), state, ops)


def _fire_int8(acc2: jax.Array, cfg: EngineConfig, c2: EngineConfig,
               keep_dense: bool, logical_shape: tuple | None = None
               ) -> EventStream:
    """Int8 fire (DESIGN.md §12): threshold the accumulator, dynamically
    calibrate a *symmetric* QParams over the fired map (zero point 0 — an
    absent event must be an exact zero in both domains), requantize the
    accumulator into it (unit input/weight scales: the engine dequantizes
    at tile load, so accumulators carry real values), and encode the int8
    codes at threshold 0.  The kept twin is the dequantized map — exactly
    the fake-quant round-trip's values, which is what makes the int8 chain
    bitwise against its fake-quant twin within a backend."""
    from repro.core.fire import FireConfig
    from repro.core.fire import fire as jnp_fire
    from repro.core.quantize import (QParams, calibrate, dequantize,
                                     requantize_accumulator)

    fired = jnp_fire(acc2, FireConfig(threshold=c2.threshold,
                                      magnitude=c2.magnitude,
                                      signed=c2.signed))
    qp = calibrate(fired, symmetric=True, bits=cfg.int8_bits)
    unit = QParams.symmetric(1.0)
    q = requantize_accumulator(fired, unit, unit, qp, bits=cfg.int8_bits)
    s = EventStream.encode(q, blk_m=c2.blk_m, blk_k=c2.blk_k,
                           capacity=c2.capacity, threshold=0.0,
                           keep_dense=False)
    return dataclasses.replace(
        s, fired=dequantize(q, qp) if keep_dense else None, qparams=qp,
        logical_shape=logical_shape,
        signed=c2.magnitude or c2.signed)


def fire(acc: jax.Array, cfg: EngineConfig = _DEFAULT, *,
         keep_dense: bool = True) -> EventStream:
    """Fire phase: threshold ``acc`` (M, K) and emit next-layer events.

    Returns an EventStream ready to feed ``linear`` with no re-encode.
    ``keep_dense=False`` drops the dense twin so downstream code provably
    runs event-only.  With ``cfg.int8_events`` the emitted values are int8
    codes carrying a symmetric ``QParams`` on the stream (the jnp fire +
    encode lowering — the fused Pallas fire kernel stays f32); consumers
    dequantize at tile load (DESIGN.md §12).
    """
    # Clamp once here and hand the backend the *same* geometry the stream
    # records — a custom fire backend must see the tile sizes the consuming
    # linear will assume.
    c = cfg.for_width(*acc.shape)
    signed = cfg.magnitude or cfg.signed
    if 0 in acc.shape:
        # Degenerate accumulator: explicit empty stream, no backend dispatch
        # (a Pallas fire backend must not see a 0-extent launch).
        s = EventStream.empty(acc.shape, blk_m=c.blk_m, blk_k=c.blk_k,
                              capacity=c.capacity, dtype=acc.dtype,
                              fired=acc if keep_dense else None)
        return dataclasses.replace(s, signed=signed)
    if cfg.int8_events:
        return _fire_int8(acc, cfg, c, keep_dense)
    fired, bev = dispatch("fire", cfg)(acc, c)
    stream = EventStream(events=bev, fired=fired if keep_dense else None,
                         shape=acc.shape, blk_m=c.blk_m, blk_k=c.blk_k,
                         signed=signed)
    return stream


def fire_conv(acc: jax.Array, cfg: EngineConfig = _DEFAULT, *,
              keep_dense: bool = True, blk_m: int = 1) -> EventStream:
    """Fire phase over a conv accumulator (B, OY, OX, CO) -> conv stream.

    ``blk_m`` picks the emitted granularity: 1 (default) is pixel-granular —
    the per-tap path's row-group gather unit; STRIP_W emits a strip-aligned
    stream (8-pixel row strips, requires W % STRIP_W == 0) for a consumer
    the fused-tap kernel can serve (DESIGN.md §6) — choose it from the
    *next* layer's geometry (``core.events.strip_eligible``).  Either way
    ``engine.conv2d`` accepts the stream with no re-encode.
    ``keep_dense=False`` drops the fired twin so a conv→conv boundary
    provably runs event-only; keep it when the consumer is a pool (the pool
    reads the twin for free — the fire phase computes it anyway).
    """
    b, h, w, c = acc.shape
    assert blk_m == 1 or (blk_m == ev.STRIP_W and w % ev.STRIP_W == 0), \
        (blk_m, acc.shape, "strip streams need blk_m == STRIP_W and "
                           "W % STRIP_W == 0")
    acc2 = acc.reshape(b * h * w, c)
    c2 = cfg.replace(blk_m=blk_m).for_width(*acc2.shape)
    signed = cfg.magnitude or cfg.signed
    if 0 in acc2.shape:
        s = EventStream.empty(acc2.shape, blk_m=c2.blk_m, blk_k=c2.blk_k,
                              capacity=c2.capacity, dtype=acc.dtype,
                              fired=acc2 if keep_dense else None,
                              logical_shape=(b, h, w, c))
        return dataclasses.replace(s, signed=signed)
    if cfg.int8_events:
        return _fire_int8(acc2, cfg, c2, keep_dense,
                          logical_shape=(b, h, w, c))
    fired, bev = dispatch("fire_conv", cfg)(acc2, c2)
    return EventStream(events=bev, fired=fired if keep_dense else None,
                       shape=acc2.shape, blk_m=c2.blk_m, blk_k=c2.blk_k,
                       logical_shape=(b, h, w, c), signed=signed)


def sparsify(h: jax.Array, cfg: EngineConfig = _DEFAULT) -> jax.Array:
    """Shape-preserving fire + dead-tile masking on (..., K) activations.

    The pure-XLA image of the MNF multiply phase used inside LM blocks
    (models/layers.mnf_sparsify): with threshold 0 and a ReLU-family
    activation it is the identity; with threshold > 0 whole event-free
    (blk_m, blk_k) tiles are zeroed, matching what the event_matmul kernel
    skips — HLO FLOPs stay truthful for the dry-run (DESIGN.md §2).
    """
    from repro.core.fire import FireConfig
    from repro.core.fire import fire as jnp_fire
    from repro.kernels.event_matmul.ref import mask_dead_blocks

    fired = jnp_fire(h, FireConfig(threshold=cfg.threshold,
                                   magnitude=cfg.magnitude))
    if cfg.threshold <= 0.0:
        return fired
    shp = h.shape
    h2 = fired.reshape(-1, shp[-1])
    pad_m = (-h2.shape[0]) % cfg.blk_m
    pad_k = (-h2.shape[1]) % cfg.blk_k
    h2 = jnp.pad(h2, ((0, pad_m), (0, pad_k)))
    h2 = mask_dead_blocks(h2, blk_m=cfg.blk_m, blk_k=cfg.blk_k, threshold=0.0)
    return h2[:h2.shape[0] - pad_m or None, :shp[-1]].reshape(shp)


def describe(cfg: EngineConfig = _DEFAULT) -> dict:
    """Resolved engine configuration (what serve/dry-run report)."""
    r = cfg.resolved()
    return dict(backend=r.backend, interpret=r.interpret, blk_m=r.blk_m,
                blk_k=r.blk_k, blk_n=r.blk_n, capacity=r.capacity,
                threshold=r.threshold, magnitude=r.magnitude,
                device=jax.default_backend())
