"""Engine front-door ops (DESIGN.md §3) — the only entry points the model
stack uses for MNF compute.

Every op takes an :class:`EngineConfig` and dispatches through the backend
registry; ``linear`` additionally accepts an :class:`EventStream` so
consecutive MNF layers chain events without a decode→re-encode round-trip
(the paper's end-to-end event dataflow).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.engine import trace
from repro.engine.config import EngineConfig
from repro.engine.registry import dispatch, get_backend, list_backends
from repro.engine.stream import EventStream

__all__ = ["matmul", "linear", "conv2d", "maxpool2d",
           "pool_ineligible_reason", "fire", "fire_conv", "sparsify",
           "describe"]

_DEFAULT = EngineConfig()


def matmul(a: jax.Array, w: jax.Array,
           cfg: EngineConfig = _DEFAULT) -> jax.Array:
    """y = a @ W via the configured backend.  a: (M, K), w: (K, N)."""
    return dispatch("matmul", cfg)(a, w, cfg)


def linear(x, w: jax.Array, b: jax.Array | None = None,
           cfg: EngineConfig = _DEFAULT) -> jax.Array:
    """y = x @ W (+ b).  ``x`` is a dense (..., K) array or an EventStream.

    EventStream inputs are consumed *directly* by event-native backends
    (block, pallas) — the chained-layer fast path.  Oracle backends (dense,
    scalar) decode once; that round-trip is exactly what they exist to
    measure against.
    """
    if isinstance(x, EventStream):
        if x.shape[0] == 0:
            # Zero-row stream (empty batch / dead layer): exact empty
            # result, no backend dispatch — Pallas must not see a 0-extent
            # launch.  Same accumulator dtype as the dispatch path, so the
            # output dtype does not flip with the batch size.
            y = jnp.zeros((0, w.shape[-1]),
                          jnp.promote_types(x.events.values.dtype, w.dtype))
            return y if b is None else y + b
        name = cfg.resolve_backend()
        if name in list_backends("linear_events"):
            trace.record(op="linear", backend=name, chained=True)
            return get_backend("linear_events", name)(x, w, b, cfg)
        trace.record(op="linear", backend=name, fallback_decode=True)
        return linear(x.dense(), w, b, cfg)
    lead = x.shape[:-1]
    y = dispatch("linear", cfg)(x.reshape(-1, x.shape[-1]), w, b, cfg)
    return y.reshape(*lead, w.shape[-1])


def conv2d(x, w: jax.Array, b: jax.Array | None = None,
           cfg: EngineConfig = _DEFAULT, *, stride: int = 1,
           padding: int = 0) -> jax.Array:
    """2-D convolution.  x: (B, H, W, CI) dense or a conv ``EventStream``
    (NHWC ``logical_shape`` — what ``fire_conv`` emits), w: (KH, KW, CI, CO).

    Conv streams are consumed *directly* by event-native backends — layer
    L's fired feature-map events feed layer L+1's k·k taps with no dense map
    materialized (DESIGN.md §5).  A strip-aligned stream (blk_m == STRIP_W)
    on a strip-eligible layer rides ``conv2d_events_strip`` — the fused-tap
    path: one kernel launch for the whole layer, event grid STRIP_W-fold
    smaller (DESIGN.md §6); stride-2 downsampling convs ride it too, each
    tap gathering interleaved half-strips (``core.events.STRIP_STRIDES``).
    A pixel-granular stream takes the per-tap ``conv2d_events`` path (k·k
    row-group gathers — the oracle the fused kernel is bit-exact against).
    Backends without the matching event op, and strip streams whose
    geometry cannot ride the fused kernel, decode once; every such fallback
    is visible to ``trace_dispatch``.
    """
    if isinstance(x, EventStream):
        name = cfg.resolve_backend()
        is_conv_stream = (x.logical_shape is not None
                          and len(x.logical_shape) == 4)
        if is_conv_stream and x.shape[0] == 0:
            # Empty batch: exact empty output, no backend dispatch (Pallas
            # must not see a 0-extent launch).  Accumulator dtype matches
            # the dispatch path (batch size must not change the dtype).
            bsz, h, wd, _ = x.logical_shape
            from repro.core.mnf_conv import conv_out_size
            oy = conv_out_size(h, w.shape[0], stride, padding)
            ox = conv_out_size(wd, w.shape[1], stride, padding)
            y = jnp.zeros((bsz, oy, ox, w.shape[-1]),
                          jnp.promote_types(x.events.values.dtype, w.dtype))
            return y if b is None else y + b
        k = w.shape[0]
        if is_conv_stream and x.blk_m == ev.STRIP_W:
            if (ev.strip_eligible(x.logical_shape[2], k, stride, padding,
                                  co=w.shape[-1])
                    and name in list_backends("conv2d_events_strip")):
                trace.record(op="conv2d", backend=name, chained=True,
                             strip=True, launches=1, stride=stride)
                return get_backend("conv2d_events_strip", name)(
                    x, w, b, cfg, stride, padding)
            # A strip stream the fused path cannot consume (ineligible
            # geometry or backend without the op): visible decode, never a
            # silent re-tile.
            trace.record(op="conv2d", backend=name, fallback_decode=True,
                         strip=True)
            x = x.dense_nhwc()
        elif is_conv_stream and name in list_backends("conv2d_events"):
            trace.record(op="conv2d", backend=name, chained=True,
                         launches=k * k)
            return get_backend("conv2d_events", name)(x, w, b, cfg, stride,
                                                      padding)
        else:
            trace.record(op="conv2d", backend=name, fallback_decode=True)
            x = x.dense_nhwc() if is_conv_stream else x.dense()
    return dispatch("conv2d", cfg)(x, w, b, cfg, stride, padding)


def pool_ineligible_reason(x, k: int, stride: int | None = None,
                           cfg: EngineConfig = _DEFAULT) -> str | None:
    """Why ``maxpool2d`` cannot pool ``x`` in the event domain (None = can).

    ``x`` is an :class:`EventStream` or an NHWC ``logical_shape`` tuple
    (models decide boundary formats statically, before the stream exists).
    The segment max runs with identity 0, so it needs a ReLU-family stream:
    every event value non-negative (``magnitude`` fire can emit negative
    events and is ineligible), event-absent positions exactly 0.  The
    geometry must give the VALID window at least one output pixel, and the
    resolved backend must register the ``maxpool2d_events`` op.
    """
    stride = k if stride is None else stride
    shape = x.logical_shape if isinstance(x, EventStream) else x
    if shape is None or len(shape) != 4:
        return "not a conv stream (no NHWC logical_shape)"
    b, h, w, c = shape
    if k < 1 or stride < 1:
        return f"degenerate window k={k}, stride={stride}"
    if h < k or w < k:
        return (f"VALID {k}x{k} window exceeds the {h}x{w} map "
                f"(no output pixels)")
    if cfg.magnitude:
        return ("magnitude fire can emit negative events; the segment max "
                "runs with identity 0 and needs a ReLU-family stream")
    name = cfg.resolve_backend()
    if name not in list_backends("maxpool2d_events"):
        return f"backend {name!r} has no maxpool2d_events op"
    return None


def maxpool2d(x, k: int, stride: int | None = None,
              cfg: EngineConfig = _DEFAULT, *, keep_dense: bool = True):
    """VALID max-pool.  x: (B, H, W, C) dense or a conv ``EventStream``.

    Conv streams are pooled *in the event domain* by eligible backends
    (``maxpool2d_events``): a segment max over the stream's pixel/strip
    events — fire emits non-negative values and event-absent positions are
    exactly 0, so the result is bit-identical to the dense
    ``reduce_window`` pool — re-emitted through the fire phase as a pooled
    ``EventStream`` at ``cfg.blk_m`` granularity (pick it from the
    consuming conv via :meth:`EngineConfig.for_pool`).  Conv→pool→conv
    boundaries therefore stay events-only end to end (DESIGN.md §7).
    Ineligible streams (see :func:`pool_ineligible_reason`) decode once —
    visibly, never silently — and dense inputs return the dense pooled map.
    """
    stride = k if stride is None else stride
    if isinstance(x, EventStream):
        name = cfg.resolve_backend()
        reason = pool_ineligible_reason(x, k, stride, cfg)
        if reason is None:
            b, h, w, c = x.logical_shape
            oh = (h - k) // stride + 1
            ow = (w - k) // stride + 1
            # Emitted granularity: cfg.blk_m (the for_pool config path); a
            # pooled width that cannot tile strips stays pixel-granular —
            # consumers trust blk_m == STRIP_W implies W % STRIP_W == 0.
            bm = cfg.blk_m if cfg.blk_m == 1 or (
                cfg.blk_m == ev.STRIP_W and ow % ev.STRIP_W == 0) else 1
            if x.shape[0] == 0:        # degenerate stream: exact empty out
                return EventStream.empty(
                    (b * oh * ow, c), blk_m=bm, blk_k=cfg.blk_k,
                    dtype=x.events.values.dtype,
                    logical_shape=(b, oh, ow, c))
            trace.record(op="maxpool2d", backend=name, chained=True,
                         pool_events=True, launches=1)
            rows = get_backend("maxpool2d_events", name)(x, k, stride, cfg)
            # Pooled values are already fired (non-negative, sub-threshold
            # zeroed upstream): fire at threshold 0 is the identity
            # re-emission at the consumer's granularity.
            return fire_conv(rows.reshape(b, oh, ow, c),
                             cfg.replace(threshold=0.0),
                             keep_dense=keep_dense, blk_m=bm)
        trace.record(op="maxpool2d", backend=name, fallback_decode=True,
                     reason=reason)
        x = x.dense_nhwc() if x.logical_shape is not None else x.dense()
    return dispatch("maxpool2d", cfg)(x, k, stride, cfg)


def fire(acc: jax.Array, cfg: EngineConfig = _DEFAULT, *,
         keep_dense: bool = True) -> EventStream:
    """Fire phase: threshold ``acc`` (M, K) and emit next-layer events.

    Returns an EventStream ready to feed ``linear`` with no re-encode.
    ``keep_dense=False`` drops the dense twin so downstream code provably
    runs event-only.
    """
    # Clamp once here and hand the backend the *same* geometry the stream
    # records — a custom fire backend must see the tile sizes the consuming
    # linear will assume.
    c = cfg.for_width(*acc.shape)
    if 0 in acc.shape:
        # Degenerate accumulator: explicit empty stream, no backend dispatch
        # (a Pallas fire backend must not see a 0-extent launch).
        return EventStream.empty(acc.shape, blk_m=c.blk_m, blk_k=c.blk_k,
                                 capacity=c.capacity, dtype=acc.dtype,
                                 fired=acc if keep_dense else None)
    fired, bev = dispatch("fire", cfg)(acc, c)
    stream = EventStream(events=bev, fired=fired if keep_dense else None,
                         shape=acc.shape, blk_m=c.blk_m, blk_k=c.blk_k)
    return stream


def fire_conv(acc: jax.Array, cfg: EngineConfig = _DEFAULT, *,
              keep_dense: bool = True, blk_m: int = 1) -> EventStream:
    """Fire phase over a conv accumulator (B, OY, OX, CO) -> conv stream.

    ``blk_m`` picks the emitted granularity: 1 (default) is pixel-granular —
    the per-tap path's row-group gather unit; STRIP_W emits a strip-aligned
    stream (8-pixel row strips, requires W % STRIP_W == 0) for a consumer
    the fused-tap kernel can serve (DESIGN.md §6) — choose it from the
    *next* layer's geometry (``core.events.strip_eligible``).  Either way
    ``engine.conv2d`` accepts the stream with no re-encode.
    ``keep_dense=False`` drops the fired twin so a conv→conv boundary
    provably runs event-only; keep it when the consumer is a pool (the pool
    reads the twin for free — the fire phase computes it anyway).
    """
    b, h, w, c = acc.shape
    assert blk_m == 1 or (blk_m == ev.STRIP_W and w % ev.STRIP_W == 0), \
        (blk_m, acc.shape, "strip streams need blk_m == STRIP_W and "
                           "W % STRIP_W == 0")
    acc2 = acc.reshape(b * h * w, c)
    c2 = cfg.replace(blk_m=blk_m).for_width(*acc2.shape)
    if 0 in acc2.shape:
        return EventStream.empty(acc2.shape, blk_m=c2.blk_m, blk_k=c2.blk_k,
                                 capacity=c2.capacity, dtype=acc.dtype,
                                 fired=acc2 if keep_dense else None,
                                 logical_shape=(b, h, w, c))
    fired, bev = dispatch("fire_conv", cfg)(acc2, c2)
    return EventStream(events=bev, fired=fired if keep_dense else None,
                       shape=acc2.shape, blk_m=c2.blk_m, blk_k=c2.blk_k,
                       logical_shape=(b, h, w, c))


def sparsify(h: jax.Array, cfg: EngineConfig = _DEFAULT) -> jax.Array:
    """Shape-preserving fire + dead-tile masking on (..., K) activations.

    The pure-XLA image of the MNF multiply phase used inside LM blocks
    (models/layers.mnf_sparsify): with threshold 0 and a ReLU-family
    activation it is the identity; with threshold > 0 whole event-free
    (blk_m, blk_k) tiles are zeroed, matching what the event_matmul kernel
    skips — HLO FLOPs stay truthful for the dry-run (DESIGN.md §2).
    """
    from repro.core.fire import FireConfig
    from repro.core.fire import fire as jnp_fire
    from repro.kernels.event_matmul.ref import mask_dead_blocks

    fired = jnp_fire(h, FireConfig(threshold=cfg.threshold,
                                   magnitude=cfg.magnitude))
    if cfg.threshold <= 0.0:
        return fired
    shp = h.shape
    h2 = fired.reshape(-1, shp[-1])
    pad_m = (-h2.shape[0]) % cfg.blk_m
    pad_k = (-h2.shape[1]) % cfg.blk_k
    h2 = jnp.pad(h2, ((0, pad_m), (0, pad_k)))
    h2 = mask_dead_blocks(h2, blk_m=cfg.blk_m, blk_k=cfg.blk_k, threshold=0.0)
    return h2[:h2.shape[0] - pad_m or None, :shp[-1]].reshape(shp)


def describe(cfg: EngineConfig = _DEFAULT) -> dict:
    """Resolved engine configuration (what serve/dry-run report)."""
    r = cfg.resolved()
    return dict(backend=r.backend, interpret=r.interpret, blk_m=r.blk_m,
                blk_k=r.blk_k, blk_n=r.blk_n, capacity=r.capacity,
                threshold=r.threshold, magnitude=r.magnitude,
                device=jax.default_backend())
