"""repro.engine — the unified MNF event-pipeline engine (DESIGN.md §3–§5).

One config, one registry, one inter-layer currency:

  * :class:`EngineConfig` consolidates every tiling/capacity/threshold/
    backend knob that used to be scattered across four divergent entry
    points (``mnf_linear``, ``tap_event_conv2d``, ``event_matmul``,
    ``fire_and_encode``).
  * The backend registry maps ``(op, backend)`` to implementations; the
    built-in dense/scalar/block/pallas paths register at import, and new
    backends (sharded, quantized) are one :func:`register_backend` away
    from every model in the repo.
  * :class:`EventStream` makes ``BlockEvents`` the currency between layers:
    ``fire`` emits it, ``linear`` consumes it directly — activations stay
    compressed end to end, the paper's core claim.

Typical use::

    from repro import engine
    cfg = engine.EngineConfig(backend="auto")
    s = engine.fire(engine.linear(x, w1, cfg=cfg), cfg)   # layer 1
    y = engine.linear(s, w2, cfg=cfg)                     # layer 2, chained
"""
from repro.core.events import (STRIP_CO_MIN, STRIP_STRIDES, STRIP_W,
                               pool_window_ineligible_reason,
                               retile_ineligible_reason, strip_eligible,
                               strip_ineligible_reason)
from repro.costmodel.crossover import linear_shape_class
from repro.engine.api import (conv2d, describe, fire, fire_conv, fire_delta,
                              linear, matmul, maxpool2d,
                              pool_ineligible_reason,
                              recurrent_ineligible_reason, recurrent_step,
                              route_conv, route_linear, route_pool,
                              route_recurrent, sparsify)
from repro.engine.config import BACKENDS, RECURRENT_BLK_K, EngineConfig
from repro.engine.registry import (dispatch, get_backend, list_backends,
                                   register_backend, registered_ops)
from repro.engine.stream import EventStream
from repro.engine.trace import trace_dispatch

import repro.engine.backends  # noqa: F401  (registers built-in backends)

__all__ = [
    "BACKENDS", "RECURRENT_BLK_K", "EngineConfig", "EventStream",
    "STRIP_CO_MIN", "STRIP_STRIDES", "STRIP_W", "strip_eligible",
    "strip_ineligible_reason", "pool_window_ineligible_reason",
    "retile_ineligible_reason", "linear_shape_class",
    "register_backend", "get_backend", "dispatch", "list_backends",
    "registered_ops",
    "matmul", "linear", "conv2d", "maxpool2d", "pool_ineligible_reason",
    "route_conv", "route_pool", "route_linear", "route_recurrent",
    "recurrent_ineligible_reason", "recurrent_step",
    "fire", "fire_conv", "fire_delta", "sparsify", "describe",
    "trace_dispatch",
]
