"""Multi-device integration: sharded steps, pipeline PP, small-mesh dry-run.

Runs in a subprocess with XLA_FLAGS forcing 8 host devices so the main test
process keeps its single real device (smoke tests must not see 512 devices).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout=520):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    r = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import make_train_step
        from repro.models import init_params
        from repro.optim import adamw_init

        cfg = get_config('qwen2-1.5b').reduced()
        cfg = dataclasses.replace(cfg, d_ff=128, vocab_size=256, fsdp=True)
        shape = ShapeConfig('t', 32, 8, 'train')
        from repro.launch.mesh import checked_mesh
        mesh = checked_mesh((2, 4), ('data', 'model'))
        plan = make_train_step(cfg, shape, mesh)
        key = jax.random.PRNGKey(0)
        with mesh:
            params = jax.jit(lambda k: init_params(k, cfg)[0],
                             out_shardings=plan.param_shardings)(key)
            opt = adamw_init(params)
            batch = dict(
                tokens=jax.random.randint(key, (8, 32), 0, 256, jnp.int32),
                labels=jax.random.randint(key, (8, 32), 0, 256, jnp.int32))
            p2, o2, metrics = plan.fn(params, opt, batch)
        loss_sharded = float(metrics['loss'])
        assert np.isfinite(loss_sharded)

        # single-device reference loss for the SAME params/batch
        from repro.models import lm_loss
        params1 = jax.jit(lambda k: init_params(k, cfg)[0])(key)
        ref = float(jax.jit(lambda p: lm_loss(p, batch, cfg))(params1))
        assert abs(loss_sharded - ref) < 5e-2, (loss_sharded, ref)
        print('OK', loss_sharded, ref)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply
        from repro.launch.mesh import checked_mesh
        mesh = checked_mesh((4,), ('pipe',))
        n_stages, n_micro, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, mb, d))

        def stage_fn(w, mb_x):
            return jnp.tanh(mb_x @ w)

        y = pipeline_apply(stage_fn, ws, x, mesh=mesh, axis='pipe')
        ref = x
        for i in range(n_stages):
            ref = jax.vmap(lambda m: stage_fn(ws[i], m))(ref)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-4)
        print('OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_small_mesh_dryrun_all_step_kinds():
    r = _run("""
        import dataclasses, jax
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import plan_cell
        from repro.launch.mesh import checked_mesh
        mesh = checked_mesh((2, 4), ('data', 'model'))
        for arch in ('qwen2-1.5b', 'deepseek-moe-16b', 'rwkv6-7b',
                     'hymba-1.5b', 'whisper-base'):
            cfg = get_config(arch).reduced()
            cfg = dataclasses.replace(cfg, d_ff=128, vocab_size=256)
            for shape in (ShapeConfig('tr', 64, 8, 'train'),
                          ShapeConfig('pf', 64, 8, 'prefill'),
                          ShapeConfig('dc', 64, 8, 'decode')):
                plan = plan_cell(cfg, shape, mesh)
                with mesh:
                    compiled = plan.fn.lower(*plan.arg_specs).compile()
                    assert compiled.cost_analysis() is not None
            print('OK', arch)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.count("OK") == 5


@pytest.mark.slow
def test_elastic_remesh_resharding():
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime import elastic_remesh, reshard_tree
        mesh8 = elastic_remesh(8, model_parallel=4)
        assert dict(zip(mesh8.axis_names, mesh8.devices.shape)) == \\
            {'data': 2, 'model': 4}
        tree = {'w': jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
        specs = {'w': ('embed', 'ff')}
        sharded = reshard_tree(tree, specs, mesh8)
        # shrink to 4 devices (simulated node loss) and reshard
        mesh4 = elastic_remesh(4, model_parallel=4)
        resharded = reshard_tree(sharded, specs, mesh4)
        np.testing.assert_array_equal(np.asarray(resharded['w']),
                                      np.asarray(tree['w']))
        print('OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_gradient_accumulation_matches_full_batch():
    r = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import make_train_step
        from repro.models import init_params
        from repro.optim import AdamWConfig, adamw_init

        cfg = get_config('qwen2-0.5b').reduced()
        cfg = dataclasses.replace(cfg, d_ff=128, vocab_size=256)
        shape = ShapeConfig('t', 32, 8, 'train')
        from repro.launch.mesh import checked_mesh
        mesh = checked_mesh((2, 4), ('data', 'model'))
        opt = AdamWConfig(lr=1e-3)
        key = jax.random.PRNGKey(0)
        batch = dict(
            tokens=jax.random.randint(key, (8, 32), 0, 256, jnp.int32),
            labels=jax.random.randint(key, (8, 32), 0, 256, jnp.int32))
        losses = {}
        for acc in (1, 4):
            plan = make_train_step(cfg, shape, mesh, opt=opt,
                                   accum_steps=acc)
            with mesh:
                params = jax.jit(lambda k: init_params(k, cfg)[0],
                                 out_shardings=plan.param_shardings)(key)
                p2, o2, m = plan.fn(params, adamw_init(params), batch)
            losses[acc] = (float(m['loss']), p2)
        assert abs(losses[1][0] - losses[4][0]) < 2e-2, losses
        d = jax.tree.map(lambda a, b: float(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
            losses[1][1], losses[4][1])
        assert max(jax.tree.leaves(d)) < 2e-2
        print('OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_moe_ep_shard_map_matches_gspmd():
    r = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.moe import moe_apply, moe_apply_ep, moe_init

        cfg = get_config('deepseek-moe-16b').reduced()
        cfg = dataclasses.replace(cfg, compute_dtype='float32')
        from repro.launch.mesh import checked_mesh
        mesh = checked_mesh((2, 4), ('data', 'model'))
        p, _ = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (4, 16, cfg.d_model), jnp.float32) * 0.3
        with mesh:
            f_ep = jax.jit(lambda pp, xx: moe_apply_ep(pp, xx, cfg))
            hlo = f_ep.lower(p, x).compile().as_text()
            assert 'all-reduce' in hlo, 'EP path did not engage'
            y_ep, aux_ep = f_ep(p, x)
        y_ref, aux_ref = jax.jit(
            lambda pp, xx: moe_apply(pp, xx, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(
            float(aux_ep['load_balance_loss']),
            float(aux_ref['load_balance_loss']), atol=1e-3)
        print('OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
