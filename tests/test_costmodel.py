"""Cost model: paper-claim validation (Fig 1/2/8, Table 4 anchors)."""
import pytest

from repro.costmodel import (PAPER_TABLE4, TABLE1, compare_dataflows,
                             mnf_utilization_at_density, network_cycles,
                             snap_utilization_at_density, table4_row)
from repro.costmodel.table4 import (ALEXNET_DENSITY_PROFILE,
                                    ALEXNET_W_DENSITY,
                                    VGG16_DENSITY_PROFILE, VGG16_W_DENSITY)
from repro.costmodel.workloads import analytic_network_stats
from repro.models.cnn import ALEXNET, VGG16


def test_fig1_mnf_wins_at_every_density():
    for shape in TABLE1.values():
        for d in (1.0, 0.6, 0.3, 0.1):
            e = compare_dataflows(shape, d, 0.6)
            assert e["mnf"] == min(e.values())


def test_fig1_advantage_grows_with_sparsity():
    shape = TABLE1["layer1"]
    gains = []
    for d in (1.0, 0.6, 0.3, 0.1):
        e = compare_dataflows(shape, d, 0.6)
        gains.append(min(e["ws"], e["inp"], e["os"]) / e["mnf"])
    assert gains == sorted(gains)


def test_fig2_mnf_flat_snap_decays():
    ds = (1.0, 0.6, 0.3, 0.1, 0.05)
    mnf = [mnf_utilization_at_density(d) for d in ds]
    snap = [snap_utilization_at_density(d) for d in ds]
    assert min(mnf) > 0.9                      # ~100% at all densities
    assert max(mnf) - min(mnf) < 0.08          # flat
    assert snap[0] > snap[-1] and snap[-1] < 0.5


def test_fig8_vgg16_anchors():
    stats = analytic_network_stats(VGG16, VGG16_DENSITY_PROFILE)
    mnf = network_cycles(stats, "mnf", d_w=VGG16_W_DENSITY)
    for design, paper in (("scnn_dense", 19.0), ("scnn", 8.31),
                          ("sparten", 3.15), ("gospa", 2.57)):
        ours = network_cycles(stats, design, d_w=VGG16_W_DENSITY) / mnf
        assert ours == pytest.approx(paper, rel=0.02), design


def test_fig8_alexnet_heldout_within_20pct():
    stats = analytic_network_stats(ALEXNET, ALEXNET_DENSITY_PROFILE)
    mnf = network_cycles(stats, "mnf", d_w=ALEXNET_W_DENSITY)
    for design, paper in (("scnn", 7.32), ("sparten", 3.51),
                          ("gospa", 2.68)):
        ours = network_cycles(stats, design, d_w=ALEXNET_W_DENSITY) / mnf
        assert abs(ours - paper) / paper < 0.20, (design, ours)


def test_table4_frames_and_energy():
    for name, spec, prof, wd in (
            ("vgg16", VGG16, VGG16_DENSITY_PROFILE, VGG16_W_DENSITY),
            ("alexnet", ALEXNET, ALEXNET_DENSITY_PROFILE, ALEXNET_W_DENSITY)):
        r = table4_row(analytic_network_stats(spec, prof), w_density=wd)
        p = PAPER_TABLE4[name]
        assert r["frames_s"] == pytest.approx(p["frames_s"], rel=0.02)
        assert r["power_mw"] == pytest.approx(p["power_mw"], rel=0.30)
        assert r["frames_j"] == pytest.approx(p["frames_j"], rel=0.30)


def test_event_macs_scale_with_density():
    lo = analytic_network_stats(VGG16, tuple([0.1] * 16))
    hi = analytic_network_stats(VGG16, tuple([0.8] * 16))
    assert sum(s["event_macs"] for s in hi) > \
        5 * sum(s["event_macs"] for s in lo)
