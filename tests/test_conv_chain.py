"""Event-resident conv chaining (DESIGN.md §5/§5.1): conv streams feed the
next layer's taps with no dense round-trip; the whole CNN runs as one jit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core.mnf_conv import dense_conv2d
from repro.models.cnn import (ALEXNET, VGG16, CNNSpec, ConvSpec, FCSpec,
                              PoolSpec, chain_boundary_summary, cnn_forward,
                              init_cnn_params, make_cnn_pipeline)

KEY = jax.random.PRNGKey(0)

MINI = CNNSpec("mini", 8, 3,
               (ConvSpec(8, 3, 1, 1), ConvSpec(8, 3, 1, 1), PoolSpec(),
                FCSpec(10)))


def _fired_map(seed, shape=(2, 6, 5, 3), sparsity=0.5):
    r = np.random.default_rng(seed)
    x = r.normal(size=shape) * (r.random(shape) > sparsity)
    return jax.nn.relu(jnp.asarray(x.astype(np.float32)))


# ---------------------------------------------------------------------------
# single layer: conv on a stream == conv on its dense twin == oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
@pytest.mark.parametrize("stride,padding", [(1, 1), (2, 0), (2, 2)])
def test_conv2d_events_matches_dense_oracle(backend, stride, padding):
    r = np.random.default_rng(1)
    x = _fired_map(1)
    w = jnp.asarray(r.normal(size=(3, 3, 3, 4)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=4, blk_k=8, blk_n=4)
    stream = engine.fire_conv(x, cfg)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(stream.without_dense(), w, cfg=cfg, stride=stride,
                          padding=padding)
    assert not any(rec.get("decode") for rec in recs), "chained conv decoded"
    assert any(rec.get("chained") and rec["op"] == "conv2d" for rec in recs)
    ref = dense_conv2d(x, w, stride=stride, padding=padding)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


def test_conv2d_events_bitwise_equals_reencoded_roundtrip():
    """Consuming fired events directly == decode→re-encode, bit for bit
    (same pixel-granular geometry, same tiles, same order)."""
    r = np.random.default_rng(2)
    x = _fired_map(2)
    w = jnp.asarray(r.normal(size=(3, 3, 3, 4)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_m=4, blk_k=8)
    stream = engine.fire_conv(x, cfg)
    y_chain = engine.conv2d(stream.without_dense(), w, cfg=cfg, padding=1)
    redone = engine.EventStream.encode_nhwc(stream.dense_nhwc(), blk_k=8)
    y_round = engine.conv2d(redone, w, cfg=cfg, padding=1)
    assert bool(jnp.all(y_chain == y_round)), "paths diverged bitwise"


# ---------------------------------------------------------------------------
# whole networks: event-resident == per-layer round-trip (bitwise) == oracle
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("spec,size", [(ALEXNET, 64), (VGG16, 32)])
def test_event_resident_forward_bitwise_and_boundaries(spec, size):
    """At threshold 0, batch ≥ 2: the chained forward is bit-identical to
    the per-layer round-trip (the dense-boundary twin of the same event
    dataflow), allclose to the dense-backend oracle, and every boundary
    between the first conv and the FC head runs events-only — pools
    included (the event-native segment max, DESIGN.md §7): zero densify
    points on the chain.
    """
    s = spec.scaled(size)
    params = init_cnn_params(KEY, s, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, size, size, s.in_ch)))

    with engine.trace_dispatch() as recs:
        ym = cnn_forward(params, x, s, mnf=True, chain=True)
    n_conv = sum(isinstance(l, ConvSpec) for l in s.layers)
    n_fc = sum(isinstance(l, FCSpec) for l in s.layers)
    n_pool = sum(isinstance(l, PoolSpec) for l in s.layers)
    # Zero densify points between the first conv and the FC head: no
    # decode, no fallback, and every pool rides the event-native path.
    assert sum(1 for r in recs if r.get("decode")) == 0
    assert sum(1 for r in recs if r.get("fallback_decode")) == 0
    assert sum(1 for r in recs if r.get("pool_events")
               and r["op"] == "maxpool2d") == n_pool
    # Every conv consumes events except a chain head whose geometry cannot
    # strip-encode the dense input image (input_encode counts the heads
    # that can — AlexNet's stride-4 conv1 at 64 px cannot, VGG16@32 can).
    n_enc = chain_boundary_summary(s, batch=2)["input_encode"]
    assert sum(1 for r in recs if r.get("chained")
               and r["op"] == "conv2d") == n_conv - 1 + n_enc
    # Every FC consumes events — the first through the conv→FC re-tiler
    # (DESIGN.md §12), the rest as chained fire streams.
    assert sum(1 for r in recs if r.get("chained")
               and r["op"] == "linear") == n_fc

    yr = cnn_forward(params, x, s, mnf=True, chain=False)
    assert bool(jnp.all(ym == yr)), "chained != round-trip bitwise"
    yd = cnn_forward(params, x, s, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


def test_one_jit_pipeline_matches_eager_and_caches():
    params = init_cnn_params(KEY, MINI, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 8, 8, 3)))
    fn = make_cnn_pipeline(MINI, donate=False)
    y1 = fn(params, x)
    y2 = fn(params, x)
    assert bool(jnp.all(y1 == y2))
    assert bool(jnp.all(y1 == cnn_forward(params, x, MINI, mnf=True)))
    try:
        n = fn._cache_size()
    except AttributeError:
        n = 1            # older jax: no cache introspection — shape check only
    assert n == 1, "pipeline retraced for identical input shapes"


def test_pipeline_pallas_backend_runs_under_one_jit():
    cfg = engine.EngineConfig(backend="pallas", blk_m=4, blk_k=8, blk_n=8)
    params = init_cnn_params(KEY, MINI, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 8, 8, 3)))
    fn = make_cnn_pipeline(MINI, engine_cfg=cfg, donate=False)
    y = fn(params, x)
    yd = cnn_forward(params, x, MINI, mnf=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


# ---------------------------------------------------------------------------
# stream geometry / registry seams
# ---------------------------------------------------------------------------

def test_fire_conv_stream_geometry():
    x = _fired_map(3, shape=(2, 4, 5, 6))
    cfg = engine.EngineConfig(backend="block", blk_k=8)
    s = engine.fire_conv(x, cfg)
    assert s.blk_m == 1 and s.blk_k == 6          # pixel rows, clamped K
    assert s.logical_shape == (2, 4, 5, 6) and s.shape == (40, 6)
    np.testing.assert_array_equal(np.asarray(s.dense_nhwc()), np.asarray(x))
    # events-only view still reconstructs exactly (threshold 0 is lossless)
    np.testing.assert_array_equal(
        np.asarray(s.without_dense().dense_nhwc()), np.asarray(x))


def test_conv_event_ops_registered():
    for op in ("conv2d_events", "conv2d_events_strip"):
        assert set(engine.list_backends(op)) == {"block", "pallas"}, op
    assert set(engine.BACKENDS) <= set(engine.list_backends("fire_conv"))


def test_occupancy_zero_grid_is_zero():
    s = engine.EventStream.encode(jnp.zeros((0, 8)), blk_m=1, blk_k=8)
    assert float(s.occupancy()) == 0.0


def test_zero_row_streams_never_reach_pallas():
    """Empty batches / fully-dead layers: encode returns an explicit empty
    stream, and fire/linear/conv2d short-circuit instead of handing Pallas
    a 0-extent launch (regression: slice_sizes > operand shape)."""
    cfg = engine.EngineConfig(backend="pallas", blk_m=8, blk_k=8, blk_n=4)
    s = engine.fire(jnp.zeros((0, 8)), cfg)            # used to raise
    assert s.shape == (0, 8) and float(s.num_scalar_events) == 0.0
    assert float(s.occupancy()) == 0.0
    y = engine.linear(s, jnp.ones((8, 4)), cfg=cfg)
    assert y.shape == (0, 4)
    y = engine.linear(s, jnp.ones((8, 4)), b=jnp.ones((4,)), cfg=cfg)
    assert y.shape == (0, 4)
    # dtype must not flip with batch size: empty shortcut promotes like the
    # dispatch path (f32 events @ bf16 weights -> f32)
    yb = engine.linear(s, jnp.ones((8, 4), jnp.bfloat16), cfg=cfg)
    assert yb.dtype == jnp.float32
    sc = engine.fire_conv(jnp.zeros((0, 6, 6, 4)), cfg)
    assert sc.logical_shape == (0, 6, 6, 4)
    yc = engine.conv2d(sc, jnp.ones((3, 3, 4, 8)), cfg=cfg, padding=1)
    assert yc.shape == (0, 6, 6, 8)
    # the block-event grid of the empty stream is explicitly empty
    assert s.events.counts.shape == (0,)
    assert s.events.values.shape[0] == 0


def test_for_conv_clamps_blk_k():
    cfg = engine.EngineConfig(blk_k=128)
    assert cfg.for_conv(3).blk_k == 3
    assert cfg.for_conv(512).blk_k == 128
    assert cfg.for_conv(0).blk_k == 1             # degenerate channel depth


# ---------------------------------------------------------------------------
# fallback visibility: no more invisible round-trips
# ---------------------------------------------------------------------------

def test_linear_events_fallback_is_bit_identical_and_marked():
    """A backend without ``linear_events`` must decode-fallback to a result
    bit-identical to the explicit dense path, and the fallback must surface
    a ``fallback_decode=True`` record (the silent round-trip is visible)."""
    r = np.random.default_rng(5)
    a = jax.nn.relu(jnp.asarray(r.normal(size=(8, 16)).astype(np.float32)))
    w = jnp.asarray(r.normal(size=(16, 6)).astype(np.float32))
    cfg_b = engine.EngineConfig(backend="block", blk_m=4, blk_k=8)
    stream = engine.fire(a, cfg_b)

    engine.register_backend("matmul", "nochain", lambda x, wt, c: x @ wt)
    engine.register_backend(
        "linear", "nochain",
        lambda x, wt, b, c: x @ wt if b is None else x @ wt + b)
    try:
        cfg = cfg_b.replace(backend="nochain")
        with engine.trace_dispatch() as recs:
            y = engine.linear(stream, w, cfg=cfg)
        marks = [rec for rec in recs if rec.get("fallback_decode")]
        assert marks and marks[0]["op"] == "linear" \
            and marks[0]["backend"] == "nochain"
        y_dense = engine.linear(stream.dense(), w, cfg=cfg)
        assert bool(jnp.all(y == y_dense)), "fallback diverged from dense"
    finally:
        engine.registry._REGISTRY.pop(("matmul", "nochain"))
        engine.registry._REGISTRY.pop(("linear", "nochain"))


def test_conv2d_events_fallback_decodes_with_marker():
    """Backends without ``conv2d_events`` (oracles) decode conv streams —
    correct result, visible marker."""
    r = np.random.default_rng(6)
    x = _fired_map(6)
    w = jnp.asarray(r.normal(size=(3, 3, 3, 4)).astype(np.float32))
    stream = engine.fire_conv(x, engine.EngineConfig(backend="block",
                                                     blk_k=8))
    cfg = engine.EngineConfig(backend="dense")
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(stream, w, cfg=cfg, padding=1)
    assert any(rec.get("fallback_decode") and rec["op"] == "conv2d"
               for rec in recs)
    ref = dense_conv2d(x, w, stride=1, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)
