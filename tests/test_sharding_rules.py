"""Logical-axis resolver: priority, divisibility, reuse (no multi-device)."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (abstract_mesh_compat, logical_to_pspec,
                                     make_rules)


@pytest.fixture(scope="module")
def mesh16():
    # abstract mesh: shape arithmetic only, no devices needed
    return abstract_mesh_compat((16, 16), ("data", "model"))


def test_divisibility_drops_heads(mesh16):
    rules = make_rules(mesh16)
    # 12 heads don't divide 16 -> heads replicated, attn_seq takes model
    ps = logical_to_pspec(("batch", "attn_seq", "heads", None),
                          (256, 4096, 12, 128), mesh16, rules)
    assert ps == P("data", "model")


def test_priority_prefers_heads(mesh16):
    rules = make_rules(mesh16)
    ps = logical_to_pspec(("batch", "attn_seq", "heads", None),
                          (256, 4096, 32, 128), mesh16, rules)
    assert ps == P("data", None, "model")


def test_axis_reuse_blocked(mesh16):
    rules = make_rules(mesh16)
    # experts take model; ff_expert must not reuse it
    ps = logical_to_pspec(("experts", "embed", "ff"), (64, 2048, 1408),
                          mesh16, rules)
    assert ps == P("model")


def test_vocab_beats_cache_seq(mesh16):
    rules = make_rules(mesh16)
    ps = logical_to_pspec(("cache_seq", "vocab"), (32768, 256000), mesh16,
                          rules)
    assert ps == P(None, "model")


def test_fsdp_rule(mesh16):
    rules = make_rules(mesh16, fsdp=True)
    ps = logical_to_pspec(("vocab", "embed"), (256000, 4608), mesh16, rules)
    assert ps == P("model", "data")
    rules2 = make_rules(mesh16, fsdp=False)
    ps2 = logical_to_pspec(("vocab", "embed"), (256000, 4608), mesh16, rules2)
    assert ps2 == P("model")


def test_batch_over_pod_and_data():
    mesh = abstract_mesh_compat((2, 16, 16), ("pod", "data", "model"))
    rules = make_rules(mesh)
    ps = logical_to_pspec(("batch", None), (256, 4096), mesh, rules)
    assert ps == P(("pod", "data"))
    # batch=1 (long_500k): replicated
    ps1 = logical_to_pspec(("batch", None), (1, 4096), mesh, rules)
    assert ps1 == P()


def test_overrides():
    mesh = abstract_mesh_compat((16, 16), ("data", "model"))
    rules = make_rules(mesh, overrides={"ff": None})
    ps = logical_to_pspec(("embed", "ff"), (1024, 4096), mesh, rules)
    assert ps == P()
