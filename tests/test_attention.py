"""Chunked attention vs naive softmax oracle; MLA decode vs expanded."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GLOBAL_WINDOW, MLAConfig, ModelConfig
from repro.models.attention import chunked_attention, mla_apply, mla_init


def naive_attention(q, k, v, *, q_pos, window, causal=True, softcap=None,
                    kv_len=None, scale=None):
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = d ** -0.5 if scale is None else scale
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q * scale, kk).astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    tpos = jnp.arange(k.shape[1])
    delta = q_pos[:, None] - tpos[None, :]
    ok = jnp.ones_like(delta, bool)
    if kv_len is not None:
        ok = ok & (tpos[None, :] < kv_len)
    if causal:
        ok = ok & (delta >= 0) & (delta < window)
    else:
        ok = ok & (jnp.abs(delta) < window)
    logits = jnp.where(ok[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, vv.astype(jnp.float32))


@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (6, 1)])
@pytest.mark.parametrize("window", [GLOBAL_WINDOW, 5])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_chunked_vs_naive(rng, h, kh, window, softcap):
    b, sq, d = 2, 16, 8
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, sq, kh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, sq, kh, d)).astype(np.float32))
    pos = jnp.arange(sq)
    out = chunked_attention(q, k, v, q_positions=pos, window=window,
                            softcap=softcap, chunk=4)
    ref = naive_attention(q, k, v, q_pos=pos, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_chunked_decode_with_kv_len(rng):
    """Single query vs partially-filled cache."""
    b, h, d, smax = 2, 4, 8, 32
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, smax, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, smax, h, d)).astype(np.float32))
    pos = jnp.asarray([20])
    out = chunked_attention(q, k, v, q_positions=pos, window=GLOBAL_WINDOW,
                            kv_len=21, chunk=8)
    ref = naive_attention(q, k, v, q_pos=pos, window=GLOBAL_WINDOW, kv_len=21)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_non_causal_cross_attention(rng):
    b, sq, skv, h, d = 1, 6, 10, 2, 8
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, skv, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, skv, h, d)).astype(np.float32))
    pos = jnp.arange(sq)
    out = chunked_attention(q, k, v, q_positions=pos, window=GLOBAL_WINDOW,
                            causal=False, chunk=4)
    ref = naive_attention(q, k, v, q_pos=pos, window=GLOBAL_WINDOW,
                          causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_mla_decode_matches_expanded(rng):
    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64, head_dim=16,
        mla=MLAConfig(kv_lora_rank=16, qk_rope_dim=4, qk_nope_dim=8,
                      v_head_dim=8),
        compute_dtype="float32", attn_chunk=8)
    p, _ = mla_init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 9
    x = jnp.asarray(rng.normal(size=(b, s, 32)).astype(np.float32)) * 0.3
    pos = jnp.arange(s)
    # expanded over the whole sequence
    full, _ = mla_apply(p, x, cfg=cfg, positions=pos, window=GLOBAL_WINDOW)
    # prefill s-1 via absorbed cache then decode the last token
    cache = dict(c=jnp.zeros((b, s, 16), jnp.float32),
                 kr=jnp.zeros((b, s, 4), jnp.float32))
    _, cache = mla_apply(p, x[:, :s - 1], cfg=cfg, positions=pos[:s - 1],
                         window=GLOBAL_WINDOW, cache=cache, decode_pos=0)
    last, _ = mla_apply(p, x[:, s - 1:], cfg=cfg, positions=pos[s - 1:],
                        window=GLOBAL_WINDOW, cache=cache,
                        decode_pos=s - 1)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-3)
