"""MoE dispatch — routing-as-fire semantics, capacity, gating."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import moe_apply, moe_capacity, moe_init


def _cfg(**over):
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(cfg, compute_dtype="float32", **over)
    return cfg


def test_moe_shapes_and_finite(rng):
    cfg = _cfg()
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["load_balance_loss"]) > 0
    assert 0.0 <= float(aux["drop_fraction"]) <= 1.0


def test_moe_capacity_rounding():
    cfg = _cfg()
    c = moe_capacity(64, cfg)
    assert c % 8 == 0 and c >= 8


def test_moe_matches_manual_dispatch(rng):
    """Tiny case cross-checked against an O(T·E) dense loop."""
    cfg = _cfg()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_shared=0, top_k=2,
                                     capacity_factor=8.0))  # no drops
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 6, cfg.d_model)).astype(np.float32))
    y, _ = moe_apply(p, x, cfg)

    # manual: for each token run its top-k experts densely
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, 2)
    ref = np.zeros_like(np.asarray(xf))
    for ti in range(xf.shape[0]):
        for j in range(2):
            e = int(topi[ti, j])
            h = np.asarray(xf[ti]) @ np.asarray(p["w_up"][e])
            g = jax.nn.silu(np.asarray(xf[ti]) @ np.asarray(p["w_gate"][e]))
            ref[ti] += float(topw[ti, j]) * (np.asarray(g) * h) @ \
                np.asarray(p["w_down"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)), ref,
                               atol=2e-3, rtol=2e-3)


def test_moe_capacity_drops_counted(rng):
    cfg = _cfg(moe_dispatch_groups=1)   # single group so capacity binds
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    _, aux = moe_apply(p, x, cfg)
    assert float(aux["drop_fraction"]) > 0.1
