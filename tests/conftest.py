"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 placeholders."""
import pathlib
import sys

import numpy as np
import pytest

# --- hypothesis fallback -----------------------------------------------------
# Property tests import hypothesis at module scope; environments without it
# (see requirements-dev.txt) must still *collect and run* the suite, so when
# the real package is absent we install tests/_hypothesis_fallback.py in its
# place: same decorator API, deterministic example batches, no search.
try:
    import hypothesis  # noqa: F401
except ImportError:                                        # pragma: no cover
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis_fallback as _hf

    sys.modules["hypothesis"] = _hf
    sys.modules["hypothesis.strategies"] = _hf.strategies


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
