"""Optimizer, schedules, compression, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import TokenStreamConfig, cnn_batch, lm_batch, markov_lm_batch
from repro.optim import (AdamWConfig, adamw_init, adamw_update, constant,
                         event_psum, global_norm, quantized_psum,
                         topk_threshold, warmup_cosine)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=1e9)
    state = adamw_init(params)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(grads, state, params, opt)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_metric():
    params = {"w": jnp.ones(4)}
    opt = AdamWConfig(grad_clip=1.0)
    state = adamw_init(params)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(grads, state, params, opt)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)
    assert float(constant(0.5)(jnp.asarray(7))) == 0.5


def test_quantized_psum_single_device():
    from repro.launch.mesh import checked_mesh
    from repro.parallel.sharding import shard_map_compat
    x = jnp.linspace(-1, 1, 64)
    out = shard_map_compat(
        lambda v: quantized_psum(v, "i"),
        checked_mesh((1,), ("i",)),
        in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec())(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-2)


def test_event_psum_error_feedback():
    """Fired + residual always reconstructs the running gradient sum."""
    from repro.launch.mesh import checked_mesh
    from repro.parallel.sharding import shard_map_compat
    mesh = checked_mesh((1,), ("i",))
    P = jax.sharding.PartitionSpec
    residual = jnp.zeros(32)
    total_sent = jnp.zeros(32)
    total_true = jnp.zeros(32)
    rng = np.random.default_rng(0)
    for step in range(6):
        g = jnp.asarray(rng.normal(size=32).astype(np.float32))
        fired, residual = shard_map_compat(
            lambda gv, rv: event_psum(gv, rv, "i", k_frac=0.25),
            mesh, in_specs=(P(), P()), out_specs=(P(), P()))(g, residual)
        total_sent = total_sent + fired
        total_true = total_true + g
        np.testing.assert_allclose(np.asarray(total_sent + residual),
                                   np.asarray(total_true), atol=1e-5)
        # communication is sparse
        assert (np.asarray(fired) != 0).mean() <= 0.6


def test_topk_threshold():
    x = jnp.arange(100.0)
    th = topk_threshold(x, 0.1)
    assert float(th) == 90.0


def test_lm_batch_determinism_and_resume():
    cfg = TokenStreamConfig(vocab_size=64, seq_len=16, global_batch=4)
    b1 = lm_batch(cfg, 7)
    b2 = lm_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = lm_batch(cfg, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # host sharding partitions the batch deterministically
    h0 = lm_batch(cfg, 7, host_index=0, host_count=2)
    assert h0["tokens"].shape[0] == 2


def test_markov_batch_has_structure():
    cfg = TokenStreamConfig(vocab_size=32, seq_len=64, global_batch=4)
    b = markov_lm_batch(cfg, 0)
    toks = np.asarray(b["tokens"])
    # with 8 successors per token, bigram entropy is far below uniform
    assert b["labels"].shape == (4, 64)
    assert toks.min() >= 0 and toks.max() < 32


def test_cnn_batch_sparsity():
    x = np.asarray(cnn_batch(2, 16, 3, 0, activation_sparsity=0.7))
    assert abs((x == 0).mean() - 0.7) < 0.1
    assert (x >= 0).all()


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "count": jnp.asarray(3)}
    d = str(tmp_path / "ck")
    ckpt.save(tree, d, 10)
    ckpt.save(tree, d, 20)
    assert ckpt.latest_step(d) == 20
    assert ckpt.all_steps(d) == [10, 20]
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = ckpt.restore(like, d)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    t = ckpt.save_async({"x": jnp.ones(8)}, d, 5)
    t.join()
    assert ckpt.latest_step(d) == 5
    # a leftover tmp dir never shadows a completed step
    assert not any(p.endswith(".tmp") for p in os.listdir(d))
