"""The decode launcher must run from a CLEAN environment.

Regression: ``examples/serve_lm_decode.py`` used to re-exec the serve module
via ``subprocess.call`` and silently relied on PYTHONPATH=src reaching the
child — from a bare shell (cron, CI) the child could not import ``repro``.
The launcher now runs in-process and bootstraps ``sys.path`` itself, so the
subprocess below deliberately gets NO PYTHONPATH.
"""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LAUNCHER = os.path.join(REPO, "examples", "serve_lm_decode.py")


def test_launcher_runs_from_clean_environment():
    env = {"PATH": os.environ.get("PATH", "/usr/bin:/bin"),
           "HOME": os.environ.get("HOME", "/root"),
           "JAX_PLATFORMS": "cpu"}
    assert "PYTHONPATH" not in env
    r = subprocess.run(
        [sys.executable, LAUNCHER, "--arch", "rwkv6-7b",
         "--batch", "2", "--prompt-len", "8", "--gen", "2"],
        env=env, capture_output=True, text=True, timeout=520, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]
    stats = json.loads(r.stdout.strip().splitlines()[-1])
    # rwkv6-7b's reduced config runs with MNF enabled at threshold 0: the
    # gated decode must report its per-token fired-event stats.
    assert "events_per_token" in stats, stats
    assert stats["events_per_token"] > 0
    assert len(stats["events_per_layer"]) > 0


def test_launcher_importable_without_src_on_path():
    # Import-time side effects only; main() is exercised by the slow test.
    import importlib.util
    spec = importlib.util.spec_from_file_location("serve_lm_decode", LAUNCHER)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert callable(mod.main)
    assert mod._SRC == os.path.join(REPO, "src")
