"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (event_matmul, event_matmul_int8,
                           event_matmul_int8_ref, event_matmul_ref,
                           fire_and_encode, fire_compact, fire_compact_ref,
                           wkv6, wkv6_ref)


@pytest.mark.parametrize("m,k,n,blk_m,blk_k,blk_n", [
    (8, 128, 128, 8, 128, 128),
    (16, 256, 256, 8, 128, 128),
    (32, 512, 384, 8, 128, 128),
    (24, 384, 200, 8, 128, 100),     # padded N
    (7, 130, 65, 8, 128, 128),       # everything ragged
])
@pytest.mark.parametrize("sparsity", [0.0, 0.8, 0.97])
def test_event_matmul_sweep(rng, m, k, n, blk_m, blk_k, blk_n, sparsity):
    a = (rng.normal(size=(m, k)) * (rng.random((m, k)) > sparsity))
    a = jnp.asarray(a.astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    y = event_matmul(a, w, blk_m=blk_m, blk_k=blk_k, blk_n=blk_n,
                     interpret=True)
    import repro.core.events as ev
    ap = ev.pad_to_block_multiple(ev.pad_to_block_multiple(a, blk_m, 0),
                                  blk_k, 1)
    wp = ev.pad_to_block_multiple(w, blk_k, 0)
    ref = event_matmul_ref(ap, wp, blk_m=blk_m, blk_k=blk_k)[:m, :n]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_event_matmul_dtypes(rng, dtype):
    a = jnp.asarray(rng.normal(size=(8, 128)), dtype)
    w = jnp.asarray(rng.normal(size=(128, 128)), dtype)
    y = event_matmul(a, w, interpret=True)
    ref = jnp.asarray(a, jnp.float32) @ jnp.asarray(w, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1.5 if dtype == jnp.bfloat16 else 1e-3)


@pytest.mark.parametrize("m,k,n,blk_m,blk_k", [
    (8, 128, 64, 8, 128),
    (16, 64, 24, 8, 16),
    (5, 33, 10, 8, 16),              # ragged M and K
])
@pytest.mark.parametrize("sparsity", [0.0, 0.6, 1.0])
def test_event_matmul_int8_vs_ref(rng, m, k, n, blk_m, blk_k, sparsity):
    """The int8-value lowering (DESIGN.md §12): codes dequantize at tile
    load, accumulation is f32 — the kernel must match the dense oracle
    (dequant live tiles, then matmul) up to f32 accumulation order, with
    all-zero streams in-distribution."""
    from repro.core.quantize import calibrate, quantize

    a = (rng.normal(size=(m, k)) * (rng.random((m, k)) > sparsity))
    a = jnp.asarray(a.astype(np.float32))
    qp = calibrate(a)
    q = quantize(a, qp)
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    y = event_matmul_int8(q, w, qp, blk_m=blk_m, blk_k=blk_k, blk_n=32,
                          interpret=True)
    import repro.core.events as ev
    qpad = ev.pad_to_block_multiple(ev.pad_to_block_multiple(q, blk_m, 0),
                                    blk_k, 1)
    wp = ev.pad_to_block_multiple(w, blk_k, 0)
    ref = event_matmul_int8_ref(qpad, wp, qp, blk_m=blk_m,
                                blk_k=blk_k)[:m, :n]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3,
                               rtol=1e-3)


def test_event_matmul_threshold_drops_tiles(rng):
    a = np.full((8, 256), 1e-4, np.float32)
    a[:, :128] = 1.0
    w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    y = event_matmul(jnp.asarray(a), w, threshold=1e-2, interpret=True)
    ref = event_matmul_ref(jnp.asarray(a), w, blk_m=8, blk_k=128,
                           threshold=1e-2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3)


@pytest.mark.parametrize("kw", [dict(), dict(threshold=0.3),
                                dict(magnitude=True, threshold=0.2),
                                dict(qscale=0.1)])
def test_fire_compact_modes(rng, kw):
    acc = jnp.asarray(rng.normal(size=(24, 260)).astype(np.float32))
    f, occ = fire_compact(acc, blk_m=8, blk_k=128, interpret=True, **kw)
    # ref works on padded shape; compare the unpadded region
    import repro.core.events as ev
    ap = ev.pad_to_block_multiple(ev.pad_to_block_multiple(acc, 8, 0), 128, 1)
    fr, occr = fire_compact_ref(ap, blk_m=8, blk_k=128, **kw)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr)[:24, :260])
    np.testing.assert_array_equal(np.asarray(occ), np.asarray(occr))


def test_fire_and_encode_pipeline(rng):
    acc = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
    fired, bev = fire_and_encode(acc, blk_m=8, blk_k=128, interpret=True)
    assert np.all(np.asarray(fired) >= 0)
    assert int(bev.counts.max()) <= 2


@pytest.mark.parametrize("b,h,t,d,chunk", [(1, 1, 16, 8, 4), (2, 3, 40, 16, 16),
                                           (1, 2, 33, 8, 8)])
def test_wkv6_vs_ref(rng, b, h, t, d, chunk):
    r = jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.2, 0.99, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    o, s = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(srf), atol=1e-3)


def test_wkv6_initial_state(rng):
    b, h, t, d = 1, 2, 12, 8
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.5, 0.99, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    s0 = jnp.asarray(rng.normal(size=(b, h, d, d)).astype(np.float32))
    o, s = wkv6(r, k, v, w, u, s0, chunk=4, interpret=True)
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0, 1),
                        out_axes=(1, 1))(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(srf), atol=1e-3)


@pytest.mark.parametrize("b,t,d,n,d_blk,chunk", [
    (1, 16, 8, 4, 8, 4), (2, 40, 24, 4, 8, 16), (1, 33, 130, 8, 128, 8)])
def test_mamba_scan_vs_ref(rng, b, t, d, n, d_blk, chunk):
    from repro.kernels import mamba_scan, mamba_scan_ref
    da = jnp.asarray(rng.uniform(0.3, 0.99, (b, t, d, n)).astype(np.float32))
    dbx = jnp.asarray(rng.normal(size=(b, t, d, n)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(b, t, n)).astype(np.float32))
    y, h = mamba_scan(da, dbx, c, d_blk=d_blk, chunk=chunk, interpret=True)
    yr, hr = mamba_scan_ref(da, dbx, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-3,
                               rtol=1e-3)


def test_mamba_scan_initial_state(rng):
    from repro.kernels import mamba_scan, mamba_scan_ref
    b, t, d, n = 2, 12, 8, 4
    da = jnp.asarray(rng.uniform(0.5, 0.99, (b, t, d, n)).astype(np.float32))
    dbx = jnp.asarray(rng.normal(size=(b, t, d, n)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(b, t, n)).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(b, d, n)).astype(np.float32))
    y, h = mamba_scan(da, dbx, c, h0, d_blk=8, chunk=4, interpret=True)
    yr, hr = mamba_scan_ref(da, dbx, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-3)
