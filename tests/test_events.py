"""Event encoding unit + property tests (paper §4 encoding, TPU-adapted)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (decode_block_events, encode_block_events,
                        encode_scalar_events, block_occupancy,
                        pad_to_block_multiple)


def test_scalar_events_order_and_count(rng):
    x = jnp.asarray([0.0, 2.0, 0.0, -3.0, 1.0])
    ev = encode_scalar_events(x)
    assert int(ev.count) == 3
    np.testing.assert_array_equal(np.asarray(ev.indices[:3]), [1, 3, 4])
    np.testing.assert_allclose(np.asarray(ev.values[:3]), [2.0, -3.0, 1.0])
    # padding slots carry zeros
    np.testing.assert_allclose(np.asarray(ev.values[3:]), 0.0)


def test_scalar_events_threshold():
    x = jnp.asarray([0.5, -2.0, 0.1])
    ev = encode_scalar_events(x, threshold=0.4)
    assert int(ev.count) == 2


def test_block_occupancy():
    x = jnp.zeros((2, 8)).at[0, 5].set(1.0)
    occ = block_occupancy(x, blk_k=4)
    np.testing.assert_array_equal(np.asarray(occ),
                                  [[False, True], [False, False]])


def test_pad_to_block_multiple():
    x = jnp.ones((3, 5))
    y = pad_to_block_multiple(x, 4, 0)
    assert y.shape == (4, 5) and float(y[3].sum()) == 0.0
    assert pad_to_block_multiple(x, 3, 0) is x


def test_padding_idx_repeats_last_live(rng):
    """Padding slots repeat the last live index (DMA no-op downstream)."""
    x = np.zeros((4, 32), np.float32)
    x[:, 8:16] = 1.0                      # only block 1 live (blk_k=8)
    ev = encode_block_events(jnp.asarray(x), blk_m=4, blk_k=8)
    assert int(ev.counts[0]) == 1
    np.testing.assert_array_equal(np.asarray(ev.block_idx[0]), [1, 1, 1, 1])


@settings(max_examples=25, deadline=None)
@given(m_blocks=st.integers(1, 4), k_blocks=st.integers(1, 6),
       blk_m=st.sampled_from([1, 2, 4]), blk_k=st.sampled_from([2, 4, 8]),
       sparsity=st.floats(0.0, 1.0), seed=st.integers(0, 2 ** 16))
def test_block_roundtrip_property(m_blocks, k_blocks, blk_m, blk_k, sparsity,
                                  seed):
    """decode(encode(x)) == x at threshold 0 for any shape/sparsity."""
    r = np.random.default_rng(seed)
    m, k = m_blocks * blk_m, k_blocks * blk_k
    x = r.normal(size=(m, k)) * (r.random((m, k)) > sparsity)
    x = jnp.asarray(x.astype(np.float32))
    ev = encode_block_events(x, blk_m=blk_m, blk_k=blk_k)
    y = decode_block_events(ev, blk_m=blk_m, blk_k=blk_k, m=m, k=k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), cap=st.integers(1, 6))
def test_capacity_truncation_keeps_first_events(seed, cap):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(4, 48)).astype(np.float32))
    full = encode_block_events(x, blk_m=4, blk_k=8)
    trunc = encode_block_events(x, blk_m=4, blk_k=8, capacity=cap)
    keep = min(cap, int(full.counts[0]))
    np.testing.assert_array_equal(np.asarray(trunc.block_idx[0, :keep]),
                                  np.asarray(full.block_idx[0, :keep]))
