"""Occupancy-adaptive dispatch: routing decisions, trace schema, bitwise
invariance (DESIGN.md §11).

What this suite pins:

  * **Schema** — every dispatched boundary record (chained, routed_dense,
    *and* fallback_decode) carries the full routing schema
    (``ROUTE_FIELDS``): the chosen route and the cost estimates that
    explain it.  A record without them is a regression in the dispatch
    tracer, not a formatting nit — serving's boundary report and the CI
    route gate both read these fields.
  * **Decisions** — forced routes are honored (and normalized to the
    flavor the stream's granularity can actually serve); adaptive routing
    flips with occupancy exactly where its cost source (analytic model or
    installed crossover table) says it should; zero-event streams stay on
    the event path with exact-zero output and no dense fallback.
  * **Staticness** — decisions consume only trace-time values
    (geometry + ``occupancy_hint``), never traced data, so one compiled
    boundary has exactly one route: re-tracing with different data must
    yield identical decisions.
  * **Bitwise invariance** — the route changes the *schedule*, never the
    bits: a chained conv→pool→conv→FC forward equals its per-layer
    round-trip twin bitwise under every routing mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.costmodel import crossover as xover
from repro.models.cnn import (CNNSpec, ConvSpec, FCSpec, PoolSpec,
                              cnn_forward, init_cnn_params)

KEY = jax.random.PRNGKey(7)

#: The satellite contract: every boundary record that dispatched (chained,
#: routed dense by choice, or visibly fell back) explains itself with
#: exactly these fields (engine.api._route_fields).
ROUTE_FIELDS = ("route", "est_event_cost", "est_dense_cost", "occupancy",
                "route_source", "shape_class")


def _x(shape, sparsity=0.3, seed=0):
    r = np.random.default_rng(seed)
    x = np.abs(r.normal(size=shape)).astype(np.float32) + 1e-3
    return jnp.asarray(x * (r.random(shape) > sparsity))


def _cfg(**kw):
    kw.setdefault("backend", "block")
    kw.setdefault("blk_m", 1)
    kw.setdefault("blk_k", 8)
    kw.setdefault("blk_n", 8)
    return engine.EngineConfig(**kw)


def _records(recs, op):
    return [r for r in recs if r.get("op") == op]


def _assert_schema(rec):
    for f in ROUTE_FIELDS:
        assert f in rec, f"boundary record missing routing field {f!r}: {rec}"
    assert rec["route"] is not None
    assert rec["est_event_cost"] > 0 and rec["est_dense_cost"] > 0
    assert 0.0 <= rec["occupancy"] <= 1.0


# ---------------------------------------------------------------------------
# schema: chained / routed_dense / fallback_decode all carry ROUTE_FIELDS
# ---------------------------------------------------------------------------

def test_schema_on_chained_conv_and_pool_and_linear():
    cfg = _cfg(blk_m=engine.STRIP_W)
    x = _x((1, 16, 16, 8))
    w = _x((3, 3, 8, 8), sparsity=0.0, seed=1)
    stream = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=True)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(stream, w, cfg=cfg, stride=1, padding=1)
        pooled = engine.maxpool2d(
            engine.fire_conv(y, cfg, blk_m=engine.STRIP_W), 2, 2, cfg=cfg)
        fstream = engine.fire(pooled.dense_nhwc().reshape(1, -1)[:, :256],
                              _cfg(blk_m=8, blk_k=32, blk_n=32))
        engine.linear(fstream, _x((256, 16), sparsity=0.0, seed=2),
                      cfg=_cfg(blk_m=8, blk_k=32, blk_n=32))
    for op in ("conv2d", "maxpool2d", "linear"):
        rs = _records(recs, op)
        assert rs, f"no {op} boundary record"
        for r in rs:
            _assert_schema(r)
            assert r.get("chained"), r
            assert r["route"] in xover.EVENT_ROUTES
            assert r["route_source"] == "geometry"    # auto mode


def test_schema_on_routed_dense():
    cfg = _cfg(blk_m=engine.STRIP_W, route="dense")
    x = _x((1, 16, 16, 8))
    w = _x((3, 3, 8, 8), sparsity=0.0, seed=1)
    stream = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=True)
    with engine.trace_dispatch() as recs:
        engine.conv2d(stream, w, cfg=cfg, stride=1, padding=1)
        engine.maxpool2d(stream, 2, 2, cfg=cfg)
    for op in ("conv2d", "maxpool2d"):
        (r,) = _records(recs, op)
        _assert_schema(r)
        assert r.get("routed_dense") and not r.get("fallback_decode"), r
        assert r["route"] == "dense" and r["route_source"] == "forced"


def test_schema_on_fallback_decode():
    # Strip stream on strip-ineligible geometry (no padding, k=3: the strip
    # kernel needs SAME-family alignment) — conv has no event path for it.
    cfg = _cfg(blk_m=engine.STRIP_W)
    assert not engine.strip_eligible(16, 3, 1, 0, co=8)
    x = _x((1, 16, 16, 8))
    w = _x((3, 3, 8, 8), sparsity=0.0, seed=1)
    stream = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=True)
    with engine.trace_dispatch() as recs:
        engine.conv2d(stream, w, cfg=cfg, stride=1, padding=0)
    (r,) = _records(recs, "conv2d")
    _assert_schema(r)
    assert r.get("fallback_decode"), r
    assert r["route"] == "dense" and r["route_source"] == "geometry"

    # Pool: magnitude fire emits negative events — the segment max is
    # ineligible whatever the mode; the fallback record still explains
    # itself with the routing schema.
    mcfg = _cfg(blk_m=engine.STRIP_W, magnitude=True, threshold=0.1)
    mstream = engine.fire_conv(jnp.asarray(
        np.random.default_rng(3).normal(size=(1, 8, 8, 8)).astype(
            np.float32)), mcfg, blk_m=engine.STRIP_W, keep_dense=True)
    with engine.trace_dispatch() as recs:
        engine.maxpool2d(mstream, 2, 2, cfg=mcfg)
    (r,) = _records(recs, "maxpool2d")
    _assert_schema(r)
    assert r.get("fallback_decode") and r.get("reason"), r
    assert r["route"] == "dense" and r["route_source"] == "geometry"


# ---------------------------------------------------------------------------
# forced routes: honored, and normalized to the achievable flavor
# ---------------------------------------------------------------------------

def test_forced_routes_honored_and_bitwise():
    x = _x((1, 16, 16, 8))
    base = _cfg(blk_m=engine.STRIP_W)
    stream = engine.fire_conv(x, base, blk_m=engine.STRIP_W, keep_dense=True)
    outs, routes = {}, {}
    for route in ("window", "pixel", "dense"):
        cfg = base.replace(route=route)
        with engine.trace_dispatch() as recs:
            outs[route] = engine.maxpool2d(stream, 2, 2,
                                           cfg=cfg).dense_nhwc()
        (r,) = _records(recs, "maxpool2d")
        routes[route] = r["route"]
        assert r["route_source"] == "forced"
    assert routes == {"window": "window", "pixel": "pixel",
                      "dense": "dense"}
    ref = outs.pop("dense")
    for route, y in outs.items():
        assert bool(jnp.all(y == ref)), f"{route} pool != dense pool"


def test_forced_flavor_normalizes_to_granularity():
    # Forcing "strip" on a pixel-granular stream: the stream cannot ride
    # the fused strip kernel, so the decision lands on the flavor that
    # exists ("pixel") — visibly, with source still "forced".
    x = _x((1, 16, 16, 8))
    cfg = _cfg(blk_m=1, route="strip")
    stream = engine.fire_conv(x, cfg, blk_m=1, keep_dense=True)
    w = _x((3, 3, 8, 8), sparsity=0.0, seed=1)
    with engine.trace_dispatch() as recs:
        engine.conv2d(stream, w, cfg=cfg, stride=1, padding=1)
    (r,) = _records(recs, "conv2d")
    assert r["route"] == "pixel" and r["route_source"] == "forced"
    assert r.get("chained") and not r.get("fallback_decode")


# ---------------------------------------------------------------------------
# adaptive: flips with occupancy, from both cost sources
# ---------------------------------------------------------------------------

def test_adaptive_flips_on_analytic_model():
    # No table installed: the analytic seed routes event at low occupancy
    # (skipped work dominates) and dense at full occupancy (the event path
    # pays LAUNCH_OVERHEAD_CYCLES it can never win back).
    prev = xover.set_active_table(None)
    try:
        lo = engine.route_conv((1, 16, 16, 8), (3, 3, 8, 8),
                               _cfg(route="adaptive", occupancy_hint=0.02),
                               stride=1, padding=1, blk_m=1)
        hi = engine.route_conv((1, 16, 16, 8), (3, 3, 8, 8),
                               _cfg(route="adaptive", occupancy_hint=1.0),
                               stride=1, padding=1, blk_m=1)
    finally:
        xover.set_active_table(prev)
    assert lo.route == "pixel" and lo.source == "model"
    assert hi.route == "dense" and hi.source == "model"
    assert lo.ratio < 1.0 < hi.ratio


def test_adaptive_flips_on_installed_table():
    # A synthetic measured table inverts the analytic seed's verdicts —
    # proof the table has authority when it covers the boundary.
    entries = [
        dict(kind="crossover", boundary="conv", backend="block",
             shape_class="k3s1", occupancy=0.02,
             us=dict(pixel=500.0, dense=100.0)),
        dict(kind="crossover", boundary="conv", backend="block",
             shape_class="k3s1", occupancy=1.0,
             us=dict(pixel=10.0, dense=100.0)),
    ]
    prev = xover.set_active_table(xover.CrossoverTable(entries))
    try:
        lo = engine.route_conv((1, 16, 16, 8), (3, 3, 8, 8),
                               _cfg(route="adaptive", occupancy_hint=0.02),
                               stride=1, padding=1, blk_m=1)
        hi = engine.route_conv((1, 16, 16, 8), (3, 3, 8, 8),
                               _cfg(route="adaptive", occupancy_hint=1.0),
                               stride=1, padding=1, blk_m=1)
    finally:
        xover.set_active_table(prev)
    assert lo.route == "dense" and lo.source == "table"
    assert hi.route == "pixel" and hi.source == "table"


def test_table_flavor_conditioning():
    # The achievable flavor is granularity-bound: a strip boundary must be
    # judged on strip time even when the pixel path is faster (the
    # flavor-blind min would misroute it onto a slow strip twin).
    entries = [dict(kind="crossover", boundary="conv", backend="block",
                    shape_class="k3s1", occupancy=0.5,
                    us=dict(strip=300.0, pixel=20.0, dense=100.0))]
    t = xover.CrossoverTable(entries)
    assert t.ratio("conv", 0.5, backend="block", shape_class="k3s1",
                   flavor="strip") == pytest.approx(3.0)
    assert t.ratio("conv", 0.5, backend="block", shape_class="k3s1",
                   flavor="pixel") == pytest.approx(0.2)
    # Flavor-blind lookup (no flavor kwarg) sees the best event flavor.
    assert t.ratio("conv", 0.5, backend="block",
                   shape_class="k3s1") == pytest.approx(0.2)
    dec = xover.decide_route("adaptive", "conv", occupancy=0.5,
                             event_route="strip", dense_macs=1e6,
                             avg_touched=9.0, c_out=8, backend="block",
                             shape_class="k3s1", table=t)
    assert dec.route == "dense" and dec.source == "table"


def test_pool_shape_class_is_channel_aware():
    # Dense-pool cost scales with C at fixed k/stride: wide and narrow
    # pooling boundaries must not share a crossover curve (a merged curve
    # let the wide shape's event win misroute the narrow one).
    dec = engine.route_pool((2, 16, 16, 128), 2, 2,
                            _cfg(blk_m=engine.STRIP_W),
                            blk_m=engine.STRIP_W)
    assert dec is not None
    x = _x((2, 16, 16, 128))
    cfg = _cfg(blk_m=engine.STRIP_W)
    stream = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=True)
    with engine.trace_dispatch() as recs:
        engine.maxpool2d(stream, 2, 2, cfg=cfg)
    (r,) = _records(recs, "maxpool2d")
    assert r["shape_class"] == "k2s2c128"


# ---------------------------------------------------------------------------
# zero-event streams: the event route short-circuits, no dense fallback
# ---------------------------------------------------------------------------

def test_zero_event_stream_stays_event():
    cfg = _cfg(blk_m=engine.STRIP_W, route="adaptive", occupancy_hint=0.0)
    stream = engine.fire_conv(jnp.zeros((1, 16, 16, 8), jnp.float32), cfg,
                              blk_m=engine.STRIP_W, keep_dense=False)
    assert int(jnp.sum(stream.events.counts)) == 0
    w = _x((3, 3, 8, 8), sparsity=0.0, seed=1)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(stream, w, cfg=cfg, stride=1, padding=1)
    (r,) = _records(recs, "conv2d")
    assert r["route"] in xover.EVENT_ROUTES and r.get("chained"), r
    assert not any(x.get("fallback_decode") for x in recs), recs
    assert bool(jnp.all(y == 0.0)), "zero events must produce exact zeros"


# ---------------------------------------------------------------------------
# staticness: decisions depend on cfg + geometry, never on traced data
# ---------------------------------------------------------------------------

def test_route_decisions_jit_deterministic():
    cfg = _cfg(blk_m=engine.STRIP_W, route="adaptive", occupancy_hint=0.4)
    w = _x((3, 3, 8, 8), sparsity=0.0, seed=1)

    def fwd(s):
        return engine.conv2d(s, w, cfg=cfg, stride=1, padding=1)

    routes = []
    for sparsity in (0.0, 0.95):   # wildly different *data* occupancy
        s = engine.fire_conv(_x((1, 16, 16, 8), sparsity=sparsity), cfg,
                             blk_m=engine.STRIP_W, keep_dense=True)
        with engine.trace_dispatch() as recs:
            # A fresh closure per trace: jax.eval_shape caches on
            # (function identity, avals) and a cache hit records nothing.
            jax.eval_shape(lambda ss: fwd(ss), s)
        routes.append([(r["route"], r["route_source"], r["occupancy"])
                       for r in _records(recs, "conv2d")])
        assert routes[-1], "dispatch trace recorded no conv2d boundary"
    assert routes[0] == routes[1], \
        "route flipped on traced data — decisions must be trace-time static"
    # And the jaxpr is data-independent too: one compiled boundary, one
    # route (jit caching can never flip it).
    s0 = engine.fire_conv(_x((1, 16, 16, 8), sparsity=0.0), cfg,
                          blk_m=engine.STRIP_W, keep_dense=True)
    s1 = engine.fire_conv(_x((1, 16, 16, 8), sparsity=0.95), cfg,
                          blk_m=engine.STRIP_W, keep_dense=True)
    assert str(jax.make_jaxpr(fwd)(s0)) == str(jax.make_jaxpr(fwd)(s1))


# ---------------------------------------------------------------------------
# bitwise invariance: the route never changes the bits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("route,hint", [
    ("auto", None), ("adaptive", 0.05), ("adaptive", 1.0), ("dense", None)])
def test_chain_bitwise_under_every_route(route, hint):
    spec = CNNSpec("route-prop", 16, 3,
                   (ConvSpec(8, 3, 1, 1), PoolSpec(2, 2),
                    ConvSpec(8, 3, 2, 1), FCSpec(16)), num_classes=8)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(_x((1, 16, 16, 3), sparsity=0.4, seed=11))
    cfg = engine.EngineConfig(backend="block", route=route,
                              occupancy_hint=hint)
    with engine.trace_dispatch() as recs:
        ym = cnn_forward(params, x, spec, mnf=True, chain=True,
                         engine_cfg=cfg)
    assert not any(r.get("fallback_decode") for r in recs), recs
    for r in recs:
        if r.get("route") is not None:
            _assert_schema(r)
    yr = cnn_forward(params, x, spec, mnf=True, chain=False, engine_cfg=cfg)
    assert bool(jnp.all(ym == yr)), \
        f"chained != round-trip under route={route} hint={hint}"
    yd = cnn_forward(params, x, spec, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


def test_adaptive_routes_match_forced_executables():
    # The adaptive executable IS the chosen static route's executable:
    # trace the adaptive decision, then require jaxpr identity with the
    # same boundary forced to that route (the sweep's noise-immune
    # equivalence, pinned here as a unit test).
    base = _cfg(blk_m=engine.STRIP_W)
    x = _x((1, 16, 16, 8))
    stream = engine.fire_conv(x, base, blk_m=engine.STRIP_W,
                              keep_dense=True)
    for hint in (0.05, 1.0):
        acfg = base.replace(route="adaptive", occupancy_hint=hint)

        def fwd(s, cfg=acfg):
            return engine.maxpool2d(s, 2, 2, cfg=cfg).dense_nhwc()

        with engine.trace_dispatch() as recs:
            jax.eval_shape(fwd, stream)
        (r,) = _records(recs, "maxpool2d")
        fcfg = base.replace(route=r["route"])

        def forced(s, cfg=fcfg):
            return engine.maxpool2d(s, 2, 2, cfg=cfg).dense_nhwc()

        assert str(jax.make_jaxpr(fwd)(stream)) \
            == str(jax.make_jaxpr(forced)(stream))
