"""Randomized bitwise geometry suite for the whole event path (DESIGN.md §9).

PR 3/4 pinned the event path's bit-exactness contracts on hand-picked
geometries; this suite samples (B, H, W, k, stride, padding, C, CO,
threshold, sparsity) with hypothesis (or the deterministic fallback shim —
tests/_hypothesis_fallback.py) and asserts the same contracts hold across
the sampled space, on both the block and pallas backends:

  * conv: a strip-eligible geometry (stride 1, 2 or 4 — the N-part
    interleaved straddle plan, dead subtaps compacted) rides the fused
    strip path bit-identical to the per-tap pixel oracle and allclose to
    the dense conv; ineligible geometry (odd downsampled widths,
    over-padding p > k//2, stride-4 on narrow maps) degrades visibly
    (fallback_decode) and stays correct.
  * pool: the event-native segment max equals the dense ``reduce_window``
    pool bit for bit, from pixel- and strip-granular streams alike.
  * chain: a conv→pool→conv(stride 1/2/4)→FC network's chained forward is
    bit-identical to the per-layer round-trip twin, whatever mix of
    strip/pixel/pool boundaries the sampled geometry lands on.
  * conv→FC seam: an eligible (B, H, W, C) stream re-tiles to the
    flattened FC view by address plan alone — ``linear`` on the stream is
    bitwise ``linear`` on the dense flatten at matched geometry, pixel and
    strip granularity alike (DESIGN.md §12).
  * int8 chain: with int8 event values the chained MLP forward is bitwise
    the fake-quant round-trip twin, across sampled widths and thresholds.

Zero-event streams (sparsity 1.0) are in-distribution on purpose: every
contract must hold when nothing fires.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import engine
from repro.core.fire import FireConfig, fire
from repro.core.mnf_conv import dense_conv2d
from repro.models.cnn import (CNNSpec, ConvSpec, FCSpec, PoolSpec,
                              cnn_forward, init_cnn_params)
from repro.models.mlp import MLPSpec, init_mlp_params, mlp_forward

KEY = jax.random.PRNGKey(0)


def _input(seed: int, shape, sparsity: float) -> jax.Array:
    """Signed, sparsified input — fire decides what becomes an event."""
    r = np.random.default_rng(seed)
    x = r.normal(size=shape) * (r.random(shape) > sparsity)
    return jnp.asarray(x.astype(np.float32))


def _seed(*parts) -> int:
    return abs(hash(tuple(parts))) % (2 ** 31)


# ---------------------------------------------------------------------------
# conv: strip == per-tap (bitwise) == dense (allclose), or visible fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 2), h=st.integers(4, 9), wmul=st.sampled_from([1, 2, 4]),
       ci=st.integers(1, 5), comul=st.integers(1, 2),
       k=st.sampled_from([1, 3, 5]), stride=st.sampled_from([1, 2, 4]),
       pad_mode=st.sampled_from(["none", "same", "over"]),
       threshold=st.sampled_from([0.0, 0.2]),
       sparsity=st.sampled_from([0.25, 0.6, 1.0]))
def test_conv_geometry_strip_pertap_dense(backend, b, h, wmul, ci, comul, k,
                                          stride, pad_mode, threshold,
                                          sparsity):
    # wmul=4 gives the W=32 maps where stride-4 geometries tile strips;
    # "over" samples p > k//2 — the padding rule's visible fallback.
    w0 = 8 * wmul
    p = {"none": 0, "same": k // 2, "over": k // 2 + 1}[pad_mode]
    co = 8 * comul
    h = max(h, k)                          # at least one output row
    x = _input(_seed(b, h, w0, ci, co, k, stride, p, sparsity),
               (b, h, w0, ci), sparsity)
    wgt = jnp.asarray(np.random.default_rng(_seed(k, ci, co)).normal(
        size=(k, k, ci, co)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=4, blk_n=8,
                              threshold=threshold)
    strip = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=False)
    fired = fire(x, FireConfig(threshold=threshold))
    eligible = engine.strip_eligible(w0, k, stride, p, co=co)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(strip, wgt, cfg=cfg, stride=stride, padding=p)
    if eligible:
        assert any(r.get("strip") and r.get("chained")
                   and r.get("launches") == 1 for r in recs), recs
        assert not any(r.get("fallback_decode") or r.get("decode")
                       for r in recs), recs
        pixel = engine.fire_conv(x, cfg, blk_m=1, keep_dense=False)
        y_pix = engine.conv2d(pixel, wgt, cfg=cfg, stride=stride, padding=p)
        assert bool(jnp.all(y == y_pix)), "strip != per-tap bitwise"
    else:
        assert any(r.get("fallback_decode") and r.get("strip")
                   for r in recs), recs
    ref = dense_conv2d(fired, wgt, stride=stride, padding=p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# pool: event-native segment max == dense reduce_window, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 2), h=st.integers(4, 10), wmul=st.integers(1, 2),
       c=st.integers(1, 6), k=st.sampled_from([2, 3]),
       stride=st.integers(1, 3), strips_in=st.booleans(),
       sparsity=st.sampled_from([0.3, 0.7, 1.0]))
def test_pool_geometry_bitwise(backend, b, h, wmul, c, k, stride, strips_in,
                               sparsity):
    w0 = 8 * wmul
    h = max(h, k)
    x = _input(_seed(b, h, w0, c, k, stride, sparsity), (b, h, w0, c),
               sparsity)
    fired = fire(x, FireConfig())
    cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=4)
    stream = engine.fire_conv(x, cfg, blk_m=8 if strips_in else 1,
                              keep_dense=False)
    with engine.trace_dispatch() as recs:
        pooled = engine.maxpool2d(stream, k, stride, cfg=cfg)
    assert any(r.get("pool_events") for r in recs), recs
    assert not any(r.get("fallback_decode") for r in recs), recs
    ref = engine.maxpool2d(fired, k, stride, cfg=cfg)   # dense reduce_window
    assert bool(jnp.all(pooled.dense_nhwc() == ref)), \
        "event pool != dense pool bitwise"


# ---------------------------------------------------------------------------
# chain: conv -> pool -> conv(stride 1, 2 or 4) -> FC, chained == round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
@settings(max_examples=5, deadline=None)
@given(size=st.sampled_from([8, 16]), ci=st.integers(1, 3),
       k1=st.sampled_from([1, 3]), k2=st.sampled_from([1, 3]),
       s2=st.sampled_from([1, 2, 4]), sparsity=st.sampled_from([0.3, 0.8]),
       route=st.sampled_from(["auto", "adaptive", "dense"]),
       hint=st.sampled_from([0.05, 1.0]))
def test_chained_conv_pool_conv_bitwise(backend, size, ci, k1, k2, s2,
                                        sparsity, route, hint):
    # ``route`` is a sampled dimension on purpose (DESIGN.md §11): the
    # routing mode changes the *schedule* at every boundary — event flavor
    # vs dense-by-choice — and the chained == round-trip bitwise contract
    # must hold whatever mix of routes the sampled point lands on.
    spec = CNNSpec("prop", size, ci,
                   (ConvSpec(8, k1, 1, k1 // 2), PoolSpec(2, 2),
                    ConvSpec(8, k2, s2, k2 // 2), FCSpec(8)), num_classes=8)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(_input(_seed(size, ci, k1, k2, s2, sparsity),
                           (1, size, size, ci), sparsity))
    cfg = engine.EngineConfig(backend=backend, route=route,
                              occupancy_hint=hint)
    with engine.trace_dispatch() as recs:
        ym = cnn_forward(params, x, spec, mnf=True, chain=True,
                         engine_cfg=cfg)
    assert not any(r.get("fallback_decode") for r in recs), recs
    yr = cnn_forward(params, x, spec, mnf=True, chain=False, engine_cfg=cfg)
    assert bool(jnp.all(ym == yr)), "chained != round-trip"
    yd = cnn_forward(params, x, spec, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


# ---------------------------------------------------------------------------
# conv→FC seam: re-tiled stream linear == dense flatten linear, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 2), h=st.integers(1, 6), wmul=st.integers(1, 3),
       cmul=st.integers(1, 3), strips=st.booleans(),
       threshold=st.sampled_from([0.0, 0.25]),
       sparsity=st.sampled_from([0.0, 0.5, 1.0]))
def test_conv_to_fc_retile_matches_dense_flatten(backend, b, h, wmul, cmul,
                                                 strips, threshold, sparsity):
    w0, c = 8 * wmul, 4 * cmul                 # W % STRIP_W, C % blk_k == 0
    x = _input(_seed(b, h, w0, c, strips, threshold, sparsity),
               (b, h, w0, c), sparsity)
    cfg = engine.EngineConfig(backend=backend, blk_k=4, threshold=threshold)
    stream = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W if strips else 1,
                              keep_dense=False)
    wgt = jnp.asarray(np.random.default_rng(_seed(h, w0, c)).normal(
        size=(h * w0 * c, 8)).astype(np.float32))
    with engine.trace_dispatch() as recs:
        y = engine.linear(stream, wgt, cfg=cfg)
    rec = next(r for r in recs if r.get("op") == "linear")
    assert rec.get("chained") and rec.get("retile") is True, recs
    assert not any(r.get("fallback_decode") or r.get("decode")
                   for r in recs), recs
    # The dense twin at the seam's geometry (threshold 0: fire already
    # thresholded, the boundary encode is lossless — DESIGN.md §5/§12).
    flat = fire(x, FireConfig(threshold=threshold)).reshape(b, h * w0 * c)
    fcfg = cfg.replace(threshold=0.0, blk_m=1, blk_k=stream.blk_k)
    assert bool(jnp.all(y == engine.linear(flat, wgt, cfg=fcfg))), \
        "conv→FC re-tile != dense flatten"


# ---------------------------------------------------------------------------
# int8 chain: chained MLP == fake-quant round-trip twin, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
@settings(max_examples=8, deadline=None)
@given(batch=st.integers(1, 4), in_f=st.sampled_from([16, 48, 96]),
       w1=st.sampled_from([8, 24]), w2=st.sampled_from([8, 12]),
       threshold=st.sampled_from([0.0, 0.1]),
       sparsity=st.sampled_from([0.3, 0.8, 1.0]))
def test_int8_mlp_chain_matches_fake_quant_twin(backend, batch, in_f, w1, w2,
                                                threshold, sparsity):
    spec = MLPSpec("prop_mlp", in_f, (w1, w2, 6))
    params = init_mlp_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(_input(_seed(batch, in_f, w1, w2, sparsity),
                           (batch, in_f), sparsity))
    fire_cfg = FireConfig(threshold=threshold, quantize_to_int8=True)
    cfg = engine.EngineConfig(backend=backend)
    with engine.trace_dispatch() as recs:
        ym = mlp_forward(params, x, spec, mnf=True, chain=True,
                         fire_cfg=fire_cfg, engine_cfg=cfg)
    assert not any(r.get("fallback_decode") for r in recs), recs
    yr = mlp_forward(params, x, spec, mnf=True, chain=False,
                     fire_cfg=fire_cfg, engine_cfg=cfg)
    assert bool(jnp.all(ym == yr)), "int8 chain != fake-quant twin"


# ---------------------------------------------------------------------------
# fire-gated recurrent decode (DESIGN.md §13): chained step == dense step
# bitwise at threshold 0 on the block backend; the pallas kernel is bitwise
# within-backend (gated vs all-live drive through the same kernel) and
# allclose to the dense step (interpret mode contracts mul-add chains into
# FMAs — a 1-ulp formulation difference the block path does not have).
# Zero-row (B == 0) and empty streams are in-distribution on purpose: the
# step must short-circuit before Pallas ever sees a 0-extent launch.
# ---------------------------------------------------------------------------

from repro.engine.stream import EventStream  # noqa: E402
from repro.kernels.mamba_scan.step import mamba_step_ref  # noqa: E402
from repro.kernels.wkv6.step import wkv6_step_ref  # noqa: E402


def _all_live_twin(stream, cfg):
    """The same drive with every K-block live (encode at threshold -1):
    what the gated kernel consumes when nothing is gated."""
    import dataclasses as _dc
    s = EventStream.encode(stream.dense(), blk_m=1, blk_k=stream.blk_k,
                           threshold=-1.0)
    return _dc.replace(s, signed=True)


@pytest.mark.parametrize("backend", ["block", "pallas"])
@settings(max_examples=10, deadline=None)
@given(g=st.integers(0, 6), d=st.integers(1, 20),
       threshold=st.sampled_from([0.0, 0.3]),
       sparsity=st.sampled_from([0.0, 0.5, 1.0]))
def test_recurrent_wkv6_chained_vs_dense(backend, g, d, threshold, sparsity):
    seed = _seed("wkv6", g, d, threshold, sparsity)
    rng = np.random.default_rng(seed)
    r, v, u = (jnp.asarray(rng.normal(size=(g, d)).astype(np.float32))
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.3, 0.99, (g, d)).astype(np.float32))
    s = jnp.asarray(rng.normal(size=(g, d, d)).astype(np.float32))
    k = _input(seed + 1, (g, d), sparsity)
    cfg = engine.EngineConfig(backend=backend,
                              threshold=threshold).for_recurrent(d).resolved()
    stream = engine.fire_delta(k, cfg)
    assert stream.signed
    with engine.trace_dispatch() as recs:
        o, s2 = engine.recurrent_step("wkv6", stream, s, cfg,
                                      r=r, v=v, w=w, u=u)
    if g > 0:
        assert any(rec.get("op") == "recurrent_step" and rec.get("chained")
                   for rec in recs), recs
    k_fired = fire(k, FireConfig(threshold=threshold, signed=True))
    o_ref, s_ref = wkv6_step_ref(r, k_fired, v, w, u, s)
    if backend == "block" or g == 0:
        assert bool(jnp.all(o == o_ref)), "gated o != dense step o"
        assert bool(jnp.all(s2 == s_ref)), "gated S' != dense step S'"
    else:
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_ref),
                                   atol=1e-5, rtol=1e-5)
        # Within-backend contract: gating changes nothing but the work.
        o_al, s_al = engine.recurrent_step(
            "wkv6", _all_live_twin(stream, cfg), s, cfg, r=r, v=v, w=w, u=u)
        assert bool(jnp.all(o == o_al)) and bool(jnp.all(s2 == s_al)), \
            "pallas gated != pallas all-live (within-backend bitwise)"


@pytest.mark.parametrize("backend", ["block", "pallas"])
@settings(max_examples=10, deadline=None)
@given(b=st.integers(0, 4), di=st.integers(1, 24), n=st.integers(1, 8),
       threshold=st.sampled_from([0.0, 0.3]),
       sparsity=st.sampled_from([0.0, 0.5, 1.0]))
def test_recurrent_mamba_chained_vs_dense(backend, b, di, n, threshold,
                                          sparsity):
    seed = _seed("mamba", b, di, n, threshold, sparsity)
    rng = np.random.default_rng(seed)
    da = jnp.asarray(rng.uniform(0.3, 0.99, (b, di, n)).astype(np.float32))
    bm, cm = (jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
              for _ in range(2))
    h = jnp.asarray(rng.normal(size=(b, di, n)).astype(np.float32))
    g = _input(seed + 1, (b, di), sparsity)
    cfg = engine.EngineConfig(backend=backend,
                              threshold=threshold).for_recurrent(di).resolved()
    stream = engine.fire_delta(g, cfg)
    with engine.trace_dispatch() as recs:
        y, h2 = engine.recurrent_step("mamba", stream, h, cfg,
                                      da=da, bmat=bm, cmat=cm)
    if b > 0:
        assert any(rec.get("op") == "recurrent_step" and rec.get("chained")
                   for rec in recs), recs
    g_fired = fire(g, FireConfig(threshold=threshold, signed=True))
    y_ref, h_ref = mamba_step_ref(g_fired, da, bm, cm, h)
    if backend == "block" or b == 0:
        assert bool(jnp.all(y == y_ref)), "gated y != dense step y"
        assert bool(jnp.all(h2 == h_ref)), "gated h' != dense step h'"
    else:
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(h2), np.asarray(h_ref),
                                   atol=1e-5, rtol=1e-5)
        y_al, h_al = engine.recurrent_step(
            "mamba", _all_live_twin(stream, cfg), h, cfg,
            da=da, bmat=bm, cmat=cm)
        assert bool(jnp.all(y == y_al)) and bool(jnp.all(h2 == h_al)), \
            "pallas gated != pallas all-live (within-backend bitwise)"


# ---------------------------------------------------------------------------
# signed fire: a negative supra-threshold delta is an EVENT, not a drop
# (regression — the fire phase used to assume ReLU-family events >= 0)
# ---------------------------------------------------------------------------

def test_signed_fire_emits_negative_deltas():
    acc = jnp.asarray([[-2.0, -0.5, 0.4, 3.0]], jnp.float32)
    fired = fire(acc, FireConfig(threshold=1.0, signed=True))
    np.testing.assert_array_equal(np.asarray(fired),
                                  [[-2.0, 0.0, 0.0, 3.0]])
    cfg = engine.EngineConfig(backend="block",
                              threshold=1.0).for_recurrent(4)
    stream = engine.fire_delta(acc, cfg)
    assert stream.signed
    # The event VALUES carry the sign — drop the dense twin so the check
    # reads the compacted events, not the cached map.
    got = stream.without_dense().dense()
    np.testing.assert_array_equal(np.asarray(got), [[-2.0, 0.0, 0.0, 3.0]])


def test_unsigned_stream_rejected_by_recurrent_step():
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32))
    s = jnp.asarray(rng.normal(size=(3, 8, 8)).astype(np.float32))
    r = v = w = u = jnp.abs(k)
    cfg = engine.EngineConfig(backend="block").for_recurrent(8)
    # ReLU-fired stream (unsigned): negative deltas were already dropped.
    unsigned = engine.fire(k, cfg.replace(signed=False, blk_m=1))
    assert not unsigned.signed
    reason = engine.recurrent_ineligible_reason(unsigned, "wkv6", cfg)
    assert reason == ("recurrent deltas are signed; this stream was fired "
                      "unsigned (ReLU fire), so negative deltas were "
                      "already dropped")
    with engine.trace_dispatch() as recs:
        engine.recurrent_step("wkv6", unsigned, s, cfg, r=r, v=v, w=w, u=u)
    rec = next(rec for rec in recs if rec.get("op") == "recurrent_step")
    assert rec.get("fallback_decode") and rec.get("reason") == reason, recs


def test_pool_rejects_signed_stream_by_name():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 4, 8, 3)).astype(np.float32))
    emit = engine.EngineConfig(backend="block", signed=True, blk_m=1,
                               blk_k=4)
    stream = engine.fire_conv(x, emit)
    assert stream.signed
    pool_cfg = engine.EngineConfig(backend="block", blk_m=1, blk_k=4)
    reason = engine.pool_ineligible_reason(stream, 2, 2, pool_cfg)
    assert reason == ("stream carries signed event values (signed/"
                      "magnitude fire); the segment max runs with identity "
                      "0 and needs a ReLU-family stream")
    with engine.trace_dispatch() as recs:
        out = engine.maxpool2d(stream, 2, 2, pool_cfg)
    assert any(rec.get("fallback_decode") and rec.get("reason") == reason
               for rec in recs), recs
    # The visible dense fallback still pools correctly.
    import jax.lax as lax
    ref = lax.reduce_window(np.asarray(stream.dense_nhwc()), -np.inf,
                            jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                            "VALID")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
