"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU asserting output shapes + no NaNs, plus decode==forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_params, lm_loss, prefill)
from repro.models.layers import unembed_matrix
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


def _batch_kwargs(cfg, b, key):
    kw = {}
    if cfg.vision_tokens:
        kw["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.02
    if cfg.encoder_decoder:
        kw["audio_frames"] = jax.random.normal(
            key, (b, cfg.enc_frames, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.02
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params, _ = init_params(KEY, cfg)
    b, s = 2, 32
    batch = dict(tokens=jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
                 labels=jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
                 **_batch_kwargs(cfg, b, KEY))
    opt = AdamWConfig(lr=1e-3)
    opt_state = adamw_init(params)

    loss, grads = jax.value_and_grad(lambda p: lm_loss(p, batch, cfg))(params)
    new_params, opt_state, metrics = adamw_update(grads, opt_state, params,
                                                  opt)
    assert np.isfinite(float(loss)), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually moved
    delta = jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()),
                         params, new_params)
    assert max(jax.tree.leaves(delta)) > 0, arch
    # no NaNs anywhere in the update
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(new_params)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32")
    params, _ = init_params(KEY, cfg)
    b, s = 2, 12
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)
    kw = _batch_kwargs(cfg, b, KEY)
    dkw = {k: v for k, v in kw.items() if k == "audio_frames"}
    h, _, _ = forward(params, toks, cfg, **kw)
    w = unembed_matrix(params["embed"], cfg)
    full = h[:, s - 1:s + 1].astype(jnp.float32) @ w.astype(jnp.float32)
    if cfg.final_logit_softcap:
        full = cfg.final_logit_softcap * jnp.tanh(
            full / cfg.final_logit_softcap)
    lg_pre, cache = prefill(params, toks[:, :s], cfg, max_len=s + 4, **kw)
    lg_dec, _ = decode_step(params, cache, toks[:, s:s + 1], s, cfg, **dkw)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]),
                               np.asarray(full[:, 0]), atol=5e-3)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(full[:, 1]), atol=5e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_shapes(arch):
    """Full (unreduced) config instantiates abstractly with exact dims."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg)[0],
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    emb = shapes["embed"]["tok"]
    assert emb.shape == (cfg.vocab_size, cfg.d_model)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    lead = jax.tree.leaves(shapes["layers"])[0].shape[0]
    assert lead == cfg.num_layers - n_dense
