"""End-to-end behaviour tests for the paper's system.

1. MNF CNN inference pipeline: event-driven network == dense network on the
   paper's workload topology, and the event accounting feeds the cost model
   end to end (activation sparsity in -> cycle/energy numbers out).
2. LM training pipeline: a reduced qwen2 with MNF-MLP trains on the
   synthetic Markov corpus and the loss decreases (the technique does not
   break optimization).
3. Serving pipeline: prefill + N decode steps greedy-match a full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.costmodel import network_cycles, table4_row
from repro.data import TokenStreamConfig, cnn_batch, markov_lm_batch
from repro.models import decode_step, forward, init_params, lm_loss, prefill
from repro.models.cnn import ALEXNET, cnn_forward, init_cnn_params, run_with_stats
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
def test_event_driven_cnn_pipeline_end_to_end():
    spec = ALEXNET.scaled(64)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = cnn_batch(2, 64, 3, step=0, activation_sparsity=0.6)
    logits_mnf, stats = run_with_stats(params, x, spec)
    logits_dense = cnn_forward(params, x, spec, mnf=False)
    np.testing.assert_allclose(np.asarray(logits_mnf),
                               np.asarray(logits_dense), atol=5e-3, rtol=5e-3)
    # measured events -> cost model
    cyc = network_cycles(stats, "mnf", d_w=0.5)
    assert cyc > 0
    row = table4_row(stats, w_density=0.5)
    assert row["frames_s"] > 0 and row["frames_j"] > 0
    # sparsity actually reduced work vs the dense-event count
    dense_cycles = network_cycles(
        [dict(s, in_events=s["in_elems"],
              event_macs=s["dense_macs"]) for s in stats], "mnf")
    assert cyc < dense_cycles


@pytest.mark.slow
def test_lm_training_loss_decreases():
    cfg = get_config("qwen2-0.5b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, vocab_size=64)
    params, _ = init_params(KEY, cfg)
    opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = adamw_init(params)
    ds = TokenStreamConfig(vocab_size=64, seq_len=32, global_batch=8)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, cfg))(params)
        params, state, _ = adamw_update(grads, state, params, opt)
        return params, state, loss

    losses = []
    for i in range(30):
        params, state, loss = step(params, state, markov_lm_batch(ds, i))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_serving_pipeline_greedy_consistency():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              compute_dtype="float32")
    params, _ = init_params(KEY, cfg)
    b, s, gen = 2, 10, 4
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    logits, cache = prefill(params, toks, cfg, max_len=s + gen)
    seq = toks
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(gen):
        seq = jnp.concatenate([seq, cur], axis=1)
        logits, cache = decode_step(params, cache, cur, s + i, cfg)
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    # teacher-forced full forward reproduces the same greedy continuations
    h, _, _ = forward(params, seq, cfg)
    from repro.models.layers import unembed_matrix
    w = unembed_matrix(params["embed"], cfg)
    full_logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    greedy_full = jnp.argmax(full_logits[:, s - 1:-1], -1)
    np.testing.assert_array_equal(np.asarray(seq[:, s:]),
                                  np.asarray(greedy_full))
