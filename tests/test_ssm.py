"""RWKV6 chunked formulation and Mamba chunked scan vs naive recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.wkv6.ref import wkv6_ref
from repro.models.ssm import (WKV_LOG_DECAY_MIN, mamba_apply, mamba_init,
                              mamba_step, wkv6_chunked, wkv6_step)


def test_wkv6_chunked_equals_ref_within_clamp(rng):
    b, h, t, d = 2, 2, 40, 8
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    wmin = float(np.exp(WKV_LOG_DECAY_MIN)) + 1e-3
    w = jnp.asarray(rng.uniform(wmin, 0.999, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    o, s = wkv6_chunked(r, k, v, w, u, chunk=8)
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(srf), atol=2e-3,
                               rtol=2e-3)


def test_wkv6_chunk_invariance(rng):
    b, h, t, d = 1, 2, 24, 8
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.3, 0.99, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    o1, s1 = wkv6_chunked(r, k, v, w, u, chunk=4)
    o2, s2 = wkv6_chunked(r, k, v, w, u, chunk=12)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3)


def test_wkv6_step_matches_scan(rng):
    b, h, t, d = 1, 1, 6, 4
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.4, 0.99, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k, v, w, u)
    s = jnp.zeros((b, h, d, d), jnp.float32)
    for i in range(t):
        o, s = wkv6_step(r[:, :, i], k[:, :, i], v[:, :, i], w[:, :, i], u, s)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf[:, :, -1]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(srf), atol=1e-4)


def _naive_mamba(p, x, cfg):
    """Step-by-step reference using mamba_step."""
    b, t, d = x.shape
    di = d * cfg.ssm.expand
    conv = jnp.zeros((b, cfg.ssm.conv_dim - 1, di), x.dtype)
    h = jnp.zeros((b, di, cfg.ssm.state_dim), jnp.float32)
    outs = []
    state = (conv, h)
    for i in range(t):
        o, state = mamba_step(p, x[:, i:i + 1], cfg, state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), state


def test_mamba_chunked_equals_stepwise(rng):
    cfg = get_config("hymba-1.5b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              ssm=dataclasses.replace(cfg.ssm, scan_chunk=5,
                                                      expand=1))
    p, _ = mamba_init(jax.random.PRNGKey(0), cfg, d_inner=cfg.d_model)
    x = jnp.asarray(rng.normal(size=(2, 13, cfg.d_model)).astype(np.float32)) * 0.3
    y, (conv, h) = mamba_apply(p, x, cfg)
    yr, (convr, hr) = _naive_mamba(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(conv), np.asarray(convr), atol=1e-5)


# ---------------------------------------------------------------------------
# fire-gated decode (DESIGN.md §13): the event path is a formulation change,
# not a numeric one — at threshold 0 the gated block decode is bitwise the
# ungated decode; raising the threshold strictly sheds events per token.
# ---------------------------------------------------------------------------

def _rwkv_decode_once(cfg, rng):
    import dataclasses
    from repro.models.ssm import (rwkv6_block_apply, rwkv6_block_decode,
                                  rwkv6_block_init)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    p, _ = rwkv6_block_init(jax.random.PRNGKey(7), cfg)
    x = jnp.asarray(rng.normal(size=(2, 6, cfg.d_model)).astype(np.float32))
    _, state = rwkv6_block_apply(p, x, cfg)
    tok = jnp.asarray(rng.normal(size=(2, 1, cfg.d_model)).astype(np.float32))
    return rwkv6_block_decode(p, tok, cfg, state)


def test_rwkv6_gated_decode_bitwise_at_zero_threshold():
    import dataclasses
    rng = np.random.default_rng(11)
    base = get_config("rwkv6-7b").reduced()
    assert base.mnf.enabled and base.mnf.threshold == 0.0
    y_gated, st_gated = _rwkv_decode_once(base, np.random.default_rng(11))
    off = dataclasses.replace(base,
                              mnf=dataclasses.replace(base.mnf,
                                                      enabled=False))
    y_dense, st_dense = _rwkv_decode_once(off, np.random.default_rng(11))
    assert bool(jnp.all(y_gated == y_dense))
    assert bool(jnp.all(st_gated["wkv"] == st_dense["wkv"]))
    # At threshold 0 every channel fires: B * heads * head_dim events.
    assert float(st_gated["events"]) > 0


def test_rwkv6_gated_decode_pallas_close():
    import dataclasses
    base = get_config("rwkv6-7b").reduced()
    pall = dataclasses.replace(base,
                               mnf=dataclasses.replace(base.mnf,
                                                       use_pallas=True))
    y_p, st_p = _rwkv_decode_once(pall, np.random.default_rng(11))
    y_b, st_b = _rwkv_decode_once(base, np.random.default_rng(11))
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_b), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_p["wkv"]),
                               np.asarray(st_b["wkv"]), atol=1e-4, rtol=1e-4)


def test_rwkv6_events_per_token_monotone_in_threshold():
    import dataclasses
    base = get_config("rwkv6-7b").reduced()
    counts = []
    for th in (0.0, 0.1, 0.5, 2.0):
        cfg = dataclasses.replace(base,
                                  mnf=dataclasses.replace(base.mnf,
                                                          threshold=th))
        _, st = _rwkv_decode_once(cfg, np.random.default_rng(11))
        counts.append(float(st["events"]))
    assert counts == sorted(counts, reverse=True), counts
    assert counts[0] > counts[-1], counts


def test_mamba_gated_step_bitwise_at_zero_threshold(rng):
    import dataclasses
    cfg = get_config("hymba-1.5b").reduced()
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              ssm=dataclasses.replace(cfg.ssm, expand=1))
    assert cfg.mnf.enabled and cfg.mnf.threshold == 0.0
    p, _ = mamba_init(jax.random.PRNGKey(3), cfg, d_inner=cfg.d_model)
    b, di = 2, cfg.d_model
    conv = jnp.asarray(rng.normal(
        size=(b, cfg.ssm.conv_dim - 1, di)).astype(np.float32))
    h = jnp.asarray(rng.normal(
        size=(b, di, cfg.ssm.state_dim)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
    y_g, (cv_g, h_g), n_ev = mamba_step(p, x, cfg, (conv, h),
                                        with_events=True)
    off = dataclasses.replace(cfg,
                              mnf=dataclasses.replace(cfg.mnf,
                                                      enabled=False))
    y_d, (cv_d, h_d) = mamba_step(p, x, off, (conv, h))
    assert bool(jnp.all(y_g == y_d))
    assert bool(jnp.all(h_g == h_d))
    assert bool(jnp.all(cv_g == cv_d))
    assert float(n_ev) == b * di  # threshold 0: every channel fires
