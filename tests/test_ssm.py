"""RWKV6 chunked formulation and Mamba chunked scan vs naive recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.wkv6.ref import wkv6_ref
from repro.models.ssm import (WKV_LOG_DECAY_MIN, mamba_apply, mamba_init,
                              mamba_step, wkv6_chunked, wkv6_step)


def test_wkv6_chunked_equals_ref_within_clamp(rng):
    b, h, t, d = 2, 2, 40, 8
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    wmin = float(np.exp(WKV_LOG_DECAY_MIN)) + 1e-3
    w = jnp.asarray(rng.uniform(wmin, 0.999, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    o, s = wkv6_chunked(r, k, v, w, u, chunk=8)
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(srf), atol=2e-3,
                               rtol=2e-3)


def test_wkv6_chunk_invariance(rng):
    b, h, t, d = 1, 2, 24, 8
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.3, 0.99, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    o1, s1 = wkv6_chunked(r, k, v, w, u, chunk=4)
    o2, s2 = wkv6_chunked(r, k, v, w, u, chunk=12)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3)


def test_wkv6_step_matches_scan(rng):
    b, h, t, d = 1, 1, 6, 4
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.4, 0.99, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    orf, srf = jax.vmap(wkv6_ref, in_axes=(1, 1, 1, 1, 0),
                        out_axes=(1, 1))(r, k, v, w, u)
    s = jnp.zeros((b, h, d, d), jnp.float32)
    for i in range(t):
        o, s = wkv6_step(r[:, :, i], k[:, :, i], v[:, :, i], w[:, :, i], u, s)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf[:, :, -1]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(srf), atol=1e-4)


def _naive_mamba(p, x, cfg):
    """Step-by-step reference using mamba_step."""
    b, t, d = x.shape
    di = d * cfg.ssm.expand
    conv = jnp.zeros((b, cfg.ssm.conv_dim - 1, di), x.dtype)
    h = jnp.zeros((b, di, cfg.ssm.state_dim), jnp.float32)
    outs = []
    state = (conv, h)
    for i in range(t):
        o, state = mamba_step(p, x[:, i:i + 1], cfg, state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), state


def test_mamba_chunked_equals_stepwise(rng):
    cfg = get_config("hymba-1.5b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              ssm=dataclasses.replace(cfg.ssm, scan_chunk=5,
                                                      expand=1))
    p, _ = mamba_init(jax.random.PRNGKey(0), cfg, d_inner=cfg.d_model)
    x = jnp.asarray(rng.normal(size=(2, 13, cfg.d_model)).astype(np.float32)) * 0.3
    y, (conv, h) = mamba_apply(p, x, cfg)
    yr, (convr, hr) = _naive_mamba(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(conv), np.asarray(convr), atol=1e-5)
