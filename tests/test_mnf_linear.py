"""Multiply-phase FC paths: scalar events (Alg. 2) and block events == dense."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (block_event_linear, dense_linear, mnf_linear,
                        scalar_event_linear)
from repro.core.fire import FireConfig


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 12), k=st.integers(1, 40), n=st.integers(1, 24),
       sparsity=st.floats(0, 1), seed=st.integers(0, 2 ** 16))
def test_block_event_linear_equals_dense(m, k, n, sparsity, seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray((r.normal(size=(m, k)) *
                     (r.random((m, k)) > sparsity)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    y = block_event_linear(a, w, blk_m=4, blk_k=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense_linear(a, w)),
                               atol=2e-4, rtol=2e-4)


def test_scalar_event_linear_equals_dense(rng):
    a = jnp.asarray((rng.normal(size=(32,)) *
                     (rng.random(32) > 0.6)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(scalar_event_linear(a, w)),
                               np.asarray(dense_linear(a, w)), atol=1e-5)


def test_mnf_linear_fire_phase(rng):
    """threshold-0 fire == ReLU(dense)."""
    a = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    y = mnf_linear(a, w, fire_cfg=FireConfig(threshold=0.0), blk_m=4, blk_k=8)
    ref = jnp.maximum(dense_linear(a, w), 0.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4)


def test_bias(rng):
    a = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(block_event_linear(a, w, b, blk_m=4, blk_k=8)),
        np.asarray(dense_linear(a, w, b)), atol=2e-4)
