"""Serving tier: batcher invariants, AOT engine, padding bitwiseness.

The policy half (ContinuousBatcher) is pure host-side state, tested
without compiling anything; the engine half compiles one tiny pipeline
per bucket once (module-scoped fixture) and every test reuses those
executables.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import (MeshCapacityError, checked_mesh,
                               make_serve_mesh)
from repro.serving import (ContinuousBatcher, ServeEngine, ServeEngineConfig,
                           pad_bucket, smallest_bucket)

BUCKETS = (1, 2, 4)


# -- batcher policy (no jax) --------------------------------------------------

def test_smallest_admissible_bucket():
    buckets = (1, 8, 32, 128)
    assert smallest_bucket(1, buckets) == 1
    assert smallest_bucket(2, buckets) == 8
    assert smallest_bucket(8, buckets) == 8
    assert smallest_bucket(9, buckets) == 32
    assert smallest_bucket(128, buckets) == 128
    with pytest.raises(ValueError):
        smallest_bucket(129, buckets)


def test_pad_bucket_zero_rows():
    imgs = [np.full((3, 3, 2), i + 1, np.float32) for i in range(2)]
    out = pad_bucket(imgs, 4)
    assert out.shape == (4, 3, 3, 2) and out.dtype == np.float32
    np.testing.assert_array_equal(out[0], imgs[0])
    np.testing.assert_array_equal(out[1], imgs[1])
    assert not out[2:].any()            # padding rows are exactly zero


def test_plan_tick_routes_head_of_queue():
    b = ContinuousBatcher((1, 8, 32, 128))
    assert b.plan_tick(1) == [(1, 1)]
    assert b.plan_tick(5) == [(8, 5)]          # smallest admissible, padded
    assert b.plan_tick(128) == [(128, 128)]
    # overflow spills into a second head-of-queue batch
    assert b.plan_tick(200) == [(128, 128), (128, 72)]
    # tick budget truncates the plan, never reorders it
    b2 = ContinuousBatcher((1, 8, 32, 128), max_batches_per_tick=1)
    assert b2.plan_tick(200) == [(128, 128)]


def test_fifo_across_ticks():
    b = ContinuousBatcher(BUCKETS)
    for _ in range(3):
        b.submit(None)
    bucket, reqs = b.next_batch()
    assert bucket == 4 and [r.rid for r in reqs] == [0, 1, 2]
    b.end_tick()
    for _ in range(2):
        b.submit(None)
    bucket, reqs = b.next_batch()
    assert bucket == 2 and [r.rid for r in reqs] == [3, 4]
    assert b.next_batch() is None


def test_no_starvation_under_budget():
    """With a 1-batch tick budget and sustained overload, completion order
    is still exactly submission order — no request is passed over."""
    b = ContinuousBatcher((1, 2), max_batches_per_tick=1)
    done = []
    for _ in range(6):
        for _ in range(3):              # arrivals outpace the budget
            b.submit(None)
        batch = b.next_batch()          # engine honours the budget of 1
        if batch:
            done.extend(r.rid for r in batch[1])
        b.end_tick()
    assert done == list(range(len(done)))
    # backlog grew (overload), but strictly the newest requests wait
    assert min(r.rid for r in b._queue) == len(done)


def test_request_stamps():
    b = ContinuousBatcher(BUCKETS)
    r = b.submit(None, submit_time=1.5)
    assert r.arrival_tick == 0 and r.submit_time == 1.5
    b.end_tick()
    r2 = b.submit(None)
    assert r2.arrival_tick == 1 and r2.rid == r.rid + 1
    _, reqs = b.next_batch()
    assert all(q.bucket == 2 for q in reqs)


# -- mesh capacity ------------------------------------------------------------

def test_mesh_capacity_error_is_actionable():
    with pytest.raises(MeshCapacityError) as ei:
        checked_mesh((8192, 2), ("data", "model"))
    msg = str(ei.value)
    assert "16384" in msg and "xla_force_host_platform_device_count" in msg


def test_mesh_capacity_fallback_warns_to_ones():
    with pytest.warns(RuntimeWarning, match="Falling back"):
        mesh = checked_mesh((8192, 2), ("data", "model"), fallback=True)
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \
        {"data": 1, "model": 1}


def test_make_serve_mesh_spans_devices():
    mesh = make_serve_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size == len(jax.devices())


# -- the engine (one compile per bucket, shared across tests) -----------------

@pytest.fixture(scope="module")
def served():
    from repro.models.cnn import MINI, init_cnn_params
    spec = MINI.scaled(8)
    params = init_cnn_params(jax.random.PRNGKey(0), spec,
                             weight_sparsity=0.5)
    eng = ServeEngine(spec, params, ServeEngineConfig(buckets=BUCKETS))
    rng = np.random.default_rng(0)
    images = np.maximum(rng.standard_normal((16, 8, 8, 3),
                                            dtype=np.float32), 0.0)
    return spec, params, eng, images


def test_warmup_compiles_every_bucket(served):
    _, _, eng, _ = served
    assert eng.recompiles == len(BUCKETS)
    assert set(eng.warmup_s) == set(BUCKETS)


def test_padding_bitwise_per_bucket(served):
    """Real rows of every padded bucket == the unpadded chained forward."""
    from repro.models.cnn import make_cnn_pipeline
    spec, params, eng, images = served
    ref_fn = make_cnn_pipeline(spec, donate=False)
    for bucket in BUCKETS:
        for n in {1, bucket // 2 + 1}:
            got = np.asarray(eng._compiled(bucket)(
                eng.params,
                eng._place(bucket, pad_bucket(list(images[:n]), bucket))))
            ref = np.asarray(ref_fn(params, jnp.asarray(images[:n])))
            np.testing.assert_array_equal(got[:n], ref), (bucket, n)
            assert got.shape[0] == bucket


def test_padding_rows_cannot_leak_into_real_rows(served):
    """Within one bucket executable, a real row's logits are bitwise
    independent of the other rows' content (zeros vs real images)."""
    _, _, eng, images = served
    for bucket in BUCKETS[1:]:
        padded = np.asarray(eng._compiled(bucket)(
            eng.params,
            eng._place(bucket, pad_bucket([images[0]], bucket))))
        full = np.asarray(eng._compiled(bucket)(
            eng.params,
            eng._place(bucket, pad_bucket(list(images[:bucket]), bucket))))
        np.testing.assert_array_equal(padded[0], full[0])


def test_recompile_counter_flat_over_ticks(served):
    _, _, eng, images = served
    warm = eng.recompiles
    for arrivals in (1, 3, 0, 4, 2):
        for i in range(arrivals):
            eng.submit(images[i])
        eng.run_tick()
    assert eng.recompiles == warm        # no steady-state trace/compile


def test_completions_are_fifo_with_latency(served):
    _, _, eng, _ = served
    rids = [r.rid for r in eng.completed]
    assert rids == sorted(rids) and len(rids) == 10
    assert all(r.latency_s > 0 and r.result is not None
               for r in eng.completed)


def test_boundary_report_no_fallback(served):
    _, _, eng, _ = served
    for bucket in BUCKETS:
        rep = eng.boundary_report(bucket)
        assert rep["fallback_decodes"] == 0
        assert rep["chained"] >= 1 and rep["pool_events"] == 1


def test_executable_snapshot_restore(served, tmp_path):
    """A restarted replica restores finished executables from cache_dir —
    zero recompiles, bitwise-identical logits."""
    spec, params, _, images = served
    cfg = ServeEngineConfig(buckets=(1,), cache_dir=str(tmp_path))
    first = ServeEngine(spec, params, cfg)
    assert first.recompiles == 1 and first.snapshot_hits == 0
    second = ServeEngine(spec, params, cfg)
    assert second.recompiles == 0 and second.snapshot_hits == 1
    assert "load_s" in second.warmup_s[1]
    x = pad_bucket([images[0]], 1)
    y1 = np.asarray(first._compiled(1)(first.params, first._place(1, x)))
    y2 = np.asarray(second._compiled(1)(second.params, second._place(1, x)))
    np.testing.assert_array_equal(y1, y2)


def test_stats_report(served):
    _, _, eng, _ = served
    s = eng.stats()
    assert s["requests"] == 10 and s["requests_s"] > 0
    assert s["p99_ms"] >= s["p50_ms"] > 0
    assert set(s["per_bucket"]) == set(BUCKETS)
    assert sum(pb["requests"] for pb in s["per_bucket"].values()) == 10
    assert s["recompiles"] == len(BUCKETS)
