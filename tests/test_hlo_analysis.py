"""Loop-aware HLO cost analyzer vs hand-computable programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo_text, parse_computations


def _compile_text(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_flops_scaled_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                        jax.ShapeDtypeStruct((128, 128), jnp.float32))
    cost = analyze_hlo_text(txt)
    expected = 2 * 128 ** 3 * 10
    assert cost.flops == pytest.approx(expected, rel=0.001)


def test_single_matmul_exact():
    txt = _compile_text(lambda a, b: a @ b,
                        jax.ShapeDtypeStruct((256, 512), jnp.float32),
                        jax.ShapeDtypeStruct((512, 128), jnp.float32))
    cost = analyze_hlo_text(txt)
    assert cost.flops == 2 * 256 * 512 * 128


def test_batched_dot_general():
    f = lambda a, b: jnp.einsum("bik,bkj->bij", a, b)
    txt = _compile_text(f, jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
                        jax.ShapeDtypeStruct((4, 64, 16), jnp.float32))
    cost = analyze_hlo_text(txt)
    assert cost.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.05)


def test_scan_bytes_not_charged_full_stack():
    """dynamic-slice of stacked weights inside a scan must charge per-slice
    bytes, not the whole stack each iteration."""
    L, D = 8, 64

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((D, D), jnp.float32),
                        jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    cost = analyze_hlo_text(txt)
    stack_bytes = L * D * D * 4
    # per-iteration slice+carry+activation traffic is a small constant × the
    # slice size; the failure mode this guards against is O(L × stack)
    # (= 64× stack here).  Legitimate traffic lands well under 16×.
    assert stack_bytes < cost.bytes < 16 * stack_bytes


def test_parse_computations_structure():
    txt = _compile_text(lambda a: jnp.sum(a ** 2),
                        jax.ShapeDtypeStruct((64,), jnp.float32))
    parsed = parse_computations(txt)
    assert parsed["comps"]
    # all instruction names got shape entries
    assert parsed["shapes"]
