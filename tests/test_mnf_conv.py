"""Event-driven conv (Alg. 1): scalar walk and tap-matmul == lax.conv."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (dense_conv2d, scalar_event_conv2d, tap_event_conv2d,
                        conv_out_size)
from repro.core.mnf_conv import event_params_for_pixel


@pytest.mark.parametrize("stride,padding", [(1, 0), (1, 1), (2, 0), (2, 1),
                                            (4, 2)])
def test_tap_event_conv_equals_dense(rng, stride, padding):
    x = jnp.asarray((rng.normal(size=(2, 9, 9, 3)) *
                     (rng.random((2, 9, 9, 3)) > 0.5)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 5)).astype(np.float32))
    y = tap_event_conv2d(x, w, stride=stride, padding=padding, blk_m=4,
                         blk_k=3)
    ref = dense_conv2d(x, w, stride=stride, padding=padding)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("stride,padding", [(1, 0), (1, 1), (2, 1)])
def test_scalar_event_conv_equals_dense(rng, stride, padding):
    x = jnp.asarray((rng.normal(size=(6, 6, 2)) *
                     (rng.random((6, 6, 2)) > 0.5)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 2, 4)).astype(np.float32))
    y = scalar_event_conv2d(x, w, stride=stride, padding=padding)
    ref = dense_conv2d(x[None], w, stride=stride, padding=padding)[0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def test_event_params_match_paper_example():
    """§4.1.1 worked example: 4×4 IFM, 3×3 filter, stride 1, pixel (1,1)."""
    sw, sn, xj, yj, oy0, ox0, dy0, dx0 = event_params_for_pixel(
        1, 1, k=3, stride=1, padding=0, oy_size=2, ox_size=2)
    assert int(sw) == 4          # start weight address
    assert int(sn) == 0          # start neuron address
    assert int(xj) == 1 and int(yj) == 1


def test_5x5_kernel(rng):
    x = jnp.asarray(rng.normal(size=(1, 11, 11, 2)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(5, 5, 2, 3)).astype(np.float32))
    y = tap_event_conv2d(x, w, stride=1, padding=2, blk_m=4, blk_k=2)
    ref = dense_conv2d(x, w, stride=1, padding=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
