"""Fire phase + int8 quantization (paper §4.2, §5.2.3 step 2)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (FireConfig, calibrate, dequantize, fake_quant, fire,
                        fire_stats, quantize, requantize_accumulator, QParams)


def test_fire_is_relu_at_zero(rng):
    x = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fire(x)),
                               np.maximum(np.asarray(x), 0.0))


def test_fire_magnitude_mode(rng):
    x = jnp.asarray([[-2.0, -0.1, 0.1, 2.0]])
    y = fire(x, FireConfig(threshold=0.5, magnitude=True))
    np.testing.assert_allclose(np.asarray(y), [[-2.0, 0.0, 0.0, 2.0]])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_fire_idempotent(seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(8, 8)).astype(np.float32))
    cfg = FireConfig(threshold=0.3)
    once = fire(x, cfg)
    np.testing.assert_allclose(np.asarray(fire(once, cfg)), np.asarray(once))


def test_fire_stats_density(rng):
    x = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    _, n, density = fire_stats(x)
    assert abs(float(density) - 0.5) < 0.15      # ~half positive
    assert int(n) == int((np.asarray(x) > 0).sum())


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_quantize_roundtrip_error_bound(seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(64,)).astype(np.float32))
    qp = calibrate(x)
    err = np.abs(np.asarray(fake_quant(x, qp)) - np.asarray(x))
    assert err.max() <= float(qp.scale) * 0.5001 + 1e-7


def test_requantize_accumulator():
    in_qp = QParams.symmetric(0.1)
    w_qp = QParams.symmetric(0.05)
    out_qp = QParams.symmetric(0.2)
    acc = jnp.asarray([100, -50, 0], jnp.int32)   # real = acc*0.005
    q = requantize_accumulator(acc, in_qp, w_qp, out_qp)
    real = np.asarray(acc) * 0.005
    np.testing.assert_allclose(np.asarray(q) * 0.2, real, atol=0.1)
