"""Fault tolerance: restart-resume, straggler detection, elastic meshes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import (LoopConfig, ResilientLoop, StragglerDetector,
                           choose_mesh_shape, reshard_tree)


def _make_loop(tmp_path, total=20, ckpt_every=5):
    def step_fn(state, batch):
        (w,) = state
        w = w + batch
        return (w,), dict(loss=float(jnp.sum(w)))

    def batch_fn(step):
        return jnp.asarray(float(step))

    return ResilientLoop(LoopConfig(total_steps=total,
                                    ckpt_dir=str(tmp_path / "ck"),
                                    ckpt_every=ckpt_every),
                         step_fn, batch_fn)


def test_loop_runs_and_checkpoints(tmp_path):
    loop = _make_loop(tmp_path)
    (w,), final, preempted = loop.run((jnp.zeros(()),))
    assert final == 20 and not preempted
    assert float(w) == sum(range(20))


def test_loop_resumes_from_checkpoint(tmp_path):
    loop = _make_loop(tmp_path, total=10, ckpt_every=5)
    loop.run((jnp.zeros(()),))
    # extend the run: a fresh loop resumes from step 10's checkpoint
    loop2 = _make_loop(tmp_path, total=15, ckpt_every=5)
    (w,), final, _ = loop2.run((jnp.zeros(()),))
    assert final == 15
    assert float(w) == sum(range(15))     # no re-applied or skipped batches


def test_straggler_detector():
    det = StragglerDetector(factor=2.0, alpha=0.5)
    assert not det.observe(0, 1.0)
    assert not det.observe(1, 1.1)
    assert det.observe(2, 5.0)            # 5x the EWMA
    assert len(det.flagged) == 1
    # stragglers don't poison the EWMA
    assert det.ewma < 1.2


def test_choose_mesh_shape():
    assert choose_mesh_shape(512, model_parallel=16) == (2, 16, 16)
    assert choose_mesh_shape(256, model_parallel=16) == (16, 16)
    # losing a host: 248 devices -> model axis shrinks to keep divisibility
    shape = choose_mesh_shape(248, model_parallel=16)
    import math
    assert math.prod(shape) <= 248


def test_reshard_tree_single_device():
    from repro.launch.mesh import checked_mesh
    mesh = checked_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.ones((4, 8))}
    specs = {"w": ("embed", "ff")}
    out = reshard_tree(tree, specs, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
