"""Engine API: every registered backend == dense oracle; registry seams."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import engine
from repro.kernels.event_matmul.ref import mask_dead_blocks


def _sparse(r, shape, sparsity):
    return jnp.asarray((r.normal(size=shape) *
                        (r.random(shape) > sparsity)).astype(np.float32))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_all_builtin_backends_registered():
    for op in ("matmul", "linear", "conv2d", "fire"):
        assert set(engine.BACKENDS) <= set(engine.list_backends(op)), op
    # the chained path exists for the event-native backends
    assert set(engine.list_backends("linear_events")) == {"block", "pallas"}


def test_register_and_dispatch_custom_backend():
    calls = []

    def fancy(a, w, cfg):
        calls.append(a.shape)
        return a @ w

    engine.register_backend("matmul", "fancy", fancy)
    try:
        cfg = engine.EngineConfig(backend="fancy")
        y = engine.matmul(jnp.ones((2, 3)), jnp.ones((3, 4)), cfg)
        assert calls == [(2, 3)] and y.shape == (2, 4)
    finally:
        engine.registry._REGISTRY.pop(("matmul", "fancy"))


def test_unknown_backend_errors():
    with pytest.raises(KeyError, match="available"):
        engine.matmul(jnp.ones((2, 2)), jnp.ones((2, 2)),
                      engine.EngineConfig(backend="nope"))
    with pytest.raises(KeyError):
        engine.get_backend("matmul", "nope")


def test_auto_resolves_off_tpu():
    cfg = engine.EngineConfig(backend="auto")
    assert cfg.resolve_backend() in engine.BACKENDS
    r = cfg.resolved()
    assert r.backend != "auto" and r.interpret is not None


# ---------------------------------------------------------------------------
# linear: all backends == dense oracle at threshold 0
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 10), k=st.integers(1, 33), n=st.integers(1, 17),
       sparsity=st.floats(0, 1), seed=st.integers(0, 2 ** 16))
def test_linear_backends_agree_with_dense(m, k, n, sparsity, seed):
    r = np.random.default_rng(seed)
    a = _sparse(r, (m, k), sparsity)
    w = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    ref = np.asarray(a) @ np.asarray(w) + np.asarray(b)
    for name in engine.list_backends("linear"):
        cfg = engine.EngineConfig(backend=name, blk_m=4, blk_k=8, blk_n=8)
        y = engine.linear(a, w, b, cfg)
        np.testing.assert_allclose(np.asarray(y), ref, atol=2e-3, rtol=2e-3,
                                   err_msg=f"backend={name}")


def test_linear_leading_dims():
    r = np.random.default_rng(0)
    x = _sparse(r, (2, 3, 16), 0.5)
    w = jnp.asarray(r.normal(size=(16, 5)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_m=4, blk_k=8)
    y = engine.linear(x, w, cfg=cfg)
    assert y.shape == (2, 3, 5)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x) @ np.asarray(w), atol=1e-4)


# ---------------------------------------------------------------------------
# conv2d: all backends == dense oracle at threshold 0
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(stride=st.sampled_from([1, 2]), padding=st.sampled_from([0, 1]),
       sparsity=st.floats(0, 1), seed=st.integers(0, 2 ** 16))
def test_conv2d_backends_agree_with_dense(stride, padding, sparsity, seed):
    r = np.random.default_rng(seed)
    x = _sparse(r, (2, 7, 7, 3), sparsity)
    w = jnp.asarray(r.normal(size=(3, 3, 3, 4)).astype(np.float32))
    ref = engine.conv2d(x, w, cfg=engine.EngineConfig(backend="dense"),
                        stride=stride, padding=padding)
    for name in engine.list_backends("conv2d"):
        cfg = engine.EngineConfig(backend=name, blk_m=4, blk_k=8, blk_n=4)
        y = engine.conv2d(x, w, cfg=cfg, stride=stride, padding=padding)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-3,
                                   rtol=2e-3, err_msg=f"backend={name}")


# ---------------------------------------------------------------------------
# lossy paths: capacity truncation and threshold > 0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
@pytest.mark.parametrize("cap", [1, 2, 3])
def test_capacity_truncation_semantics(backend, cap):
    """With capacity < live blocks, the engine multiplies exactly the kept
    (first, in ascending K-block order) events — decode(encode_cap(x)) @ w."""
    r = np.random.default_rng(7)
    a = jnp.asarray(r.normal(size=(4, 40)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(40, 6)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=4, blk_k=8, blk_n=2,
                              capacity=cap)
    y = engine.linear(a, w, cfg=cfg)
    kept = engine.EventStream.encode(a, blk_m=4, blk_k=8, capacity=cap,
                                     keep_dense=False).dense()
    ref = np.asarray(kept) @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4, rtol=1e-4)
    # and it is genuinely lossy here
    full = np.asarray(a) @ np.asarray(w)
    assert not np.allclose(ref, full)


@pytest.mark.parametrize("backend", ["block", "pallas"])
def test_threshold_drops_dead_tiles(backend):
    """threshold > 0 must match the dead-tile-masked dense oracle."""
    r = np.random.default_rng(3)
    a = np.full((8, 32), 1e-4, np.float32)
    a[:4, :8] = r.normal(size=(4, 8))
    w = jnp.asarray(r.normal(size=(32, 6)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=4, blk_k=8, blk_n=2,
                              threshold=1e-2)
    y = engine.linear(jnp.asarray(a), w, cfg=cfg)
    masked = mask_dead_blocks(jnp.asarray(a), blk_m=4, blk_k=8,
                              threshold=1e-2)
    ref = np.asarray(masked) @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4, rtol=1e-4)
    assert not np.allclose(ref, np.asarray(a) @ np.asarray(w))


# ---------------------------------------------------------------------------
# engine-only model stack (acceptance: no direct kernel calls in models/)
# ---------------------------------------------------------------------------

def test_models_use_engine_only():
    import inspect

    import repro.models.cnn as cnn
    import repro.models.layers as layers
    for mod in (cnn, layers):
        src = inspect.getsource(mod)
        for sym in ("block_event_linear", "tap_event_conv2d",
                    "event_matmul"):
            assert f"{sym}(" not in src and f"import {sym}" not in src \
                and f"{sym}," not in src, \
                f"{mod.__name__} calls {sym} directly"


def test_sparsify_identity_at_zero_threshold():
    r = np.random.default_rng(0)
    h = jnp.asarray(r.normal(size=(3, 5, 16)).astype(np.float32))
    cfg = engine.EngineConfig(threshold=0.0, magnitude=True)
    np.testing.assert_array_equal(np.asarray(engine.sparsify(h, cfg)),
                                  np.asarray(h))
    cfg = engine.EngineConfig(threshold=0.5, magnitude=True, blk_m=4, blk_k=8)
    y = engine.sparsify(h, cfg)
    assert float(jnp.mean(y == 0)) > 0.0
