"""Minimal stand-in for the ``hypothesis`` API used by this test suite.

The container images the suite runs in don't always ship hypothesis (see
requirements-dev.txt for the real dependency).  Rather than hard-failing
collection, ``conftest.py`` installs this module as ``hypothesis`` when the
real package is absent.  It implements exactly the surface the tests use —
``@settings(max_examples=..., deadline=...)``, ``@given(**strategies)``, and
``st.integers / st.floats / st.sampled_from / st.booleans`` — drawing a
deterministic (per-test-name seeded) batch of examples instead of doing real
property search.  No shrinking, no database; just enough to keep the
property tests meaningful everywhere.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sampler, desc: str):
        self._sampler = sampler
        self._desc = desc

    def sample(self, rng: np.random.Generator):
        return self._sampler(rng)

    def __repr__(self):
        return f"st.{self._desc}"


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)),
                     f"integers({min_value}, {max_value})")


def _floats(min_value: float, max_value: float, **_) -> _Strategy:
    return _Strategy(lambda r: float(r.uniform(min_value, max_value)),
                     f"floats({min_value}, {max_value})")


def _sampled_from(elements) -> _Strategy:
    elems = list(elements)
    return _Strategy(lambda r: elems[int(r.integers(0, len(elems)))],
                     f"sampled_from({elems})")


def _booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.integers(0, 2)), "booleans()")


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.sampled_from = _sampled_from
strategies.booleans = _booleans


class HealthCheck:
    """Placeholder constants (accepted, ignored)."""
    too_slow = data_too_large = filter_too_much = all = None


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*args, **strategy_kwargs):
    if args:
        raise NotImplementedError(
            "hypothesis fallback shim supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            # Deterministic per-test stream so failures reproduce.
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                example = {k: s.sample(rng)
                           for k, s in strategy_kwargs.items()}
                try:
                    fn(*a, **example, **kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): "
                        f"{fn.__name__}({example!r})") from e

        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution: expose only the remaining (real fixture) params.
        sig = inspect.signature(fn)
        left = [p for name, p in sig.parameters.items()
                if name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=left)
        del wrapper.__wrapped__
        return wrapper
    return deco
