"""Event-native MLP models (DESIGN.md §12): the FC/MNIST-class family.

Pins the module's three contracts:

  * the chained forward (fire→EventStream→linear at every hidden boundary)
    is bitwise the per-layer round-trip twin within a backend — f32, and
    int8 against the fake-quant twin;
  * the boundary accounting is structurally densify- and re-tile-free
    (every boundary is FC→FC, already in the flattened view);
  * ``fc_in_events`` is the one counting rule CNN and MLP share: the
    chained stream's twin-free event count equals the dense twin's count at
    the configured threshold — including threshold > 0, where counting
    plain non-zeros on the dense side would diverge.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core.fire import FireConfig, fire
from repro.models.cnn import fc_in_events
from repro.models.mlp import (LENET_300_100, MLP_MINI, init_mlp_params,
                              make_mlp_pipeline, mlp_boundary_summary,
                              mlp_forward, mlp_layer_dense_macs,
                              run_mlp_with_stats)

KEY = jax.random.PRNGKey(0)


def _x(seed: int, shape, sparsity=0.5) -> jax.Array:
    r = np.random.default_rng(seed)
    x = np.abs(r.normal(size=shape)) * (r.random(shape) > sparsity)
    return jnp.asarray(x.astype(np.float32))


# ---------------------------------------------------------------------------
# chained == round-trip twin, bitwise (f32 and int8), both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
@pytest.mark.parametrize("int8", [False, True])
def test_mlp_chain_bitwise_vs_roundtrip(backend, int8):
    spec = MLP_MINI
    params = init_mlp_params(KEY, spec, weight_sparsity=0.5)
    x = _x(1, (4, spec.in_features), 0.4)
    fire_cfg = FireConfig(threshold=0.05, quantize_to_int8=int8)
    cfg = engine.EngineConfig(backend=backend)
    with engine.trace_dispatch() as recs:
        ym = mlp_forward(params, x, spec, mnf=True, chain=True,
                         fire_cfg=fire_cfg, engine_cfg=cfg)
    # Only stream-consuming boundaries dispatch through the event seam:
    # the head takes the dense input, the two hidden boundaries chain.
    fc = [r for r in recs if r.get("op") == "linear"]
    assert len(fc) == len(spec.widths) - 1
    assert all(r.get("chained") for r in fc), fc
    assert not any(r.get("fallback_decode") or r.get("decode")
                   for r in recs), recs
    yr = mlp_forward(params, x, spec, mnf=True, chain=False,
                     fire_cfg=fire_cfg, engine_cfg=cfg)
    assert bool(jnp.all(ym == yr)), \
        "chained != round-trip twin (int8=%s)" % int8
    if not int8:
        yd = mlp_forward(params, x, spec, mnf=False, fire_cfg=fire_cfg)
        np.testing.assert_allclose(np.asarray(ym), np.asarray(yd),
                                   atol=2e-4, rtol=2e-4)


def test_mlp_lenet_chain_bitwise():
    """The paper's LeNet-300-100 workload, pruned to 50% weights."""
    spec = LENET_300_100
    params = init_mlp_params(KEY, spec, weight_sparsity=0.5)
    x = _x(2, (2, spec.in_features), 0.6)
    cfg = engine.EngineConfig(backend="block")
    ym = mlp_forward(params, x, spec, mnf=True, chain=True, engine_cfg=cfg)
    yr = mlp_forward(params, x, spec, mnf=True, chain=False, engine_cfg=cfg)
    assert bool(jnp.all(ym == yr))


def test_mlp_pipeline_matches_forward():
    spec = MLP_MINI
    params = init_mlp_params(KEY, spec)
    x = _x(3, (2, spec.in_features))
    fn = make_mlp_pipeline(spec, donate=False)
    assert bool(jnp.all(fn(params, x)
                        == mlp_forward(params, x, spec, mnf=True)))


# ---------------------------------------------------------------------------
# boundary accounting: structurally densify- and re-tile-free
# ---------------------------------------------------------------------------

def test_mlp_boundary_summary_schema():
    out = mlp_boundary_summary(MLP_MINI, batch=4)
    assert out["conv"] == 0 and out["pool"] == 0 and out["pool_events"] == 0
    assert out["fc"] == len(MLP_MINI.widths)
    assert out["densify"] == 0 and out["retile"] == 0
    assert out["input_encode"] == 0
    # One route decision per stream-consuming boundary (all but the head).
    assert len(out["routes"]) == len(MLP_MINI.widths) - 1
    for r in out["routes"]:
        assert r["op"] == "linear" and r["route"] in ("event", "dense")
        assert r["shape_class"].startswith("n")


def test_mlp_stats_event_macs_bounded():
    spec = MLP_MINI
    params = init_mlp_params(KEY, spec, weight_sparsity=0.5)
    x = _x(4, (4, spec.in_features), 0.7)
    _, stats = run_mlp_with_stats(params, x, spec)
    assert [s["dense_macs"] for s in stats] == \
        [4.0 * m for m in mlp_layer_dense_macs(spec)]
    for s in stats:
        assert s["kind"] == "fc" and s["event_macs"] <= s["dense_macs"]
    # Layer 1 charges exactly the input's non-zeros (Algorithm 2).
    assert stats[0]["in_events"] == float(jnp.sum(jnp.abs(x) > 0))


# ---------------------------------------------------------------------------
# fc_in_events: dense twin == chained stream, at threshold > 0
# ---------------------------------------------------------------------------

def test_fc_in_events_parity_fc_boundary():
    t = 0.2
    acc = jnp.asarray(np.random.default_rng(5).normal(
        size=(4, 64)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", threshold=t)
    stream = engine.fire(acc, cfg, keep_dense=False)
    dense = fire(acc, FireConfig(threshold=t))
    assert float(fc_in_events(stream)) == float(fc_in_events(dense, t))
    # The rule counts supra-threshold survivors, not raw non-zeros: at
    # threshold > 0 those differ, which is exactly the regression pinned.
    assert float(fc_in_events(dense, t)) < float(jnp.sum(jnp.abs(acc) > 0))


def test_fc_in_events_parity_conv_fc_seam():
    t = 0.15
    b, h, w, c = 2, 3, 8, 8
    x = jnp.asarray(np.random.default_rng(6).normal(
        size=(b, h, w, c)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4, threshold=t)
    s = engine.fire_conv(x, cfg, blk_m=1, keep_dense=False).retile_fc()
    dense = fire(x, FireConfig(threshold=t)).reshape(b, h * w * c)
    assert float(fc_in_events(s)) == float(fc_in_events(dense, t))
