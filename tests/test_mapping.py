"""PE mapping equations (paper §5.3, Eq. 1/2, Fig. 7 worked examples)."""
from repro.core import (PECapacity, conv_pes, fc_pes, noc_grid,
                        plan_conv_layer, plan_fc_layer)

CAP = PECapacity(neurons=800, weights=9000)


def test_fig7_conv_example():
    """28×28 IFM pad 1, two 3×3 filters, N=800 ⇒ 2 PEs."""
    assert conv_pes(28, 28, 3, c_out=2, c_in=1, cap=CAP) == 2


def test_fc_example():
    """1568×128 FC, W=9000 ⇒ 23 PEs (paper §5.3)."""
    assert fc_pes(1568, 128, CAP) == 23


def test_noc_grid():
    assert noc_grid(23) == (5, 5)
    assert noc_grid(2) == (2, 2)
    assert noc_grid(1) == (1, 1)


def test_plan_conv_layer():
    m = plan_conv_layer(28, 28, 3, c_out=2, c_in=1, cap=CAP)
    assert m.pes == 2 and m.event_fanout == 2
    assert m.neurons_per_pe == 784


def test_weight_bound_dominates():
    # Huge filter bank: weight SRAM forces the PE count.
    assert conv_pes(4, 4, 3, c_out=512, c_in=512, cap=CAP) == \
        -(-3 * 3 * 512 * 512 // 9000)


def test_table3_capacity():
    from repro.core.mapping import PAPER_PE
    assert PAPER_PE.neurons == int(67.5 * 1024 // 4)
    assert PAPER_PE.weights == int(691.2 * 1024)
