"""Strip-tiled fused-tap event conv (DESIGN.md §6).

The fused kernel consumes a strip-aligned (blk_m == STRIP_W) conv stream in
one launch per layer; it must be *bit-identical* to the pixel-granular
per-tap path (the oracle) — strips only interleave exact zeros into the
same reduction tree.  Strides 1, 2 and 4 all ride it (a stride-s tap
gathers up to strip_parts(s) interleaved partial strips, dead parts
compacted out of the plan).  Ineligible geometry (stride not in
STRIP_STRIDES, W % 8 != 0, odd widths, misaligned output width) must
degrade visibly, never silently.
"""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import events as ev
from repro.core.mnf_conv import dense_conv2d
from repro.kernels.event_conv import fused_conv_plan
from repro.models.cnn import (ALEXNET_DS, ALEXNET_FF, MINI_S4, VGG16,
                              VGG16_DS, CNNSpec, ConvSpec,
                              FCSpec, PoolSpec, cnn_forward,
                              conv_downsampled, init_cnn_params)

KEY = jax.random.PRNGKey(0)


def _fired(seed, shape, sparsity=0.5):
    r = np.random.default_rng(seed)
    x = r.normal(size=shape) * (r.random(shape) > sparsity)
    return jax.nn.relu(jnp.asarray(x.astype(np.float32)))


# ---------------------------------------------------------------------------
# bit-exactness: fused strip path == per-tap pixel path, per backend
# ---------------------------------------------------------------------------

ELIGIBLE = [  # (B, H, W, CI, CO, k, padding, stride) — all strip-eligible
    (2, 6, 8, 5, 8, 3, 1, 1),
    (1, 8, 16, 3, 16, 3, 1, 1),
    (2, 5, 8, 4, 16, 5, 2, 1),   # odd height
    (1, 9, 16, 2, 8, 1, 0, 1),   # 1x1 conv
    (1, 4, 16, 3, 8, 9, 4, 1),   # widest eligible filter (max tap shift)
    (1, 8, 16, 5, 8, 3, 1, 2),   # stride-2 "VGG-ds" 3x3 block
    (2, 7, 16, 4, 8, 5, 2, 2),   # stride-2 5x5, odd height
    (1, 9, 16, 3, 8, 1, 0, 2),   # stride-2 1x1 projection conv
    (1, 6, 16, 2, 8, 9, 4, 2),   # stride-2 widest filter (3-part straddles)
    (1, 8, 32, 5, 8, 3, 1, 4),   # stride-4 3x3 (5-part straddle plan)
    (1, 11, 32, 3, 8, 11, 4, 4),  # stride-4 k=11: the AlexNet conv1 class
    (2, 9, 32, 4, 8, 1, 0, 4),   # stride-4 1x1 projection conv
]


@pytest.mark.parametrize("backend", ["block", "pallas"])
@pytest.mark.parametrize("shape", ELIGIBLE)
def test_strip_bitwise_equals_pertap_and_oracle(backend, shape):
    b, h, w0, ci, co, k, p, s = shape
    x = _fired(sum(shape), (b, h, w0, ci))
    r = np.random.default_rng(1)
    wgt = jnp.asarray(r.normal(size=(k, k, ci, co)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=4, blk_n=4)
    strip = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=False)
    pixel = engine.fire_conv(x, cfg, blk_m=1, keep_dense=False)
    assert strip.events.block_idx.shape[0] * engine.STRIP_W \
        == pixel.events.block_idx.shape[0]          # 8x smaller event grid
    with engine.trace_dispatch() as recs:
        y_strip = engine.conv2d(strip, wgt, cfg=cfg, stride=s, padding=p)
    assert any(rec.get("strip") and rec.get("chained")
               and rec.get("launches") == 1 and rec.get("stride") == s
               for rec in recs), recs
    assert not any(rec.get("decode") or rec.get("fallback_decode")
                   for rec in recs)
    y_pix = engine.conv2d(pixel, wgt, cfg=cfg, stride=s, padding=p)
    assert bool(jnp.all(y_strip == y_pix)), "fused strip != per-tap bitwise"
    ref = dense_conv2d(x, wgt, stride=s, padding=p)
    np.testing.assert_allclose(np.asarray(y_strip), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# eligibility rules + EngineConfig.for_conv strip selection/validation
# ---------------------------------------------------------------------------

def test_strip_eligibility_rules():
    assert engine.strip_eligible(8, 3, 1, 1)
    assert engine.strip_eligible(16, 9, 1, 4)          # OX == W
    assert engine.strip_eligible(16, 3, 2, 1)          # stride-2 ds block
    assert engine.strip_eligible(16, 1, 2, 0)          # stride-2 projection
    assert engine.strip_eligible(32, 3, 4, 1)          # stride-4 ds block
    assert engine.strip_eligible(32, 11, 4, 4)         # AlexNet-class conv1
    assert not engine.strip_eligible(8, 3, 2, 1)       # OX = 4, misaligned
    assert not engine.strip_eligible(16, 3, 4, 1)      # OX = 4, misaligned
    assert not engine.strip_eligible(24, 3, 3, 1)      # stride 3 unvalidated
    assert not engine.strip_eligible(12, 3, 1, 1)      # W % 8 != 0
    assert not engine.strip_eligible(7, 3, 1, 1)       # odd width
    assert not engine.strip_eligible(16, 3, 1, 0)      # OX = 14, misaligned
    # ragged/tiny CO voids the bitwise contract (M-dependent dot lowering)
    assert engine.strip_eligible(8, 3, 1, 1, co=engine.STRIP_CO_MIN)
    assert engine.strip_eligible(8, 3, 1, 1, co=64)
    assert not engine.strip_eligible(8, 3, 1, 1, co=2)
    assert not engine.strip_eligible(8, 3, 1, 1, co=9)
    assert not engine.strip_eligible(8, 3, 1, 1, co=12)
    assert "stride" in engine.strip_ineligible_reason(24, 3, 3, 1)
    assert "output width" in engine.strip_ineligible_reason(16, 3, 4, 1)
    assert "width 12" in engine.strip_ineligible_reason(12, 3, 1, 1)
    assert "output width" in engine.strip_ineligible_reason(16, 3, 1, 0)
    assert "output width" in engine.strip_ineligible_reason(8, 3, 2, 1)
    assert "output channels" in engine.strip_ineligible_reason(8, 3, 1, 1,
                                                               co=2)
    assert "output channels" in engine.strip_ineligible_reason(16, 3, 2, 1,
                                                               co=12)
    assert "padding" in engine.strip_ineligible_reason(8, 3, 1, 5)


def test_strip_ineligible_reason_message_table():
    """Regression-pin the exact rule strings: `for_conv(strips=True)` embeds
    them in its ValueError and callers grep them in CI logs — the stride
    rule used to claim `stride != 1` even after stride 2 joined the plan
    (and `{1, 2}` after stride 4 did), so each message is pinned verbatim
    here and the stride set is derived from STRIP_STRIDES, never
    hardcoded."""
    r = engine.strip_ineligible_reason
    assert r(16, 3, 3, 1) == (
        f"stride 3 not in {set(ev.STRIP_STRIDES)} (strip plans gather up "
        f"to (7*stride + 7)//8 + 1 interleaved straddle parts per tap; "
        f"only these strides are validated bitwise)")
    assert str(set(ev.STRIP_STRIDES)) == "{1, 2, 4}"   # pins the verbatim text
    assert r(12, 3, 1, 1) == "input width 12 not a multiple of STRIP_W=8"
    assert r(16, 3, 1, 0) == (
        "output width 14 ((W + 2p - k)//stride + 1) not a multiple of "
        "STRIP_W=8")
    assert r(8, 3, 2, 1) == (
        "output width 4 ((W + 2p - k)//stride + 1) not a multiple of "
        "STRIP_W=8")
    assert r(8, 1, 2, 4) == (
        "padding 4 > k//2 = 0: the output map outgrows the input and a tap "
        "shift can index outside the planned straddle parts (strip plans "
        "pair each output strip with its aligned input strips)")
    assert r(24, 19, 1, 9) == (
        "tap x-offsets [-9, 9] leave the adjacent-strip window "
        "(|dx - p| <= 8)")
    assert r(8, 3, 1, 1, co=12) == (
        "output channels 12 not a multiple of STRIP_CO_MIN=8 (bitwise "
        "contract needs an M-invariant dot lowering — ragged lane "
        "remainders break it)")
    # every rule string above is the exact text for_conv(strips=True) raises
    with pytest.raises(ValueError, match="not in \\{1, 2, 4\\}"):
        engine.EngineConfig().for_conv(8, width=16, k=3, stride=3,
                                       padding=1, strips=True)


def test_strip_rejects_padding_beyond_half_window():
    """padding > k//2 grows the output map beyond the input, so a tap shift
    can index outside the planned straddle halves: such geometry must be
    ineligible (named rule), for_conv(strips=True) must raise, and a strip
    stream hitting it must take the visible decode fallback — never the
    fused plan."""
    # (k, p) pairs that pass every *other* rule (out_w % 8 == 0 at W = 8)
    for k, p in ((1, 4), (1, 8), (3, 5), (9, 8)):
        reason = engine.strip_ineligible_reason(8, k, 1, p, co=8)
        assert reason is not None and "padding" in reason, (k, p, reason)
        assert not engine.strip_eligible(8, k, 1, p, co=8)
        with pytest.raises(ValueError, match="padding"):
            engine.EngineConfig().for_conv(8, width=8, k=k, stride=1,
                                           padding=p, strips=True)
    # boundary: padding == k//2 stays eligible (the real-net "same" conv)
    assert engine.strip_eligible(8, 9, 1, 4, co=8)
    # behavior: the stream degrades visibly and stays correct
    x = _fired(11, (1, 6, 8, 4))
    r = np.random.default_rng(11)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)  # twin: free decode
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, padding=5)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    ref = dense_conv2d(x, wgt, stride=1, padding=5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


def test_tiny_co_strip_stream_falls_back_visibly():
    """A strip stream fed to a conv with CO < STRIP_CO_MIN must take the
    visible decode fallback (the bitwise contract does not hold there)."""
    x = _fired(8, (1, 6, 8, 4))
    r = np.random.default_rng(8)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 2)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, padding=1)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    ref = dense_conv2d(x, wgt, stride=1, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


def test_for_conv_strip_selection():
    cfg = engine.EngineConfig(blk_k=128)
    assert cfg.for_conv(3).blk_k == 3                  # legacy clamp intact
    assert cfg.for_conv(16, width=16, k=3, stride=1, padding=1).blk_m \
        == engine.STRIP_W
    # stride-2/4 downsampling convs resolve to strips too (DESIGN.md §6)
    assert cfg.for_conv(16, width=16, k=3, stride=2, padding=1).blk_m \
        == engine.STRIP_W
    assert cfg.for_conv(16, width=32, k=3, stride=4, padding=1).blk_m \
        == engine.STRIP_W
    # auto mode silently (and correctly) degrades to pixel granularity
    assert cfg.for_conv(16, width=12, k=3, stride=1, padding=1).blk_m == 1
    assert cfg.for_conv(16, width=8, k=3, stride=2, padding=1).blk_m == 1
    assert cfg.for_conv(16, width=16, k=3, stride=4, padding=1).blk_m == 1
    assert cfg.for_conv(16, width=24, k=3, stride=3, padding=1).blk_m == 1
    # strips=False forces pixels even on eligible geometry
    assert cfg.for_conv(16, width=16, k=3, stride=1, padding=1,
                        strips=False).blk_m == 1


def test_for_conv_rejects_degrading_strip_request():
    """strips=True on geometry that would silently fall back to pixel
    granularity must raise with the failing rule, not degrade."""
    cfg = engine.EngineConfig()
    with pytest.raises(ValueError, match="stride"):
        cfg.for_conv(16, width=24, k=3, stride=3, padding=1, strips=True)
    with pytest.raises(ValueError, match="output width"):
        cfg.for_conv(16, width=16, k=3, stride=4, padding=1, strips=True)
    with pytest.raises(ValueError, match="not a multiple"):
        cfg.for_conv(16, width=12, k=3, stride=1, padding=1, strips=True)
    with pytest.raises(ValueError, match="output width"):
        cfg.for_conv(16, width=16, k=3, stride=1, padding=0, strips=True)
    with pytest.raises(ValueError, match="output width"):
        cfg.for_conv(16, width=8, k=3, stride=2, padding=1, strips=True)
    with pytest.raises(ValueError, match="width= and k="):
        cfg.for_conv(16, strips=True)
    # eligible geometry passes and picks strips — every validated stride
    assert cfg.for_conv(16, width=16, k=3, stride=1, padding=1,
                        strips=True).blk_m == engine.STRIP_W
    assert cfg.for_conv(16, width=16, k=3, stride=2, padding=1,
                        strips=True).blk_m == engine.STRIP_W
    assert cfg.for_conv(16, width=32, k=3, stride=4, padding=1,
                        strips=True).blk_m == engine.STRIP_W


# ---------------------------------------------------------------------------
# fallback boundaries: W % 8 != 0, misaligned downsampled width, stride 4 —
# visible, never silent
# ---------------------------------------------------------------------------

def test_strip_stream_stride2_misaligned_out_falls_back_visibly():
    """Stride 2 itself is strip-eligible now, but a downsampled output
    width that doesn't tile strips (here 8 -> 4) must still take the
    visible decode fallback."""
    x = _fired(3, (1, 6, 8, 4))
    r = np.random.default_rng(3)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 5)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)   # twin kept: free decode
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, stride=2, padding=1)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    ref = dense_conv2d(x, wgt, stride=2, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


def test_strip_stream_stride3_falls_back_visibly():
    """Strides beyond STRIP_STRIDES (3: unvalidated) stay a named-rule
    fallback even on geometry whose widths would tile (W=24 -> OW=8)."""
    x = _fired(13, (1, 9, 24, 4))
    r = np.random.default_rng(13)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, stride=3, padding=1)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    ref = dense_conv2d(x, wgt, stride=3, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


def test_strip_stream_stride4_misaligned_out_falls_back_visibly():
    """Stride 4 is strip-eligible now, but a downsampled output width that
    doesn't tile strips (here 16 -> 4) must still take the visible decode
    fallback."""
    x = _fired(13, (1, 9, 16, 4))
    r = np.random.default_rng(13)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, stride=4, padding=1)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    ref = dense_conv2d(x, wgt, stride=4, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# degenerate stride-2 geometries: short-circuit or fall back visibly, never
# crash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["block", "pallas"])
def test_stride2_zero_event_stream(backend):
    """An all-dead feature map rides the fused stride-2 path with zero live
    events: every subtap idles and the result is exactly the bias plane."""
    x = jnp.zeros((1, 8, 16, 4), jnp.float32)
    r = np.random.default_rng(21)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 8)).astype(np.float32))
    bias = jnp.asarray(r.normal(size=(8,)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=4, blk_n=4)
    strip = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=False)
    assert int(strip.num_events) == 0
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(strip, wgt, bias, cfg=cfg, stride=2, padding=1)
    assert any(rec.get("strip") and rec.get("chained") for rec in recs), recs
    assert not any(rec.get("fallback_decode") for rec in recs)
    want = jnp.broadcast_to(bias, (1, 4, 8, 8))
    assert bool(jnp.all(y == want))


@pytest.mark.parametrize("backend", ["block", "pallas"])
def test_stride4_zero_event_stream(backend):
    """An all-dead feature map rides the fused stride-4 path with zero live
    events: every compacted subtap idles and the result is exactly the
    bias plane."""
    x = jnp.zeros((1, 8, 32, 4), jnp.float32)
    r = np.random.default_rng(25)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 8)).astype(np.float32))
    bias = jnp.asarray(r.normal(size=(8,)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=4, blk_n=4)
    strip = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=False)
    assert int(strip.num_events) == 0
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(strip, wgt, bias, cfg=cfg, stride=4, padding=1)
    assert any(rec.get("strip") and rec.get("chained") for rec in recs), recs
    assert not any(rec.get("fallback_decode") for rec in recs)
    want = jnp.broadcast_to(bias, (1, 2, 8, 8))
    assert bool(jnp.all(y == want))


def test_stride4_odd_downsampled_width_falls_back_visibly():
    """(24 - 3)//4 + 1 = 6: W misaligned after stride-4 downsampling cannot
    tile strips — named output-width rule, visible decode, correct
    result."""
    reason = engine.strip_ineligible_reason(24, 3, 4, 0)
    assert reason is not None and "output width 6" in reason
    x = _fired(26, (1, 7, 24, 4))
    r = np.random.default_rng(26)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, stride=4, padding=0)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(dense_conv2d(x, wgt, stride=4)),
                               atol=2e-4, rtol=2e-4)


def test_stride2_empty_batch_short_circuits():
    """B == 0 never reaches a backend (Pallas must not see 0-extent
    launches): exact empty output with the stride-aware out shape."""
    stream = engine.EventStream.empty(
        (0, 4), blk_m=engine.STRIP_W, blk_k=4,
        logical_shape=(0, 8, 16, 4))
    wgt = jnp.ones((3, 3, 4, 8), jnp.float32)
    cfg = engine.EngineConfig(backend="pallas", blk_k=4)
    y = engine.conv2d(stream, wgt, cfg=cfg, stride=2, padding=1)
    assert y.shape == (0, 4, 8, 8)


def test_stride2_odd_downsampled_width_falls_back_visibly():
    """(16 - 3)//2 + 1 = 7: W odd after downsampling cannot tile strips —
    named output-width rule, visible decode, correct result."""
    reason = engine.strip_ineligible_reason(16, 3, 2, 0)
    assert reason is not None and "output width 7" in reason
    x = _fired(22, (1, 7, 16, 4))
    r = np.random.default_rng(22)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, stride=2, padding=0)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(dense_conv2d(x, wgt, stride=2)),
                               atol=2e-4, rtol=2e-4)


def test_stride2_padding_beyond_half_window_falls_back_visibly():
    """p > k//2 at stride 2 (geometry passing every other rule): named
    padding rule, for_conv(strips=True) raises, stream decodes visibly."""
    # W=8, k=1, p=4, s=2: out_w = (8 + 8 - 1)//2 + 1 = 8 — only the
    # padding rule rejects it.
    reason = engine.strip_ineligible_reason(8, 1, 2, 4, co=8)
    assert reason is not None and "padding" in reason
    with pytest.raises(ValueError, match="padding"):
        engine.EngineConfig().for_conv(4, width=8, k=1, stride=2, padding=4,
                                       strips=True)
    x = _fired(23, (1, 6, 8, 4))
    r = np.random.default_rng(23)
    wgt = jnp.asarray(r.normal(size=(1, 1, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, stride=2, padding=4)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(dense_conv2d(x, wgt, stride=2, padding=4)),
        atol=2e-4, rtol=2e-4)
    # boundary: padding == k//2 stays eligible at stride 2
    assert engine.strip_eligible(16, 3, 2, 1, co=8)


def test_stride2_1x1_projection_misaligned_falls_back_visibly():
    """1x1/stride-2 projection over W=8 downsamples to 4 — short-circuits
    to the visible decode; the W=16 twin rides the fused path (ELIGIBLE)."""
    x = _fired(24, (1, 6, 8, 4))
    r = np.random.default_rng(24)
    wgt = jnp.asarray(r.normal(size=(1, 1, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, stride=2, padding=0)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(dense_conv2d(x, wgt, stride=2)),
        atol=2e-4, rtol=2e-4)


def test_fire_conv_strip_requires_aligned_width():
    x = _fired(4, (1, 4, 12, 3))
    with pytest.raises(AssertionError):
        engine.fire_conv(x, engine.EngineConfig(), blk_m=engine.STRIP_W)
    with pytest.raises(AssertionError):
        engine.EventStream.encode_nhwc(x, blk_k=3, blk_m=engine.STRIP_W)


def test_conv_downsampled_structure():
    """Pools become stride-2, channel-preserving conv blocks; everything
    else (and the FC head sizing, via _trace_shapes) is untouched."""
    spec = conv_downsampled(VGG16)
    assert spec.name == "vgg16_ds"
    assert not any(isinstance(l, PoolSpec) for l in spec.layers)
    ds = [l for l in spec.layers
          if isinstance(l, ConvSpec) and l.stride == 2]
    assert [d.out_ch for d in ds] == [64, 128, 256, 512, 512]
    assert all(d.k == 3 and d.padding == 1 for d in ds)


def test_downsampling_mini_net_fuses_stride2_layer():
    """conv -> stride-2 conv -> conv: the middle layer consumes its
    producer's strip stream on the fused stride-2 path (no fallback), and
    the chained forward stays bit-identical to the round-trip twin."""
    spec = CNNSpec("mini_ds", 16, 3,
                   (ConvSpec(8, 3, 1, 1),     # W 16 -> 16, strip producer
                    ConvSpec(8, 3, 2, 1),     # W 16 -> 8: fused stride-2
                    ConvSpec(8, 3, 1, 1),     # W 8 -> 8: fused stride-1
                    FCSpec(10)), num_classes=10)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 16, 16, 3)))
    with engine.trace_dispatch() as recs:
        ym = cnn_forward(params, x, spec, mnf=True, chain=True)
    s2 = [rec for rec in recs if rec.get("strip") and rec.get("chained")
          and rec.get("stride") == 2]
    s1 = [rec for rec in recs if rec.get("strip") and rec.get("chained")
          and rec.get("stride") == 1]
    # conv1 strip-encodes the dense image (input_encode), so both stride-1
    # layers fuse alongside the stride-2 one
    assert len(s2) == 1 and len(s1) == 2, recs
    assert not any(rec.get("fallback_decode") for rec in recs)
    yr = cnn_forward(params, x, spec, mnf=True, chain=False)
    assert bool(jnp.all(ym == yr)), "chained != round-trip with stride-2 strip"
    yd = cnn_forward(params, x, spec, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


def test_ds_workloads_report_ten_fused_launches():
    """The paper workloads' conv-downsampled variants (pools -> stride-2
    conv blocks) keep >= 10 conv layers total on the fused strip path at
    the CPU harness sizes, with zero densify points on the chain — traced
    structurally (eval_shape: no numeric work)."""
    total_fused = 0
    for spec, size in ((VGG16_DS, 32), (ALEXNET_DS, 68)):
        spec = spec.scaled(size)
        assert not any(isinstance(l, PoolSpec) for l in spec.layers)
        params = jax.eval_shape(lambda k, s=spec: init_cnn_params(k, s), KEY)
        x = jax.ShapeDtypeStruct((1, size, size, spec.in_ch), jnp.float32)
        with engine.trace_dispatch() as recs:
            jax.eval_shape(lambda p, xx: cnn_forward(p, xx, spec, mnf=True,
                                                     chain=True), params, x)
        fused = [r for r in recs if r.get("strip") and r.get("chained")
                 and r.get("launches") == 1]
        assert not any(r.get("fallback_decode") or r.get("decode")
                       for r in recs), (spec.name, recs)
        if spec.name.startswith("vgg"):
            assert sum(1 for r in fused if r.get("stride") == 2) == 2
        total_fused += len(fused)
    assert total_fused >= 10, total_fused


def test_first_conv_input_encode_fuses_stride4_net_bitwise():
    """MINI_S4@32 (conv -> stride-4 conv -> conv): the chain strip-encodes
    the dense input image, so *every* conv — including the head — runs one
    fused launch (zero pixel-granular layers, no fallback), and the
    chained forward stays bit-identical to the per-tap round-trip twin."""
    spec = MINI_S4
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 32, 32, 3)))
    with engine.trace_dispatch() as recs:
        ym = cnn_forward(params, x, spec, mnf=True, chain=True)
    strips = [rec for rec in recs if rec.get("strip") and rec.get("chained")]
    pertap = [rec for rec in recs if rec.get("chained")
              and rec["op"] == "conv2d" and not rec.get("strip")]
    assert len(strips) == 3 and not pertap, recs
    assert all(rec.get("launches") == 1 for rec in strips)
    s4 = [rec for rec in strips if rec.get("stride") == 4]
    assert len(s4) == 1, recs
    assert (s4[0]["subtaps"], s4[0]["subtaps_worst"]) == (39, 45)
    assert not any(rec.get("fallback_decode") for rec in recs)
    yr = cnn_forward(params, x, spec, mnf=True, chain=False)
    assert bool(jnp.all(ym == yr)), "chained != round-trip with stride-4 head"
    yd = cnn_forward(params, x, spec, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


def test_alexnet_ff_fully_fused_structurally():
    """ALEXNET_FF@256: the fully-fused AlexNet variant — all 8 convs
    (stride-4 k=11 head included, strip-encoded straight off the dense
    image) run 1 launch each on the chain, zero pixel-granular conv
    layers, zero fallbacks; conv1 reports its compacted 561/605 subtap
    plan (121 -> 1 launches).  Traced structurally (eval_shape: no
    numeric work)."""
    spec = ALEXNET_FF
    params = jax.eval_shape(lambda k: init_cnn_params(k, spec), KEY)
    x = jax.ShapeDtypeStruct((1, 256, 256, 3), jnp.float32)
    with engine.trace_dispatch() as recs:
        jax.eval_shape(lambda p, xx: cnn_forward(p, xx, spec, mnf=True,
                                                 chain=True), params, x)
    conv = [r for r in recs if r.get("op") == "conv2d" and r.get("chained")]
    strips = [r for r in conv if r.get("strip")]
    assert len(strips) == 8 and len(conv) == 8, recs
    assert all(r.get("launches") == 1 for r in strips)
    assert not any(r.get("fallback_decode") or r.get("decode")
                   for r in recs), recs
    head = [r for r in strips if r.get("stride") == 4]
    assert len(head) == 1, recs
    assert (head[0]["subtaps"], head[0]["subtaps_worst"]) == (561, 605)
    # compacted inner grid <= k^2 + live straddle parts beyond one per tap
    for r in strips:
        assert r["subtaps"] <= r["subtaps_worst"]
        assert r["compaction"] <= 1.0


@pytest.mark.slow
def test_alexnet_ff_chained_bitwise():
    """Whole-net ALEXNET_FF@256 numerics: the fully-fused chain (stride-4
    k=11 head on the compacted 5-part straddle plan) is bit-identical to
    the per-tap round-trip twin."""
    spec = ALEXNET_FF
    params = init_cnn_params(KEY, spec, weight_sparsity=0.8)
    x = jax.nn.relu(jax.random.normal(KEY, (1, 256, 256, 3)))
    ym = cnn_forward(params, x, spec, mnf=True, chain=True)
    yr = cnn_forward(params, x, spec, mnf=True, chain=False)
    assert bool(jnp.all(ym == yr))


@pytest.mark.slow
def test_vgg16_ds_chained_bitwise():
    """Whole-net VGG16_DS@32: every downsampling conv on the chain, chained
    == round-trip bit-for-bit across the stride-2 strip launches."""
    spec = VGG16_DS.scaled(32)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 32, 32, 3)))
    ym = cnn_forward(params, x, spec, mnf=True, chain=True)
    yr = cnn_forward(params, x, spec, mnf=True, chain=False)
    assert bool(jnp.all(ym == yr))


@pytest.mark.slow
def test_mixed_strip_pixel_network_bitwise():
    """Widths crossing the 8-boundary: strip and pixel conv layers mix on
    the chain, and the chained forward stays bit-identical to the per-tap
    round-trip twin across the fallback boundary."""
    spec = CNNSpec("edge", 12, 3,
                   (ConvSpec(8, 3, 1, 1),     # W 12 -> 12: ineligible (W%8)
                    ConvSpec(8, 5, 1, 0),     # W 12 -> 8: ineligible input
                    ConvSpec(8, 3, 1, 1),     # W 8 -> 8: strip-eligible
                    ConvSpec(8, 3, 1, 1),     # W 8 -> 8: strip-eligible
                    FCSpec(10)), num_classes=10)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 12, 12, 3)))
    with engine.trace_dispatch() as recs:
        ym = cnn_forward(params, x, spec, mnf=True, chain=True)
    strips = [rec for rec in recs if rec.get("strip") and rec.get("chained")]
    pertap = [rec for rec in recs if rec.get("chained")
              and rec["op"] == "conv2d" and not rec.get("strip")]
    assert len(strips) == 2 and len(pertap) == 1, recs
    assert not any(rec.get("fallback_decode") for rec in recs)
    yr = cnn_forward(params, x, spec, mnf=True, chain=False)
    assert bool(jnp.all(ym == yr)), "chained != round-trip across boundary"
    yd = cnn_forward(params, x, spec, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


# ---------------------------------------------------------------------------
# strip encoding / gather primitives
# ---------------------------------------------------------------------------

def test_strip_encode_nhwc_roundtrip_and_grid():
    x = _fired(5, (2, 3, 16, 5))
    s = engine.EventStream.encode_nhwc(x, blk_k=4, blk_m=engine.STRIP_W,
                                       keep_dense=False)
    assert s.blk_m == engine.STRIP_W
    np.testing.assert_array_equal(np.asarray(s.dense_nhwc()), np.asarray(x))
    p = engine.EventStream.encode_nhwc(x, blk_k=4, blk_m=1, keep_dense=False)
    assert s.events.block_idx.shape[0] * engine.STRIP_W \
        == p.events.block_idx.shape[0]


def test_gather_row_strips_moves_rows_exactly():
    x = _fired(6, (1, 2, 16, 4), sparsity=0.3)
    s = engine.EventStream.encode_nhwc(x, blk_k=4, blk_m=engine.STRIP_W,
                                       keep_dense=False)
    g = s.events.block_idx.shape[0]
    idx = jnp.arange(g, dtype=jnp.int32)
    live = jnp.ones((g,), bool)
    for d in (-3, 0, 2, 5):
        gat = ev.gather_row_strips(s.events, idx, live, d)
        dec = ev.decode_block_events(gat, blk_m=engine.STRIP_W, blk_k=4,
                                     m=g * engine.STRIP_W, k=4)
        flat = np.asarray(x).reshape(-1, 4)
        want = np.zeros_like(flat)
        for strip in range(g):
            for i in range(engine.STRIP_W):
                jsrc = i + d
                if 0 <= jsrc < engine.STRIP_W:
                    want[strip * 8 + i] = flat[strip * 8 + jsrc]
        np.testing.assert_array_equal(np.asarray(dec), want)


def test_scalar_event_rows_twin_free_counts():
    x = _fired(7, (2, 3, 8, 5))
    s = engine.fire_conv(x, engine.EngineConfig(backend="block", blk_k=4),
                         blk_m=engine.STRIP_W, keep_dense=False)
    want = np.sum(np.abs(np.asarray(x)) > 0, axis=-1).reshape(-1)
    np.testing.assert_array_equal(np.asarray(s.per_row_scalar_events()),
                                  want.astype(np.float32))
    assert float(s.num_scalar_events) == float(want.sum())


def test_fused_conv_plan_grid_reduction():
    plan = fused_conv_plan((2, 8, 16, 8), 3, 1, nkb=2)
    assert plan["launches_fused"] == 1 and plan["launches_per_tap"] == 9
    assert plan["event_grid_pixel"] == 8 * plan["event_grid_strip"]
    assert plan["grid_reduction"] == 8.0
    assert plan["gathered_groups_fused"] == 0
    # the inner grid axis is sized by the *compacted* subtap count
    assert (plan["subtaps"], plan["subtaps_worst"]) == (15, 18)
    assert plan["grid_fused"][1] == plan["subtaps"]
    assert plan["compaction"] == 15 / 18
    plan4 = fused_conv_plan((1, 11, 32, 3), 11, 4, nkb=1, stride=4)
    assert (plan4["subtaps"], plan4["subtaps_worst"]) == (561, 605)
    assert plan4["grid_fused"][1] == 561


def test_remap_select_ladder_bitwise_equals_matmul():
    """The two in-tile row-remap lowerings of the fused kernel — the 0/1
    selection matmul (default, MXU) and the vselect ladder
    (remap="select", VPU) — move rows identically, bit for bit, at every
    validated stride.  The DESIGN.md §6 Mosaic cost verdict rests on this
    equivalence."""
    from repro.kernels.event_conv import fused_event_conv2d
    for shape in ((2, 6, 8, 5, 8, 3, 1, 1), (1, 8, 16, 5, 8, 5, 2, 2),
                  (1, 11, 32, 3, 8, 11, 4, 4)):
        b, h, w0, ci, co, k, p, s = shape
        x = _fired(sum(shape), (b, h, w0, ci))
        r = np.random.default_rng(2)
        wgt = jnp.asarray(r.normal(size=(k, k, ci, co)).astype(np.float32))
        cfg = engine.EngineConfig(backend="pallas", blk_k=4)
        stream = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W,
                                  keep_dense=False)
        ym = fused_event_conv2d(stream, wgt, stride=s, padding=p, blk_n=8,
                                interpret=True, remap="matmul")
        ys = fused_event_conv2d(stream, wgt, stride=s, padding=p, blk_n=8,
                                interpret=True, remap="select")
        assert bool(jnp.all(ym == ys)), shape


# ---------------------------------------------------------------------------
# dead-subtap compaction: plan columns == live subtaps, no dead column
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,p,s,want", [
    (3, 1, 1, (15, 18)),    # stride 1: r==0 taps lose their second half
    (5, 2, 2, (65, 75)),    # stride 2: r<2 taps lose their third part
    (3, 1, 4, (39, 45)),    # stride 4 ds block
    (11, 4, 4, (561, 605)),  # AlexNet conv1 class
    (1, 0, 2, (2, 3)),      # 1x1 projection
    (9, 4, 1, (153, 162)),  # widest stride-1 filter
])
def test_strip_subtap_counts_pinned(k, p, s, want):
    assert ev.strip_subtap_counts(k, p, s) == want
    live, worst = want
    assert worst == ev.strip_parts(s) * k * k
    assert live <= worst


@pytest.mark.parametrize("k,p,s,w", [
    (3, 1, 1, 16), (5, 2, 2, 16), (3, 1, 4, 32), (11, 4, 4, 32),
    (1, 0, 2, 16), (9, 4, 2, 16),
])
def test_strip_tap_map_compacted_no_dead_columns(k, p, s, w):
    """Every plan column sources at least one output row (strip_shift_live)
    and the column count equals strip_subtap_counts — dead straddle parts
    are dropped at plan time, not masked at run time."""
    shape = (1, 8, w, 4)
    src, live, shift, tap = ev.strip_tap_map(shape, k, p, s)
    t = src.shape[1]
    assert t == ev.strip_subtap_counts(k, p, s)[0]
    assert shift.shape == (t,) and tap.shape == (t,)
    for d in shift:
        assert ev.strip_shift_live(int(d), s), (int(d), s)
    # each tap appears with at most strip_parts(s) live parts
    per_tap = Counter(int(x) for x in tap)
    assert max(per_tap.values()) <= ev.strip_parts(s)
    assert set(per_tap) == set(range(k * k))
