"""Strip-tiled fused-tap event conv (DESIGN.md §6).

The fused kernel consumes a strip-aligned (blk_m == STRIP_W) conv stream in
one launch per layer; it must be *bit-identical* to the pixel-granular
per-tap path (the oracle) — strips only interleave exact zeros into the
same reduction tree.  Ineligible geometry (stride != 1, W % 8 != 0, odd
widths, misaligned output width) must degrade visibly, never silently.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import events as ev
from repro.core.mnf_conv import dense_conv2d
from repro.kernels.event_conv import fused_conv_plan
from repro.models.cnn import (CNNSpec, ConvSpec, FCSpec, cnn_forward,
                              init_cnn_params)

KEY = jax.random.PRNGKey(0)


def _fired(seed, shape, sparsity=0.5):
    r = np.random.default_rng(seed)
    x = r.normal(size=shape) * (r.random(shape) > sparsity)
    return jax.nn.relu(jnp.asarray(x.astype(np.float32)))


# ---------------------------------------------------------------------------
# bit-exactness: fused strip path == per-tap pixel path, per backend
# ---------------------------------------------------------------------------

ELIGIBLE = [  # (B, H, W, CI, CO, k, padding) — all strip-eligible at stride 1
    (2, 6, 8, 5, 8, 3, 1),
    (1, 8, 16, 3, 16, 3, 1),
    (2, 5, 8, 4, 16, 5, 2),   # odd height
    (1, 9, 16, 2, 8, 1, 0),   # 1x1 conv
    (1, 4, 16, 3, 8, 9, 4),   # widest eligible filter (max tap shift)
]


@pytest.mark.parametrize("backend", ["block", "pallas"])
@pytest.mark.parametrize("shape", ELIGIBLE)
def test_strip_bitwise_equals_pertap_and_oracle(backend, shape):
    b, h, w0, ci, co, k, p = shape
    x = _fired(sum(shape), (b, h, w0, ci))
    r = np.random.default_rng(1)
    wgt = jnp.asarray(r.normal(size=(k, k, ci, co)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=4, blk_n=4)
    strip = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W, keep_dense=False)
    pixel = engine.fire_conv(x, cfg, blk_m=1, keep_dense=False)
    assert strip.events.block_idx.shape[0] * engine.STRIP_W \
        == pixel.events.block_idx.shape[0]          # 8x smaller event grid
    with engine.trace_dispatch() as recs:
        y_strip = engine.conv2d(strip, wgt, cfg=cfg, padding=p)
    assert any(rec.get("strip") and rec.get("chained")
               and rec.get("launches") == 1 for rec in recs), recs
    assert not any(rec.get("decode") or rec.get("fallback_decode")
                   for rec in recs)
    y_pix = engine.conv2d(pixel, wgt, cfg=cfg, padding=p)
    assert bool(jnp.all(y_strip == y_pix)), "fused strip != per-tap bitwise"
    ref = dense_conv2d(x, wgt, stride=1, padding=p)
    np.testing.assert_allclose(np.asarray(y_strip), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# eligibility rules + EngineConfig.for_conv strip selection/validation
# ---------------------------------------------------------------------------

def test_strip_eligibility_rules():
    assert engine.strip_eligible(8, 3, 1, 1)
    assert engine.strip_eligible(16, 9, 1, 4)          # OX == W
    assert not engine.strip_eligible(8, 3, 2, 1)       # stride 2
    assert not engine.strip_eligible(12, 3, 1, 1)      # W % 8 != 0
    assert not engine.strip_eligible(7, 3, 1, 1)       # odd width
    assert not engine.strip_eligible(16, 3, 1, 0)      # OX = 14, misaligned
    # ragged/tiny CO voids the bitwise contract (M-dependent dot lowering)
    assert engine.strip_eligible(8, 3, 1, 1, co=engine.STRIP_CO_MIN)
    assert engine.strip_eligible(8, 3, 1, 1, co=64)
    assert not engine.strip_eligible(8, 3, 1, 1, co=2)
    assert not engine.strip_eligible(8, 3, 1, 1, co=9)
    assert not engine.strip_eligible(8, 3, 1, 1, co=12)
    assert "stride" in engine.strip_ineligible_reason(8, 3, 2, 1)
    assert "width 12" in engine.strip_ineligible_reason(12, 3, 1, 1)
    assert "output width" in engine.strip_ineligible_reason(16, 3, 1, 0)
    assert "output channels" in engine.strip_ineligible_reason(8, 3, 1, 1,
                                                               co=2)
    assert "padding" in engine.strip_ineligible_reason(8, 3, 1, 5)


def test_strip_rejects_padding_beyond_half_window():
    """padding > k//2 grows the output map beyond the input, so a tap shift
    can index outside the planned straddle halves: such geometry must be
    ineligible (named rule), for_conv(strips=True) must raise, and a strip
    stream hitting it must take the visible decode fallback — never the
    fused plan."""
    # (k, p) pairs that pass every *other* rule (out_w % 8 == 0 at W = 8)
    for k, p in ((1, 4), (1, 8), (3, 5), (9, 8)):
        reason = engine.strip_ineligible_reason(8, k, 1, p, co=8)
        assert reason is not None and "padding" in reason, (k, p, reason)
        assert not engine.strip_eligible(8, k, 1, p, co=8)
        with pytest.raises(ValueError, match="padding"):
            engine.EngineConfig().for_conv(8, width=8, k=k, stride=1,
                                           padding=p, strips=True)
    # boundary: padding == k//2 stays eligible (the real-net "same" conv)
    assert engine.strip_eligible(8, 9, 1, 4, co=8)
    # behavior: the stream degrades visibly and stays correct
    x = _fired(11, (1, 6, 8, 4))
    r = np.random.default_rng(11)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)  # twin: free decode
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, padding=5)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    ref = dense_conv2d(x, wgt, stride=1, padding=5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


def test_tiny_co_strip_stream_falls_back_visibly():
    """A strip stream fed to a conv with CO < STRIP_CO_MIN must take the
    visible decode fallback (the bitwise contract does not hold there)."""
    x = _fired(8, (1, 6, 8, 4))
    r = np.random.default_rng(8)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 2)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, padding=1)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    ref = dense_conv2d(x, wgt, stride=1, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


def test_for_conv_strip_selection():
    cfg = engine.EngineConfig(blk_k=128)
    assert cfg.for_conv(3).blk_k == 3                  # legacy clamp intact
    assert cfg.for_conv(16, width=16, k=3, stride=1, padding=1).blk_m \
        == engine.STRIP_W
    # auto mode silently (and correctly) degrades to pixel granularity
    assert cfg.for_conv(16, width=12, k=3, stride=1, padding=1).blk_m == 1
    assert cfg.for_conv(16, width=16, k=3, stride=2, padding=1).blk_m == 1
    # strips=False forces pixels even on eligible geometry
    assert cfg.for_conv(16, width=16, k=3, stride=1, padding=1,
                        strips=False).blk_m == 1


def test_for_conv_rejects_degrading_strip_request():
    """strips=True on geometry that would silently fall back to pixel
    granularity must raise with the failing rule, not degrade."""
    cfg = engine.EngineConfig()
    with pytest.raises(ValueError, match="stride"):
        cfg.for_conv(16, width=16, k=3, stride=2, padding=1, strips=True)
    with pytest.raises(ValueError, match="not a multiple"):
        cfg.for_conv(16, width=12, k=3, stride=1, padding=1, strips=True)
    with pytest.raises(ValueError, match="output width"):
        cfg.for_conv(16, width=16, k=3, stride=1, padding=0, strips=True)
    with pytest.raises(ValueError, match="width= and k="):
        cfg.for_conv(16, strips=True)
    # eligible geometry passes and picks strips
    assert cfg.for_conv(16, width=16, k=3, stride=1, padding=1,
                        strips=True).blk_m == engine.STRIP_W


# ---------------------------------------------------------------------------
# fallback boundaries: W % 8 != 0, stride 2 — visible, never silent
# ---------------------------------------------------------------------------

def test_strip_stream_stride2_falls_back_visibly():
    x = _fired(3, (1, 6, 8, 4))
    r = np.random.default_rng(3)
    wgt = jnp.asarray(r.normal(size=(3, 3, 4, 5)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=engine.STRIP_W)   # twin kept: free decode
    with engine.trace_dispatch() as recs:
        y = engine.conv2d(s, wgt, cfg=cfg, stride=2, padding=1)
    assert any(rec.get("fallback_decode") and rec.get("strip")
               for rec in recs), recs
    ref = dense_conv2d(x, wgt, stride=2, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)


def test_fire_conv_strip_requires_aligned_width():
    x = _fired(4, (1, 4, 12, 3))
    with pytest.raises(AssertionError):
        engine.fire_conv(x, engine.EngineConfig(), blk_m=engine.STRIP_W)
    with pytest.raises(AssertionError):
        engine.EventStream.encode_nhwc(x, blk_k=3, blk_m=engine.STRIP_W)


@pytest.mark.slow
def test_mixed_strip_pixel_network_bitwise():
    """Widths crossing the 8-boundary: strip and pixel conv layers mix on
    the chain, and the chained forward stays bit-identical to the per-tap
    round-trip twin across the fallback boundary."""
    spec = CNNSpec("edge", 12, 3,
                   (ConvSpec(8, 3, 1, 1),     # W 12 -> 12: ineligible (W%8)
                    ConvSpec(8, 5, 1, 0),     # W 12 -> 8: ineligible input
                    ConvSpec(8, 3, 1, 1),     # W 8 -> 8: strip-eligible
                    ConvSpec(8, 3, 1, 1),     # W 8 -> 8: strip-eligible
                    FCSpec(10)), num_classes=10)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 12, 12, 3)))
    with engine.trace_dispatch() as recs:
        ym = cnn_forward(params, x, spec, mnf=True, chain=True)
    strips = [rec for rec in recs if rec.get("strip") and rec.get("chained")]
    pertap = [rec for rec in recs if rec.get("chained")
              and rec["op"] == "conv2d" and not rec.get("strip")]
    assert len(strips) == 2 and len(pertap) == 1, recs
    assert not any(rec.get("fallback_decode") for rec in recs)
    yr = cnn_forward(params, x, spec, mnf=True, chain=False)
    assert bool(jnp.all(ym == yr)), "chained != round-trip across boundary"
    yd = cnn_forward(params, x, spec, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


# ---------------------------------------------------------------------------
# strip encoding / gather primitives
# ---------------------------------------------------------------------------

def test_strip_encode_nhwc_roundtrip_and_grid():
    x = _fired(5, (2, 3, 16, 5))
    s = engine.EventStream.encode_nhwc(x, blk_k=4, blk_m=engine.STRIP_W,
                                       keep_dense=False)
    assert s.blk_m == engine.STRIP_W
    np.testing.assert_array_equal(np.asarray(s.dense_nhwc()), np.asarray(x))
    p = engine.EventStream.encode_nhwc(x, blk_k=4, blk_m=1, keep_dense=False)
    assert s.events.block_idx.shape[0] * engine.STRIP_W \
        == p.events.block_idx.shape[0]


def test_gather_row_strips_moves_rows_exactly():
    x = _fired(6, (1, 2, 16, 4), sparsity=0.3)
    s = engine.EventStream.encode_nhwc(x, blk_k=4, blk_m=engine.STRIP_W,
                                       keep_dense=False)
    g = s.events.block_idx.shape[0]
    idx = jnp.arange(g, dtype=jnp.int32)
    live = jnp.ones((g,), bool)
    for d in (-3, 0, 2, 5):
        gat = ev.gather_row_strips(s.events, idx, live, d)
        dec = ev.decode_block_events(gat, blk_m=engine.STRIP_W, blk_k=4,
                                     m=g * engine.STRIP_W, k=4)
        flat = np.asarray(x).reshape(-1, 4)
        want = np.zeros_like(flat)
        for strip in range(g):
            for i in range(engine.STRIP_W):
                jsrc = i + d
                if 0 <= jsrc < engine.STRIP_W:
                    want[strip * 8 + i] = flat[strip * 8 + jsrc]
        np.testing.assert_array_equal(np.asarray(dec), want)


def test_scalar_event_rows_twin_free_counts():
    x = _fired(7, (2, 3, 8, 5))
    s = engine.fire_conv(x, engine.EngineConfig(backend="block", blk_k=4),
                         blk_m=engine.STRIP_W, keep_dense=False)
    want = np.sum(np.abs(np.asarray(x)) > 0, axis=-1).reshape(-1)
    np.testing.assert_array_equal(np.asarray(s.per_row_scalar_events()),
                                  want.astype(np.float32))
    assert float(s.num_scalar_events) == float(want.sum())


def test_fused_conv_plan_grid_reduction():
    plan = fused_conv_plan((2, 8, 16, 8), 3, 1, nkb=2)
    assert plan["launches_fused"] == 1 and plan["launches_per_tap"] == 9
    assert plan["event_grid_pixel"] == 8 * plan["event_grid_strip"]
    assert plan["grid_reduction"] == 8.0
    assert plan["gathered_groups_fused"] == 0
