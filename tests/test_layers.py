"""Layer primitives: norms, RoPE, MLP + MNF exactness for ReLU-family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.layers import (activation_fn, apply_rope, embed_apply,
                                 embed_init, layer_norm, mlp_apply, mlp_init,
                                 mnf_sparsify, rms_norm)

KEY = jax.random.PRNGKey(0)


def test_rms_norm_unit_scale(rng):
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)) * 10
    y = rms_norm(x, jnp.zeros(64))
    rms = np.sqrt((np.asarray(y) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_layer_norm_stats(rng):
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)) * 3 + 2
    y = np.asarray(layer_norm(x, jnp.ones(64), jnp.zeros(64)))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-3)
    np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative_phase(rng):
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)).astype(np.float32))
    pos = jnp.arange(8)
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))

    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([i]))
        kj = apply_rope(k, jnp.asarray([j]))
        return float(jnp.sum(qi * kj))

    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)
    np.testing.assert_allclose(dot_at(2, 2), dot_at(6, 6), rtol=1e-4)


def test_mnf_mlp_exact_for_relu2():
    """minitron-style squared-ReLU MLP: MNF enabled == disabled exactly."""
    cfg = get_config("minitron-8b").reduced()
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    cfg_off = dataclasses.replace(
        cfg, mnf=dataclasses.replace(cfg.mnf, enabled=False))
    p, _ = mlp_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    np.testing.assert_allclose(np.asarray(mlp_apply(p, x, cfg)),
                               np.asarray(mlp_apply(p, x, cfg_off)),
                               atol=1e-6)


def test_mnf_threshold_sparsifies():
    cfg = get_config("qwen2-1.5b").reduced()
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32",
        mnf=dataclasses.replace(cfg.mnf, enabled=True, threshold=0.5))
    h = jax.random.normal(KEY, (16, cfg.d_ff), jnp.float32) * 0.3
    out = mnf_sparsify(h, cfg)
    assert (np.asarray(out) == 0).mean() > 0.5
    kept = np.abs(np.asarray(out)) > 0
    np.testing.assert_allclose(np.asarray(out)[kept],
                               np.asarray(h)[kept])


def test_activations():
    x = jnp.asarray([-1.0, 0.5])
    np.testing.assert_allclose(np.asarray(activation_fn("relu")(x)),
                               [0.0, 0.5])
    np.testing.assert_allclose(np.asarray(activation_fn("relu2")(x)),
                               [0.0, 0.25])


def test_embeddings_tied_and_untied(rng):
    for arch in ("qwen2-0.5b", "qwen2-1.5b"):
        cfg = get_config(arch).reduced()
        p, _ = embed_init(KEY, cfg)
        toks = jnp.asarray([[1, 2], [3, 4]])
        e = embed_apply(p, toks, cfg)
        assert e.shape == (2, 2, cfg.d_model)
        assert ("unembed" in p) == (not cfg.tie_embeddings)
