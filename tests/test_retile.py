"""Conv→FC re-tiler exactness contract (DESIGN.md §12).

The re-tile is pure address arithmetic: for an eligible conv stream it must
equal *encoding the flattened dense twin* at the FC geometry — array for
array (values, block_idx, counts), not merely after a decode.  Pinned here
for pixel- and strip-granular streams, f32 and int8 event values (values
travel by gather only, so the contract is dtype-blind), and zero-event
streams.  Ineligible geometry is a *named* refusal: the three
``retile_ineligible_reason`` messages are pinned verbatim, and the engine's
``linear`` must surface the same string on its visible dense fallback.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core.events import (STRIP_W, encode_block_events,
                               retile_block_events,
                               retile_ineligible_reason)
from repro.core.quantize import calibrate, quantize
from repro.engine import EventStream


def _nhwc(seed: int, shape, sparsity=0.5) -> jax.Array:
    r = np.random.default_rng(seed)
    x = r.normal(size=shape) * (r.random(shape) > sparsity)
    return jnp.asarray(x.astype(np.float32))


def _assert_same_events(got, want):
    assert got.num_k_blocks == want.num_k_blocks
    for name in ("values", "block_idx", "counts"):
        g, w = getattr(got, name), getattr(want, name)
        assert g.shape == w.shape and g.dtype == w.dtype, \
            (name, g.shape, g.dtype, w.shape, w.dtype)
        assert bool(jnp.all(g == w)), name


# ---------------------------------------------------------------------------
# re-tile == encode(flatten), array for array
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("blk_m", [1, STRIP_W])
@pytest.mark.parametrize("shape,blk_k", [
    ((2, 3, 8, 8), 4),
    ((1, 2, 16, 6), 3),      # C not a power of two
    ((1, 1, 8, 4), 4),       # single K-block per pixel
    ((3, 5, 24, 8), 8),      # one K-block == full channel depth
])
@pytest.mark.parametrize("sparsity", [0.0, 0.6, 1.0])
def test_retile_equals_flat_encode(blk_m, shape, blk_k, sparsity):
    b, h, w, c = shape
    x = _nhwc(hash((shape, blk_m, blk_k, sparsity)) % (2 ** 31), shape,
              sparsity)
    s = EventStream.encode_nhwc(x, blk_k=blk_k, blk_m=blk_m)
    rt = s.retile_fc()
    flat = x.reshape(b, h * w * c)
    ref = EventStream.encode(flat, blk_m=1, blk_k=rt.blk_k,
                             capacity=rt.events.capacity)
    _assert_same_events(rt.events, ref.events)
    assert rt.shape == (b, h * w * c) and rt.blk_m == 1
    assert rt.logical_shape is None                 # no longer a conv stream
    assert bool(jnp.all(rt.dense() == flat))        # twin rode along, bitwise


@pytest.mark.parametrize("blk_m", [1, STRIP_W])
def test_retile_int8_values_gather_only(blk_m):
    """int8 codes ride the same address plan untouched — no FP arithmetic
    touches the values, so the re-tiled stream is bitwise the encode of the
    flattened code matrix (and stays int8)."""
    b, h, w, c = 2, 3, 8, 8
    x = _nhwc(7, (b, h, w, c), 0.5)
    qp = calibrate(x)
    q = quantize(x, qp)                              # (B, H, W, C) int8
    a = q.reshape(b * h * w, c)
    bev = encode_block_events(a, blk_m=blk_m, blk_k=4)
    rt = retile_block_events(bev, (b, h, w, c), blk_m)
    ref = encode_block_events(q.reshape(b, h * w * c), blk_m=1, blk_k=4,
                              capacity=rt.capacity)
    assert rt.values.dtype == jnp.int8
    _assert_same_events(rt, ref)


def test_retile_fc_carries_qparams():
    """An int8 conv EventStream re-tiles with its QParams (and the
    dequantized twin) intact — the FC consumer dequantizes at load."""
    b, h, w, c = 1, 2, 8, 8
    x = jax.nn.relu(_nhwc(11, (b, h, w, c), 0.4))
    cfg = engine.EngineConfig(backend="block", blk_k=4, int8_events=True)
    s = engine.fire_conv(x, cfg, blk_m=1)
    assert s.qparams is not None
    rt = s.retile_fc()
    assert rt.qparams is s.qparams
    assert rt.events.values.dtype == jnp.int8
    assert bool(jnp.all(rt.dense() == s.dense().reshape(b, h * w * c)))


# ---------------------------------------------------------------------------
# ineligible geometry: the three named refusals, verbatim
# ---------------------------------------------------------------------------

def test_retile_ineligible_reasons_verbatim():
    assert retile_ineligible_reason((1, 2, 8, 8), 1, 4) is None
    assert retile_ineligible_reason((1, 2, 8, 8), STRIP_W, 4) is None
    assert retile_ineligible_reason(None, 1, 4) == (
        "stream has no NHWC logical shape (not a conv stream; "
        "nothing to re-tile)")
    assert retile_ineligible_reason((1, 2, 8, 6), 1, 4) == (
        "channel depth 6 not a multiple of blk_k=4 (the conv encoding's "
        "K-padding columns would interleave into the flattened FC row)")
    assert retile_ineligible_reason((1, 2, 8, 8), 4, 4) == (
        "row granularity blk_m=4 is neither pixel (1) nor strip "
        "(STRIP_W=8)")


def test_linear_ineligible_conv_stream_reports_named_reason():
    """A conv stream whose geometry cannot re-tile decodes *visibly*: the
    dispatch record is fallback_decode with the verbatim refusal message —
    never a silent densify."""
    b, h, w, c = 1, 2, 8, 6                          # C=6 % blk_k=4 != 0
    x = jax.nn.relu(_nhwc(3, (b, h, w, c), 0.3))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=1)
    wgt = jnp.asarray(np.random.default_rng(0).normal(
        size=(h * w * c, 5)).astype(np.float32))
    with engine.trace_dispatch() as recs:
        y = engine.linear(s, wgt, cfg=cfg)
    rec = next(r for r in recs if r.get("op") == "linear")
    assert rec.get("fallback_decode") and not rec.get("retile")
    assert rec["reason"] == (
        "channel depth 6 not a multiple of blk_k=4 (the conv encoding's "
        "K-padding columns would interleave into the flattened FC row)")
    ref = s.dense_nhwc().reshape(b, h * w * c) @ wgt
    assert bool(jnp.all(y == ref))                   # correct, just visible


def test_linear_eligible_conv_stream_chains_through_retile():
    """The eligible seam never decodes: one chained linear record with
    retile=True, bitwise the flattened dense matmul."""
    b, h, w, c = 2, 3, 8, 8
    x = jax.nn.relu(_nhwc(5, (b, h, w, c), 0.3))
    cfg = engine.EngineConfig(backend="block", blk_k=4)
    s = engine.fire_conv(x, cfg, blk_m=STRIP_W, keep_dense=False)
    wgt = jnp.asarray(np.random.default_rng(1).normal(
        size=(h * w * c, 7)).astype(np.float32))
    with engine.trace_dispatch() as recs:
        y = engine.linear(s, wgt, cfg=cfg)
    rec = next(r for r in recs if r.get("op") == "linear")
    assert rec.get("chained") and rec.get("retile") is True
    assert not any(r.get("fallback_decode") or r.get("decode") for r in recs)
    xd = jax.nn.relu(x).reshape(b, h * w * c)
    assert bool(jnp.all(y == engine.linear(xd, wgt, cfg=cfg)))
