"""Paper workloads (AlexNet/VGG16): MNF inference == dense oracle + stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.costmodel.workloads import analytic_network_stats
from repro.models.cnn import (ALEXNET, VGG16, cnn_forward, init_cnn_params,
                              layer_dense_macs, run_with_stats)

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
@pytest.mark.parametrize("spec,size", [(ALEXNET, 64), (VGG16, 32)])
def test_mnf_equals_dense(rng, spec, size):
    s = spec.scaled(size)
    params = init_cnn_params(KEY, s, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, size, size, s.in_ch)))
    yd = cnn_forward(params, x, s, mnf=False)
    ym = cnn_forward(params, x, s, mnf=True)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


def test_stats_invariants(rng):
    s = VGG16.scaled(32)
    params = init_cnn_params(KEY, s)
    x = jax.nn.relu(jax.random.normal(KEY, (1, 32, 32, 3)))
    _, stats = run_with_stats(params, x, s)
    assert len(stats) == 16                      # 13 convs + 3 FCs
    for st in stats:
        assert st["event_macs"] <= st["dense_macs"] * 1.0001
        assert 0 <= st["in_events"] <= st["in_elems"]
        assert 0.0 <= st["out_density"] <= 1.0


def test_stats_twin_free_parity_with_dense_counts():
    """The instrumented pipeline derives in_events/event_macs from the
    compacted event values (twin-free); they must equal the counts computed
    the old way, from the dense activation maps of the bit-identical
    per-layer round-trip twin."""
    import jax.numpy as jnp

    from repro import engine
    from repro.models.cnn import (CNNSpec, ConvSpec, FCSpec, PoolSpec,
                                  _touched_outputs)
    from repro.models.layers import max_pool_nhwc

    spec = CNNSpec("mini", 12, 3,
                   (ConvSpec(6, 3, 2, 1), ConvSpec(8, 3, 1, 1), PoolSpec(),
                    FCSpec(10)), num_classes=10)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 12, 12, 3)))
    _, stats = run_with_stats(params, x, spec)

    # Dense-twin reference: replicate the round-trip twin's intermediates
    # (bit-identical to the chained path) and count non-zeros directly.
    cfg = engine.EngineConfig(backend="block", blk_m=1, blk_k=8)
    xd, want = x, []
    for layer, wgt in zip(spec.layers, params):
        if isinstance(layer, ConvSpec):
            nz = np.sum(np.abs(np.asarray(xd)) > 0, axis=-1)
            touched = np.asarray(_touched_outputs(
                xd.shape[1], xd.shape[2], layer.k, layer.stride,
                layer.padding))
            want.append(dict(in_events=float(nz.sum()),
                             event_macs=float((nz * touched[None]).sum()
                                              * layer.out_ch)))
            acc = engine.conv2d(xd, wgt, cfg=cfg.for_conv(xd.shape[-1]),
                                stride=layer.stride, padding=layer.padding)
            xd = jnp.where(acc > 0, acc, 0)          # fire @ threshold 0
        elif isinstance(layer, PoolSpec):
            xd = max_pool_nhwc(xd, layer.k, layer.stride)
        else:
            flat = np.asarray(xd).reshape(xd.shape[0], -1)
            nz = float(np.sum(np.abs(flat) > 0))
            want.append(dict(in_events=nz, event_macs=nz * layer.out))
            xd = engine.linear(jnp.asarray(flat),
                               wgt, cfg=cfg)
    assert len(stats) == len(want)
    for got, ref in zip(stats, want):
        assert got["in_events"] == ref["in_events"], (got, ref)
        assert got["event_macs"] == ref["event_macs"], (got, ref)


def test_fc_in_events_respect_fire_threshold():
    """FC-layer ``in_events`` on the dense (round-trip / quantized) path
    must count events at the *configured* fire threshold, like the chained
    stream does — not ``|flat| > 0``, which also counts dequantization
    artifacts below the threshold (regression: chained vs round-trip stats
    diverged for threshold > 0)."""
    from repro.core.fire import FireConfig, fire
    from repro.models.cnn import CNNSpec, ConvSpec, FCSpec, PoolSpec
    from repro.models.layers import max_pool_nhwc
    from repro import engine

    spec = CNNSpec("mini", 12, 3,
                   (ConvSpec(6, 3, 2, 1), ConvSpec(8, 3, 1, 1), PoolSpec(),
                    FCSpec(10), FCSpec(5)), num_classes=5)
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 12, 12, 3)))
    thr = 0.3

    # Chained (threshold > 0, no quantization): FC in_events must equal the
    # supra-threshold fire-decision counts of the round-trip intermediates.
    fc = FireConfig(threshold=thr)
    _, stats = run_with_stats(params, x, spec, fire_cfg=fc)
    cfg = engine.EngineConfig(backend="block", blk_m=1, blk_k=8)
    xd, want = x, []
    for layer, wgt in zip(spec.layers, params):
        if isinstance(layer, ConvSpec):
            acc = engine.conv2d(xd, wgt, cfg=cfg.for_conv(xd.shape[-1]),
                                stride=layer.stride, padding=layer.padding)
            xd = fire(acc, fc)
        elif isinstance(layer, PoolSpec):
            xd = max_pool_nhwc(xd, layer.k, layer.stride)
        else:
            flat = np.asarray(xd).reshape(xd.shape[0], -1)
            want.append(float(np.sum(np.abs(flat) > thr)))
            acc = engine.linear(jnp.asarray(flat), wgt, cfg=cfg)
            xd = fire(acc, fc) if layer is not spec.layers[-1] else acc
    got = [s["in_events"] for s in stats if s["kind"] == "fc"]
    assert got == want, (got, want)

    # Deterministic regression: an FC fed a dense input with non-zero
    # values at or below the threshold (they are not events — the fire
    # decision at the configured threshold would not emit them).  The old
    # |flat| > 0 count included them and diverged from the chained path.
    fcspec = CNNSpec("fcnet", 1, 8, (FCSpec(4), FCSpec(3)), num_classes=3)
    fparams = init_cnn_params(KEY, fcspec)
    xf = jnp.asarray([[0.1, 0.29, 0.31, 2.0, 0.0, 0.0, 1.0, 0.2],
                      [0.0, 0.30, 0.50, 0.0, 0.1, 0.0, 0.0, 0.0]],
                     jnp.float32).reshape(2, 1, 1, 8)
    _, fstats = run_with_stats(fparams, xf, fcspec,
                               fire_cfg=FireConfig(threshold=thr))
    supra = float(np.sum(np.abs(np.asarray(xf)) > thr))     # 4 events
    nonzero = float(np.sum(np.abs(np.asarray(xf)) > 0))     # 9 non-zeros
    assert supra != nonzero                 # the regression is observable
    assert fstats[0]["in_events"] == supra, (fstats[0], supra, nonzero)


def test_analytic_matches_measured_dense_macs():
    """Analytic dense-MAC accounting equals the measured path's counts."""
    s = VGG16.scaled(32)
    params = init_cnn_params(KEY, s)
    x = jax.nn.relu(jax.random.normal(KEY, (1, 32, 32, 3)))
    _, stats = run_with_stats(params, x, s)
    ana = analytic_network_stats(s, tuple([1.0] * 16))
    for m, a in zip(stats, ana):
        assert m["dense_macs"] == pytest.approx(a["dense_macs"])


def test_full_res_dense_macs_vgg16():
    """VGG16@224 dense conv+fc MACs ≈ 15.5G (sanity vs literature)."""
    total = sum(layer_dense_macs(VGG16))
    assert 15.0e9 < total < 16.0e9


def test_full_res_dense_macs_alexnet():
    total = sum(layer_dense_macs(ALEXNET))
    assert 0.6e9 < total < 1.5e9
