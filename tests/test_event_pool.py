"""Event-native max-pool (DESIGN.md §7): segment max over a fired stream's
events == dense reduce_window pool, bit for bit; conv→pool→conv boundaries
stay events-only; ineligible streams fall back visibly, never silently."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import events as ev
from repro.kernels.event_pool import pool_plan
from repro.models.cnn import (CNNSpec, ConvSpec, FCSpec, PoolSpec,
                              chain_boundary_summary, cnn_forward,
                              init_cnn_params)
from repro.models.layers import max_pool_nhwc

KEY = jax.random.PRNGKey(0)


def _fired(seed, shape, sparsity=0.5):
    r = np.random.default_rng(seed)
    x = r.normal(size=shape) * (r.random(shape) > sparsity)
    return jax.nn.relu(jnp.asarray(x.astype(np.float32)))


# ---------------------------------------------------------------------------
# bit-exactness: event pool == dense pool, per backend, pixel + strip inputs
# ---------------------------------------------------------------------------

SHAPES = [  # (B, H, W, C, k, stride, blk_m_in)
    (2, 6, 6, 5, 2, 2, 1),
    (1, 7, 7, 3, 3, 2, 1),     # overlapping windows (AlexNet-style)
    (1, 9, 9, 4, 3, 3, 1),
    (2, 8, 16, 6, 2, 2, 8),    # strip-aligned input stream
    (1, 6, 8, 5, 3, 1, 8),     # stride-1 overlapping windows on strips
]


@pytest.mark.parametrize("backend", ["block", "pallas"])
@pytest.mark.parametrize("shape", SHAPES)
def test_event_pool_bitwise_equals_dense_pool(backend, shape):
    b, h, w0, c, k, s, bm = shape
    x = _fired(sum(shape), (b, h, w0, c))
    cfg = engine.EngineConfig(backend=backend, blk_m=1, blk_k=4)
    stream = engine.fire_conv(x, cfg, blk_m=bm, keep_dense=False)
    with engine.trace_dispatch() as recs:
        out = engine.maxpool2d(stream, k, s, cfg=cfg)
    assert any(rec.get("pool_events") and rec.get("chained")
               and rec["op"] == "maxpool2d" for rec in recs), recs
    assert not any(rec.get("decode") or rec.get("fallback_decode")
                   for rec in recs), recs
    assert isinstance(out, engine.EventStream)
    ref = max_pool_nhwc(x, k, s)
    assert out.logical_shape == ref.shape
    assert bool(jnp.all(out.dense_nhwc() == ref)), "event pool != dense pool"


def test_event_pool_emits_consumer_granularity():
    """The pooled stream re-tiles to what the consuming conv wants: strips
    when it is strip-eligible, pixels otherwise — the for_pool config path."""
    x = _fired(0, (2, 8, 16, 6))
    base = engine.EngineConfig(backend="block", blk_k=4)
    # Consumer 3x3/1/p1 conv over the pooled 8-wide map: strip-eligible.
    pcfg = base.for_pool(6, width=8, k=3, stride=1, padding=1, co=8)
    assert pcfg.blk_m == engine.STRIP_W
    stream = engine.fire_conv(x, base, blk_m=1, keep_dense=False)
    out = engine.maxpool2d(stream, 2, 2, cfg=pcfg)
    assert out.blk_m == engine.STRIP_W and out.logical_shape == (2, 4, 8, 6)
    # No consumer geometry: pixel-granular.
    assert base.for_pool(6).blk_m == 1
    # Strip-ineligible consumer (stride-2 conv whose downsampled output
    # width 4 cannot tile strips): pixel-granular.
    assert base.for_pool(6, width=8, k=3, stride=2, padding=1).blk_m == 1
    # A stride-2 consumer over a wide-enough pooled map *is* strip-eligible
    # now (the interleaved half-strip plan): pooled stream upgrades.
    assert base.for_pool(6, width=16, k=3, stride=2,
                         padding=1, co=8).blk_m == engine.STRIP_W


def test_event_pool_chains_into_conv_bitwise():
    """conv -> event pool -> conv, events end to end, bit-identical to the
    dense pool + re-encode round-trip."""
    x = _fired(1, (2, 8, 16, 4))
    wgt = jnp.asarray(np.random.default_rng(1).normal(
        size=(3, 3, 4, 8)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_m=1, blk_k=4)
    stream = engine.fire_conv(x, cfg, blk_m=1, keep_dense=False)
    pcfg = cfg.for_pool(4, width=8, k=3, stride=1, padding=1, co=8)
    with engine.trace_dispatch() as recs:
        pooled = engine.maxpool2d(stream, 2, 2, cfg=pcfg, keep_dense=False)
        y = engine.conv2d(pooled, wgt, cfg=cfg, padding=1)
    assert not any(r.get("decode") or r.get("fallback_decode") for r in recs)
    dense_pooled = max_pool_nhwc(x, 2, 2)
    redone = engine.EventStream.encode_nhwc(dense_pooled, blk_k=4,
                                            blk_m=pcfg.blk_m,
                                            keep_dense=False)
    y_round = engine.conv2d(redone, wgt, cfg=cfg, padding=1)
    assert bool(jnp.all(y == y_round)), "event-pooled conv != round-trip"


# ---------------------------------------------------------------------------
# eligibility + fallback visibility
# ---------------------------------------------------------------------------

def test_pool_ineligible_reasons():
    cfg = engine.EngineConfig(backend="block")
    assert engine.pool_ineligible_reason((1, 8, 8, 4), 2, 2, cfg) is None
    assert "window" in engine.pool_ineligible_reason((1, 2, 2, 4), 3, 2, cfg)
    assert "magnitude" in engine.pool_ineligible_reason(
        (1, 8, 8, 4), 2, 2, cfg.replace(magnitude=True))
    assert "maxpool2d_events" in engine.pool_ineligible_reason(
        (1, 8, 8, 4), 2, 2, cfg.replace(backend="dense"))
    # stream and logical-shape forms agree
    s = engine.fire_conv(_fired(2, (1, 8, 8, 4)),
                         engine.EngineConfig(backend="block", blk_k=4))
    assert engine.pool_ineligible_reason(s, 2, 2, cfg) is None
    fc = engine.fire(jnp.ones((4, 8)), engine.EngineConfig(backend="block"))
    assert "conv stream" in engine.pool_ineligible_reason(fc, 2, 2, cfg)


def test_magnitude_stream_falls_back_visibly():
    """A magnitude-fired stream can carry negative events — the identity-0
    segment max would clip them, so the engine must decode visibly and
    still match the dense pool."""
    r = np.random.default_rng(3)
    x = jnp.asarray((r.normal(size=(1, 6, 6, 4))
                     * (r.random((1, 6, 6, 4)) > 0.5)).astype(np.float32))
    cfg = engine.EngineConfig(backend="block", blk_k=4, magnitude=True)
    s = engine.fire_conv(x, cfg, blk_m=1)         # twin kept: free decode
    with engine.trace_dispatch() as recs:
        y = engine.maxpool2d(s, 2, 2, cfg=cfg)
    marks = [rec for rec in recs if rec.get("fallback_decode")]
    assert marks and "magnitude" in marks[0]["reason"], recs
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(max_pool_nhwc(x, 2, 2)))


def test_dense_backend_pools_densely():
    x = _fired(4, (1, 6, 6, 3))
    y = engine.maxpool2d(x, 2, 2, cfg=engine.EngineConfig(backend="dense"))
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(max_pool_nhwc(x, 2, 2)))


def test_pool_event_ops_registered():
    assert set(engine.list_backends("maxpool2d_events")) == {"block",
                                                             "pallas"}
    assert set(engine.BACKENDS) <= set(engine.list_backends("maxpool2d"))


# ---------------------------------------------------------------------------
# whole networks: zero densify points between first conv and the FC head
# ---------------------------------------------------------------------------

MINI = CNNSpec("mini-pool", 8, 3,
               (ConvSpec(8, 3, 1, 1), PoolSpec(),
                ConvSpec(8, 3, 1, 1), PoolSpec(), FCSpec(10)),
               num_classes=10)


@pytest.mark.parametrize("backend", ["block", "pallas"])
def test_chained_network_pools_in_event_domain(backend):
    cfg = engine.EngineConfig(backend=backend, blk_m=4, blk_k=8, blk_n=8)
    params = init_cnn_params(KEY, MINI, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 8, 8, 3)))
    with engine.trace_dispatch() as recs:
        ym = cnn_forward(params, x, MINI, mnf=True, chain=True,
                         engine_cfg=cfg)
    n_pool = sum(isinstance(l, PoolSpec) for l in MINI.layers)
    assert sum(1 for r in recs if r.get("pool_events")) == n_pool, recs
    assert not any(r.get("decode") or r.get("fallback_decode")
                   for r in recs), recs
    yr = cnn_forward(params, x, MINI, mnf=True, chain=False, engine_cfg=cfg)
    assert bool(jnp.all(ym == yr)), "chained != round-trip bitwise"
    yd = cnn_forward(params, x, MINI, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)


def test_chain_boundary_summary_counts_pools():
    from repro.core.fire import FireConfig

    s = chain_boundary_summary(MINI, batch=2)
    routes = s.pop("routes")
    assert s == dict(conv=2, fc=1, pool=2, pool_events=2, densify=0,
                     input_encode=1, retile=1)
    # One routing decision per stream-consuming boundary — conv 1 consumes
    # the strip-encoded input image (input_encode), conv 2 consumes a
    # stream, both pools do, and the FC head consumes the re-tiled pool
    # stream; default "auto" mode keeps every boundary on its geometric
    # event route.
    assert [r["op"] for r in routes] == ["conv2d", "maxpool2d", "conv2d",
                                        "maxpool2d", "linear"]
    assert all(r["route"] in ("strip", "pixel", "window", "event")
               for r in routes), routes
    assert routes[-1]["retile"] is True
    assert all(r["source"] == "geometry" for r in routes)
    # magnitude fire (the LM generalization) disables the identity-0
    # segment max: every pool becomes a densify point again
    s = chain_boundary_summary(MINI, batch=2,
                               fire_cfg=FireConfig(magnitude=True))
    assert s["pool_events"] == 0 and s["densify"] == 2


def test_chain_boundary_summary_matches_traced_pool_events():
    """The static summary must mirror the traced dataflow: a pool fed the
    dense input image (no conv stream yet) takes the dense fallback, and
    the summary must not count it as pool_events (regression: geometry-only
    accounting overcounted dense-fed pools and tripped the bench's
    silent-densify guard)."""
    spec = CNNSpec("pool-first", 8, 3,
                   (PoolSpec(), ConvSpec(8, 3, 1, 1), PoolSpec(),
                    FCSpec(10)), num_classes=10)
    s = chain_boundary_summary(spec, batch=2)
    assert s["pool"] == 2 and s["pool_events"] == 1 and s["densify"] == 1
    params = init_cnn_params(KEY, spec, weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(KEY, (2, 8, 8, 3)))
    with engine.trace_dispatch() as recs:
        cnn_forward(params, x, spec, mnf=True, chain=True)
    assert sum(1 for r in recs if r.get("pool_events")) == s["pool_events"]


# ---------------------------------------------------------------------------
# plan accounting + degenerate streams
# ---------------------------------------------------------------------------

def test_pool_window_map_plan():
    src, row, live = ev.pool_window_map((2, 6, 8, 4), 2, 2, 1)
    assert src.shape == (2 * 3 * 4, 4) and live.all()
    # pixel granularity: src is the flat raster index itself, row is 0
    assert (row == 0).all()
    ssrc, srow, slive = ev.pool_window_map((2, 6, 8, 4), 2, 2, 8)
    assert (ssrc == src // 8).all() and (srow == src % 8).all()


def test_pool_plan_accounting():
    plan = pool_plan((2, 8, 8, 16), 2, 2, nkb=2)
    assert plan["launches"] == 1 and plan["window_taps"] == 4
    assert plan["out_rows"] == 2 * 4 * 4
    assert plan["event_grid"] == plan["out_rows"] * 4 * 2
    assert plan["dense_reads"] == plan["out_rows"] * 4 * 16


def test_empty_stream_pools_to_empty():
    cfg = engine.EngineConfig(backend="pallas", blk_k=4)
    s = engine.fire_conv(jnp.zeros((0, 6, 6, 4)), cfg, blk_m=1)
    out = engine.maxpool2d(s, 2, 2, cfg=cfg)
    assert isinstance(out, engine.EventStream)
    assert out.logical_shape == (0, 3, 3, 4) and out.shape == (0, 4)
    assert float(out.occupancy()) == 0.0
