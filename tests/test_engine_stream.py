"""EventStream chaining: fired events feed the next layer with no dense
round-trip, bit-for-bit equal to the decode→re-encode path at threshold 0."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import engine


def _acts(seed, m=16, k=32, sparsity=0.5):
    r = np.random.default_rng(seed)
    a = r.normal(size=(m, k)) * (r.random((m, k)) > sparsity)
    return jnp.asarray(a.astype(np.float32))


@pytest.mark.parametrize("backend", ["block", "pallas"])
def test_chained_equals_roundtrip_bit_for_bit(backend):
    """fire → EventStream → linear == fire → dense → linear exactly."""
    r = np.random.default_rng(0)
    a = _acts(0)
    w1 = jnp.asarray(r.normal(size=(32, 24)).astype(np.float32))
    w2 = jnp.asarray(r.normal(size=(24, 10)).astype(np.float32))
    cfg = engine.EngineConfig(backend=backend, blk_m=4, blk_k=8, blk_n=8)

    acc = engine.linear(a, w1, cfg=cfg)
    stream = engine.fire(acc, cfg)

    y_chained = engine.linear(stream.without_dense(), w2, cfg=cfg)
    y_roundtrip = engine.linear(stream.dense(), w2, cfg=cfg)

    assert bool(jnp.all(y_chained == y_roundtrip)), "paths diverged bitwise"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16), sparsity=st.floats(0, 0.95))
def test_three_layer_chain_equals_dense_relu_mlp(seed, sparsity):
    """An event-chained 3-layer ReLU MLP == the dense oracle at threshold 0."""
    r = np.random.default_rng(seed)
    x = _acts(seed, m=8, k=24, sparsity=sparsity)
    ws = [jnp.asarray(r.normal(size=s).astype(np.float32) / np.sqrt(s[0]))
          for s in ((24, 16), (16, 16), (16, 4))]
    cfg = engine.EngineConfig(backend="block", blk_m=4, blk_k=8)

    h = x
    for w in ws[:-1]:
        h = engine.fire(engine.linear(h, w, cfg=cfg), cfg, keep_dense=False)
    y = engine.linear(h, ws[-1], cfg=cfg)

    ref = np.asarray(x)
    for w in ws[:-1]:
        ref = np.maximum(ref @ np.asarray(w), 0.0)
    ref = ref @ np.asarray(ws[-1])
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-3, rtol=1e-3)


def test_stream_dense_view_matches_fired():
    acc = _acts(3, m=8, k=16, sparsity=0.0)
    cfg = engine.EngineConfig(backend="block", blk_m=4, blk_k=8)
    with_dense = engine.fire(acc, cfg)
    events_only = engine.fire(acc, cfg, keep_dense=False)
    assert events_only.fired is None
    np.testing.assert_array_equal(np.asarray(with_dense.dense()),
                                  np.asarray(events_only.dense()))
    np.testing.assert_array_equal(np.asarray(with_dense.dense()),
                                  np.maximum(np.asarray(acc), 0.0))


def test_stream_occupancy_counts():
    acc = jnp.zeros((4, 32)).at[:, 8:16].set(1.0)    # one live K-block of 4
    cfg = engine.EngineConfig(backend="block", blk_m=4, blk_k=8)
    s = engine.fire(acc, cfg)
    assert int(s.num_events) == 1
    assert float(s.occupancy()) == pytest.approx(0.25)


def test_oracle_backend_decodes_stream():
    """dense/scalar backends accept a stream too (via documented decode)."""
    acc = _acts(5, m=8, k=16)
    w = jnp.asarray(np.random.default_rng(5).normal(size=(16, 6))
                    .astype(np.float32))
    cfg_b = engine.EngineConfig(backend="block", blk_m=4, blk_k=8)
    s = engine.fire(acc, cfg_b)
    y_dense = engine.linear(s, w, cfg=cfg_b.replace(backend="dense"))
    y_block = engine.linear(s, w, cfg=cfg_b)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_block),
                               atol=1e-4, rtol=1e-4)


def test_cnn_forward_chains_fc_layers():
    """models/cnn MNF path (chained FC EventStreams) == its dense oracle."""
    import jax

    from repro.models.cnn import ALEXNET, cnn_forward, init_cnn_params

    spec = ALEXNET.scaled(64)
    params = init_cnn_params(jax.random.PRNGKey(0), spec,
                             weight_sparsity=0.5)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1),
                                      (2, 64, 64, spec.in_ch)))
    ym = cnn_forward(params, x, spec, mnf=True)
    yd = cnn_forward(params, x, spec, mnf=False)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=5e-3,
                               rtol=5e-3)
